//! Offline DMD study: run the whole-domain simulation single-rank,
//! collect velocity snapshots in memory, and sweep the DMD window/rank
//! parameters over the same data — the kind of post-hoc exploration the
//! paper's online pipeline replaces.  Also demonstrates using the
//! public `analysis`/`linalg` APIs directly, without endpoints or
//! streaming.
//!
//! ```sh
//! cargo run --release --example dmd_offline -- --steps 600
//! ```

use elasticbroker::cli::Args;
use elasticbroker::config::IoMode;
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::sim::{lbm::LbmParams, SimConfig, SimRunner};

fn main() -> anyhow::Result<()> {
    elasticbroker::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let steps = args.get_parsed::<u64>("steps")?.unwrap_or(600);
    let stride = args.get_parsed::<u64>("stride")?.unwrap_or(10);
    let (h, w) = (64usize, 128usize);

    // Collect snapshots by running the sim in slices (None mode) and
    // sampling the final field of each slice — a deliberately simple
    // offline harness using only public API.
    println!("collecting snapshots: {h}x{w}, {steps} steps, every {stride}");
    let artifacts = ArtifactSet::try_load_default();
    let mut snapshots: Vec<Vec<f32>> = Vec::new();
    let slices = steps / stride;
    for k in 1..=slices {
        let cfg = SimConfig {
            ranks: 1,
            height: h,
            width: w,
            steps: k * stride,
            write_interval: u64::MAX, // never write
            io_mode: IoMode::None,
            out_dir: String::new(),
            field: "u".into(),
            params: LbmParams::default(),
            use_pjrt: false, // deterministic rust path, no h64 artifact needed
            pfs_commit_ms: 0,
        };
        let rep = SimRunner::run(&cfg, None, artifacts.clone())?;
        snapshots.push(rep.final_u[0].clone());
        if k % 10 == 0 {
            println!("  {k}/{slices} slices");
        }
    }

    // Sweep DMD parameters over the collected snapshot matrix.
    let d = snapshots[0].len();
    println!("\nDMD sweep over {} snapshots of dim {d}:", snapshots.len());
    println!(
        "{:>7} {:>5} {:>12} {:>14} {:>12}",
        "window", "rank", "lead |λ|", "stability", "σ₁/σ_r"
    );
    for window in [4usize, 8, 16] {
        for rank in [2usize, 4, 6] {
            if rank > window || window + 1 > snapshots.len() {
                continue;
            }
            let m1 = window + 1;
            let tail = &snapshots[snapshots.len() - m1..];
            let mut x = Mat::zeros(d, m1);
            for (j, snap) in tail.iter().enumerate() {
                for i in 0..d {
                    x[(i, j)] = snap[i] as f64;
                }
            }
            let (eigs, sigma, metric) = dmd::analyze_window(&x, rank)?;
            let lead = eigs.iter().map(|e| e.abs()).fold(0.0, f64::max);
            println!(
                "{window:>7} {rank:>5} {lead:>12.6} {metric:>14.3e} {:>12.1}",
                sigma[0] / sigma[rank - 1].max(1e-12)
            );
        }
    }
    println!("\nlead |λ| ≈ 1 confirms the wake settles into a statistically steady state;");
    println!("growing σ₁/σ_r means extra ranks only capture noise (pick r before the knee).");
    Ok(())
}
