//! The paper's §4 experiment, end to end: the *WindAroundBuildings*
//! CFD simulation (16 ranks, 256×128 lattice, 2000 steps) streaming
//! velocity fields through ElasticBroker to a Cloud-side DMD service —
//! **this is the repository's end-to-end validation driver** (see
//! EXPERIMENTS.md).
//!
//! Produces:
//!   * `wind_out/analysis.csv`     — every DMD result (Fig 5 data),
//!   * `wind_out/stability.txt`    — per-region stability table (Fig 5),
//!   * `wind_out/velocity_*.pgm`   — |u| heat-map frames (Fig 4 view),
//!   * a timing summary (one Fig 6 column).
//!
//! Flags: `--steps N` `--ranks N` `--write-interval N` `--no-pjrt`
//! `--trigger-ms N`.

use std::io::Write;

use elasticbroker::cli::Args;
use elasticbroker::config::{IoMode, WorkflowConfig};
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::util;
use elasticbroker::workflow::run_cfd_workflow;

fn main() -> anyhow::Result<()> {
    elasticbroker::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;

    std::fs::create_dir_all("wind_out")?;
    let mut cfg = WorkflowConfig {
        ranks: 16,
        height: 256,
        width: 128,
        steps: 2000,
        write_interval: 5,
        io_mode: IoMode::Broker,
        group_size: 16,
        executors: 16,
        trigger_ms: 500,
        dmd_window: 8,
        dmd_rank: 6,
        // The paper analyses once per trigger per stream (not per
        // snapshot) — and that cadence is what keeps analysis realtime.
        dmd_per_batch: true,
        analysis_csv: "wind_out/analysis.csv".into(),
        ..Default::default()
    };
    elasticbroker::cli::apply_overrides(&mut cfg, &args)?;
    cfg.validate()?;

    let artifacts = ArtifactSet::try_load_default();
    println!(
        "WindAroundBuildings: {} ranks, {}×{} lattice, {} steps, interval {} [{}]",
        cfg.ranks,
        cfg.height,
        cfg.width,
        cfg.steps,
        cfg.write_interval,
        if artifacts.is_some() { "PJRT" } else { "Rust fallback" }
    );

    let report = run_cfd_workflow(&cfg, artifacts)?;

    // ---- timing summary (one Fig 6 column) ----
    println!("\n=== timing ===");
    println!("simulation elapsed : {:.2} s", report.sim_elapsed.as_secs_f64());
    println!(
        "workflow end-to-end: {:.2} s (+{:.2} s analysis lag)",
        report.workflow_elapsed.as_secs_f64(),
        report
            .workflow_elapsed
            .saturating_sub(report.sim_elapsed)
            .as_secs_f64()
    );
    println!(
        "broker write cost  : {} (per call, µs)",
        report.metrics.write_call_us.summary()
    );
    println!(
        "shipped            : {} at {}/s",
        util::fmt_bytes(report.metrics.shipped.bytes()),
        util::fmt_bytes(report.metrics.shipped.bytes_per_sec() as u64)
    );
    println!(
        "analysis latency   : {} (µs)",
        report.metrics.e2e_latency_us.summary()
    );

    // ---- Fig 5: per-region stability over time ----
    let mut table = std::fs::File::create("wind_out/stability.txt")?;
    writeln!(table, "# region  analyses  mean_stability  last_stability")?;
    let mut per_rank = std::collections::BTreeMap::<u32, Vec<(u64, f64)>>::new();
    for a in &report.analysis_results {
        per_rank.entry(a.rank).or_default().push((a.step, a.stability));
    }
    println!("\n=== Fig 5: per-region DMD stability ===");
    for (rank, series) in &per_rank {
        let mean = series.iter().map(|(_, s)| s).sum::<f64>() / series.len() as f64;
        let last = series.last().map(|&(_, s)| s).unwrap_or(0.0);
        writeln!(table, "{rank:>7} {:>9} {mean:>15.6e} {last:>15.6e}", series.len())?;
        let bar = "#".repeat(((mean.log10() + 7.0).max(0.0) * 6.0) as usize);
        println!("  region {rank:>2}: mean {mean:>10.3e}  {bar}");
    }

    // ---- Fig 4 view: |u| heat-map of the final field ----
    // Re-run the same deterministic simulation in None mode to obtain
    // the final field for the frame (the broker run's state lives in
    // the rank threads).
    let (h, w) = (cfg.height, cfg.width);
    let h_loc = h / cfg.ranks;
    let sim_cfg = elasticbroker::sim::SimConfig {
        ranks: cfg.ranks,
        height: h,
        width: w,
        steps: cfg.steps,
        write_interval: cfg.write_interval,
        io_mode: IoMode::None,
        out_dir: String::new(),
        field: "velocity".into(),
        params: Default::default(),
        use_pjrt: cfg.use_pjrt,
        pfs_commit_ms: 0,
    };
    let sim = elasticbroker::sim::SimRunner::run(&sim_cfg, None, ArtifactSet::try_load_default())?;
    let mut mag = vec![0.0f32; h * w];
    for (rank, part) in sim.final_u.iter().enumerate() {
        for y in 0..h_loc {
            for x in 0..w {
                let ux = part[y * w + x];
                let uy = part[h_loc * w + y * w + x];
                mag[(rank * h_loc + y) * w + x] = (ux * ux + uy * uy).sqrt();
            }
        }
    }
    write_pgm("wind_out/velocity_final.pgm", &mag, h, w)?;
    println!("\nwrote wind_out/analysis.csv, wind_out/stability.txt, wind_out/velocity_final.pgm");
    Ok(())
}

/// Grayscale PGM heat map (max-normalized).
fn write_pgm(path: &str, data: &[f32], h: usize, w: usize) -> anyhow::Result<()> {
    let max = data.iter().cloned().fold(1e-12f32, f32::max);
    let mut out = Vec::with_capacity(h * w + 64);
    out.extend_from_slice(format!("P5\n{w} {h}\n255\n").as_bytes());
    for y in (0..h).rev() {
        for x in 0..w {
            let v = (data[y * w + x] / max * 255.0).clamp(0.0, 255.0) as u8;
            out.push(v);
        }
    }
    std::fs::write(path, out)?;
    Ok(())
}
