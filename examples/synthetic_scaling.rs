//! The paper's §4.3 scaling experiment, interactively sized: synthetic
//! generator ranks stream through ElasticBroker to the DMD service at
//! the 16 : 1 : 16 ranks : endpoints : executors ratio, reporting the
//! Fig 7 metrics (analysis latency + aggregated throughput).
//!
//! ```sh
//! cargo run --release --example synthetic_scaling -- --scales 16,32,64 --records 100
//! ```

use elasticbroker::cli::Args;
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::util;
use elasticbroker::workflow::run_synth_workflow;

fn main() -> anyhow::Result<()> {
    elasticbroker::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let scales: Vec<usize> = args
        .get("scales")
        .unwrap_or("16,32,64")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let records = args.get_parsed::<u64>("records")?.unwrap_or(100);
    let dim = args.get_parsed::<usize>("dim")?.unwrap_or(512);
    let trigger_ms = args.get_parsed::<u64>("trigger-ms")?.unwrap_or(250);
    let rate = args.get_parsed::<f64>("rate")?.unwrap_or(50.0);
    let artifacts = ArtifactSet::try_load_default();

    println!("synthetic scaling: dim={dim}, {records} records/rank, {rate} Hz/rank, trigger {trigger_ms} ms");
    println!(
        "{:>6} {:>5} {:>5} {:>9} {:>9} {:>12} {:>11} {:>11} {:>11}",
        "ranks", "eps", "exec", "records", "analyses", "agg MB/s", "p50 ms", "p95 ms", "max ms"
    );
    for ranks in scales {
        let rep = run_synth_workflow(ranks, records, dim, trigger_ms, rate, artifacts.clone())?;
        println!(
            "{:>6} {:>5} {:>5} {:>9} {:>9} {:>12.2} {:>11.1} {:>11.1} {:>11.1}",
            rep.ranks,
            rep.endpoints,
            rep.executors,
            rep.records,
            rep.analyses,
            rep.gen_bytes_per_sec / 1e6,
            rep.metrics.e2e_latency_us.quantile(0.50) as f64 / 1e3,
            rep.metrics.e2e_latency_us.quantile(0.95) as f64 / 1e3,
            rep.metrics.e2e_latency_us.max() as f64 / 1e3,
        );
    }
    println!(
        "\nexpected shape (paper Fig 7): latency roughly flat in ranks; throughput ~linear."
    );
    let _ = util::fmt_bytes(0);
    Ok(())
}
