//! Quickstart: the whole ElasticBroker pipeline in ~60 lines.
//!
//! Brings up one Cloud endpoint, a streaming+DMD service, and a small
//! 4-rank wind simulation shipping velocity snapshots through the
//! broker — then prints what the Cloud side learned about the flow.
//!
//! ```sh
//! make artifacts            # optional: enables the PJRT backend
//! cargo run --release --example quickstart
//! ```

use elasticbroker::config::{IoMode, WorkflowConfig};
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::util;
use elasticbroker::workflow::run_cfd_workflow;

fn main() -> anyhow::Result<()> {
    elasticbroker::util::logger::init();

    // A small WindAroundBuildings case: 4 ranks on a 32×64 lattice,
    // writing every 5 steps; Cloud triggers every 200 ms.
    let cfg = WorkflowConfig {
        ranks: 4,
        height: 32,
        width: 64,
        steps: 300,
        write_interval: 5,
        io_mode: IoMode::Broker,
        group_size: 4, // all 4 ranks → 1 endpoint
        executors: 4,
        trigger_ms: 200,
        dmd_window: 8,
        dmd_rank: 6,
        ..Default::default()
    };

    // The AOT artifacts (JAX/Pallas lowered to HLO, run via PJRT).
    // Missing artifacts are fine: the pure-Rust mirrors take over.
    let artifacts = ArtifactSet::try_load_default();
    println!(
        "backend: {}",
        if artifacts.is_some() { "PJRT artifacts" } else { "Rust fallback" }
    );

    let report = run_cfd_workflow(&cfg, artifacts)?;

    println!("\n=== quickstart results ===");
    println!(
        "simulation : {} ranks × {} steps in {:.2} s",
        cfg.ranks,
        cfg.steps,
        report.sim_elapsed.as_secs_f64()
    );
    println!(
        "end-to-end : {:.2} s (simulation start → last DMD analysis)",
        report.workflow_elapsed.as_secs_f64()
    );
    println!(
        "shipped    : {} at {}/s",
        util::fmt_bytes(report.metrics.shipped.bytes()),
        util::fmt_bytes(report.metrics.shipped.bytes_per_sec() as u64),
    );
    println!(
        "analyses   : {} windows; latency {}",
        report.analysis_results.len(),
        report.metrics.e2e_latency_us.summary()
    );

    // Fig 5 in miniature: how stable is the flow in each rank's region?
    let mut per_rank = std::collections::BTreeMap::<u32, Vec<f64>>::new();
    for a in &report.analysis_results {
        per_rank.entry(a.rank).or_default().push(a.stability);
    }
    println!("\nregion stability (mean sq. distance of DMD eigenvalues to unit circle):");
    for (rank, vals) in per_rank {
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let bar_len = ((mean.log10() + 6.0).max(0.0) * 8.0) as usize;
        println!(
            "  region {rank}: {:>10.3e} {}",
            mean,
            "#".repeat(bar_len.min(60))
        );
    }
    println!("\n(values near 0 ⇒ steady flow in that region; larger ⇒ transients)");

    // --- the same pipeline with durable endpoints (ISSUE 4) ----------
    // Each endpoint writes a segmented WAL under `wal_dir/ep<i>`; with
    // retention on, the streaming side acknowledges consumed cursors
    // (`XACKPOS`) and the endpoints trim their logs by them.  A crashed
    // endpoint restarted on the same directory replays its streams and
    // fencing state — see `rust/tests/crash_restart.rs` for that story.
    let wal_dir = std::env::temp_dir().join(format!("eb-quickstart-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&wal_dir);
    let cfg = WorkflowConfig {
        steps: 100,
        wal_dir: wal_dir.to_string_lossy().into_owned(),
        wal_fsync: elasticbroker::endpoint::FsyncPolicy::EveryMs(5),
        retention: true,
        ..cfg
    };
    println!("\n=== once more, with persistence (wal_dir = {}) ===", cfg.wal_dir);
    let report = run_cfd_workflow(&cfg, None)?;
    println!(
        "durable run: {} analyses in {:.2} s, {} shipped, {} replay gap(s)",
        report.analysis_results.len(),
        report.workflow_elapsed.as_secs_f64(),
        util::fmt_bytes(report.metrics.shipped.bytes()),
        report.metrics.replay_gaps.get()
    );
    let _ = std::fs::remove_dir_all(&wal_dir);
    Ok(())
}
