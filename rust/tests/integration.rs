//! Cross-module integration tests: real TCP, real threads, and (when
//! `make artifacts` has run) the real PJRT path — the full Fig 2/Fig 3
//! topology exercised end to end.

use std::sync::Arc;
use std::time::Duration;

use elasticbroker::analysis::{DmdConfig, DmdEngine};
use elasticbroker::broker::{Broker, BrokerConfig, Filter, FilterStage};
use elasticbroker::config::{IoMode, WorkflowConfig};
use elasticbroker::endpoint::{EndpointServer, StoreConfig};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::StreamRecord;
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::sim::{SimConfig, SimRunner};
use elasticbroker::streamproc::{MicroBatch, StreamReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::ConnConfig;
use elasticbroker::workflow::{run_cfd_workflow, run_synth_workflow};

fn artifacts() -> Option<Arc<ArtifactSet>> {
    ArtifactSet::try_load_default()
}

/// HPC side and Cloud side in *separate thread domains* over real TCP,
/// multiple endpoints, the paper's group mapping — records all arrive,
/// exactly once per (rank, step), in order.
#[test]
fn two_endpoint_topology_delivers_everything() {
    let e0 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let e1 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 4, // 8 ranks → 2 groups → 2 endpoints
                ..BrokerConfig::new(vec![e0.addr(), e1.addr()])
            },
            8,
            metrics.clone(),
        )
        .unwrap(),
    );

    // HPC side: 8 writer threads.
    let writers: Vec<_> = (0..8u32)
        .map(|rank| {
            let broker = broker.clone();
            std::thread::spawn(move || {
                let ctx = broker.init("u", rank).unwrap();
                let data: Vec<f32> = (0..32).map(|i| (i + rank) as f32).collect();
                for step in 0..20 {
                    ctx.write(step, &[32], &data).unwrap();
                }
                ctx.finalize().unwrap();
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }

    // Cloud side: one reader per endpoint with the group's streams.
    let groups = broker.groups();
    for (idx, srv) in [(0usize, &e0), (1usize, &e1)] {
        let keys = groups.streams_of_endpoint(idx, "u");
        assert_eq!(keys.len(), 4);
        let mut reader =
            StreamReader::connect(srv.addr(), keys.clone(), 0, ConnConfig::default()).unwrap();
        let batches = reader.poll().unwrap();
        assert_eq!(batches.len(), 4, "endpoint {idx}");
        for b in &batches {
            assert_eq!(b.len(), 20);
            let steps: Vec<u64> = b.records.iter().map(|r| r.step).collect();
            assert_eq!(steps, (0..20).collect::<Vec<_>>(), "{}", b.key);
        }
    }
    assert_eq!(metrics.shipped.records(), 160);
    assert_eq!(metrics.dropped.get(), 0);
}

/// The paper's full pipeline at integration scale, with the analysis
/// engine on the executors: simulation → broker → endpoint → streaming
/// → DMD, using the pure-Rust backends.
#[test]
fn full_pipeline_rust_backend() {
    let cfg = WorkflowConfig {
        ranks: 4,
        height: 64,
        width: 64,
        steps: 120,
        write_interval: 4,
        io_mode: IoMode::Broker,
        use_pjrt: false,
        group_size: 2, // 2 endpoints
        endpoints: Some(2),
        executors: 4,
        trigger_ms: 60,
        dmd_window: 6,
        dmd_rank: 4,
        ..Default::default()
    };
    let rep = run_cfd_workflow(&cfg, None).unwrap();
    // 30 snapshots/rank; window 7 fills at 7 → 24 analyses × 4 ranks
    assert_eq!(rep.analysis_results.len(), 24 * 4);
    for a in &rep.analysis_results {
        assert!(a.stability.is_finite() && a.stability >= 0.0);
        assert_eq!(a.eigs.len(), 4);
        assert_eq!(a.backend, "rust");
        assert!(a.sigma.windows(2).all(|w| w[0] >= w[1] - 1e-9));
    }
    assert!(rep.workflow_elapsed >= rep.sim_elapsed);
}

/// Same pipeline, PJRT backend (requires `make artifacts`): LBM steps
/// and DMD reductions go through compiled HLO, and the results agree
/// with the Rust mirror run on the identical configuration.
#[test]
fn pjrt_and_fallback_agree() {
    let Some(arts) = artifacts() else {
        eprintln!("WARNING: artifacts absent; skipping PJRT integration test");
        return;
    };
    let mk = |use_pjrt: bool| WorkflowConfig {
        ranks: 4,
        height: 32,  // h_loc=8 → lbm artifacts h8_w64; dmd d1024
        width: 64,
        steps: 100,
        write_interval: 5,
        io_mode: IoMode::Broker,
        use_pjrt,
        group_size: 4,
        executors: 4,
        trigger_ms: 60,
        dmd_window: 8,
        dmd_rank: 6,
        ..Default::default()
    };
    let rep_pjrt = run_cfd_workflow(&mk(true), Some(arts.clone())).unwrap();
    let rep_rust = run_cfd_workflow(&mk(false), None).unwrap();
    assert_eq!(rep_pjrt.backend, "pjrt");
    assert_eq!(rep_rust.backend, "rust");
    assert_eq!(
        rep_pjrt.analysis_results.len(),
        rep_rust.analysis_results.len()
    );
    // every analysis window used the compiled dmd artifact
    assert!(rep_pjrt
        .analysis_results
        .iter()
        .all(|a| a.backend == "pjrt"));

    // deterministic sim ⇒ matching (rank, step) keyed stabilities
    let key = |a: &elasticbroker::analysis::AnalysisResult| (a.rank, a.step);
    let mut left = rep_pjrt.analysis_results.clone();
    let mut right = rep_rust.analysis_results.clone();
    left.sort_by_key(&key);
    right.sort_by_key(&key);
    for (l, r) in left.iter().zip(&right) {
        assert_eq!(key(l), key(r));
        let denom = r.stability.abs().max(1e-6);
        assert!(
            (l.stability - r.stability).abs() / denom < 0.15,
            "stability diverged at rank {} step {}: pjrt {} vs rust {}",
            l.rank,
            l.step,
            l.stability,
            r.stability
        );
    }
}

/// Filters compose with the full pipeline: a Magnitude-aggregating
/// broker halves the payload and the analysis still works on it.
#[test]
fn filtered_stream_analysis() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Broker::new(
        BrokerConfig {
            group_size: 1,
            ..BrokerConfig::new(vec![srv.addr()])
        },
        1,
        metrics.clone(),
    )
    .unwrap();
    let ctx = broker
        .init_filtered("u", 0, Filter::new(vec![FilterStage::Magnitude]))
        .unwrap();
    let (h, w) = (8usize, 16usize);
    for step in 0..12u64 {
        let mut field = vec![0.0f32; 2 * h * w];
        for (i, v) in field.iter_mut().enumerate() {
            *v = ((step as f32) * 0.3 + i as f32 * 0.01).sin() * 0.9f32.powi(step as i32);
        }
        ctx.write(step, &[2, h as u32, w as u32], &field).unwrap();
    }
    ctx.finalize().unwrap();

    let engine = DmdEngine::new(
        DmdConfig {
            window: 6,
            rank: 3,
            hop: 1,
            ..Default::default()
        },
        None,
        metrics,
    )
    .unwrap();
    let mut reader =
        StreamReader::connect(srv.addr(), vec!["u/0".into()], 0, ConnConfig::default()).unwrap();
    let batches = reader.poll().unwrap();
    assert_eq!(batches.len(), 1);
    // magnitude filter collapsed [2,h,w] → [h,w]
    assert_eq!(batches[0].records[0].shape, vec![h as u32, w as u32]);
    let results = engine.process(&batches[0]);
    assert_eq!(results.len(), 6); // 12 snapshots, window 7 → 6 windows
    assert!(results.iter().all(|r| r.stability.is_finite()));
}

/// Backpressure propagates endpoint → broker → producer: a tiny memory
/// budget with a blocked reader eventually OOMs, the broker retries,
/// and after the reader drains (DEL), everything completes losslessly.
#[test]
fn oom_backpressure_recovers_after_drain() {
    let srv = EndpointServer::start(
        "127.0.0.1:0",
        StoreConfig {
            stream_maxlen: 0,
            max_memory: 256 * 1024, // tight budget
            ..StoreConfig::default()
        },
    )
    .unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 1,
                queue_cap: 4,
                ..BrokerConfig::new(vec![srv.addr()])
            },
            1,
            metrics.clone(),
        )
        .unwrap(),
    );
    // Drainer: periodically frees the stream so OOM clears.
    let addr = srv.addr();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let dstop = stop.clone();
    let drainer = std::thread::spawn(move || {
        let mut conn = RespConnHelper::connect(addr);
        while !dstop.load(std::sync::atomic::Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(40));
            conn.del("u/0");
        }
    });

    let ctx = broker.init("u", 0).unwrap();
    let data = vec![0.5f32; 16 * 1024]; // 64 KiB each → 4 fill the budget
    for step in 0..32u64 {
        ctx.write(step, &[16 * 1024], &data).unwrap();
    }
    ctx.finalize().unwrap(); // must not hang or fail
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    drainer.join().unwrap();
    assert_eq!(metrics.shipped.records(), 32);
    assert_eq!(metrics.dropped.get(), 0);
}

/// Helper: a minimal RESP client for test choreography.
struct RespConnHelper {
    conn: elasticbroker::transport::RespConn,
}

impl RespConnHelper {
    fn connect(addr: std::net::SocketAddr) -> Self {
        RespConnHelper {
            conn: elasticbroker::transport::RespConn::connect(addr, ConnConfig::default())
                .unwrap(),
        }
    }
    fn del(&mut self, key: &str) {
        let _ = self.conn.request(&[b"DEL", key.as_bytes()]);
    }
}

/// Synthetic workflow at the paper's ratio with multiple endpoints.
#[test]
fn synth_workflow_two_groups() {
    let rep = run_synth_workflow(32, 20, 128, 60, 0.0, None).unwrap();
    assert_eq!(rep.endpoints, 2);
    assert_eq!(rep.records, 32 * 20);
    // window 9 → 12 analyses per rank
    assert_eq!(rep.analyses, 32 * 12);
    assert!(rep.metrics.e2e_latency_us.quantile(0.5) > 0);
}

/// File mode and broker mode both deliver every snapshot; None mode is
/// fastest (shape of Fig 6 at micro scale, Rust backend).
#[test]
fn io_modes_complete_and_rank_sanely() {
    let mk = |mode: IoMode, dir: &str| SimConfig {
        ranks: 2,
        height: 16,
        width: 32,
        steps: 60,
        write_interval: 2,
        io_mode: mode,
        out_dir: dir.into(),
        field: "u".into(),
        params: Default::default(),
        use_pjrt: false,
        pfs_commit_ms: 0,
    };
    // None
    let rep_none = SimRunner::run(&mk(IoMode::None, ""), None, None).unwrap();
    // File
    let dir = std::env::temp_dir().join(format!("eb-int-file-{}", std::process::id()));
    let dir_s = dir.to_string_lossy().into_owned();
    std::fs::remove_dir_all(&dir).ok();
    let rep_file = SimRunner::run(&mk(IoMode::File, &dir_s), None, None).unwrap();
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 30);
    std::fs::remove_dir_all(&dir).ok();
    // Broker
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 2,
                ..BrokerConfig::new(vec![srv.addr()])
            },
            2,
            metrics.clone(),
        )
        .unwrap(),
    );
    let rep_broker = SimRunner::run(&mk(IoMode::Broker, ""), Some(broker), None).unwrap();
    assert_eq!(srv.store().xlen("u/0"), 30);
    assert_eq!(srv.store().xlen("u/1"), 30);
    // identical physics across modes
    for (a, b) in rep_none.final_u.iter().zip(&rep_broker.final_u) {
        assert_eq!(a, b, "I/O mode changed the physics");
    }
    for (a, b) in rep_none.final_u.iter().zip(&rep_file.final_u) {
        assert_eq!(a, b);
    }
}

/// A decoded record survives the whole path bit-exactly (HPC write →
/// RESP wire → store → XREAD → decode).
#[test]
fn payload_bit_exact_through_pipeline() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let broker = Broker::new(
        BrokerConfig {
            group_size: 1,
            ..BrokerConfig::new(vec![srv.addr()])
        },
        1,
        WorkflowMetrics::new(),
    )
    .unwrap();
    let ctx = broker.init("exact", 0).unwrap();
    let data: Vec<f32> = vec![
        0.0,
        -0.0,
        1.5,
        f32::MIN_POSITIVE,
        f32::MAX,
        -1e-38,
        std::f32::consts::PI,
    ];
    ctx.write(7, &[data.len() as u32], &data).unwrap();
    ctx.finalize().unwrap();
    let mut reader = StreamReader::connect(
        srv.addr(),
        vec!["exact/0".into()],
        0,
        ConnConfig::default(),
    )
    .unwrap();
    let batches = reader.poll().unwrap();
    let got = batches[0].records[0].payload_f32().unwrap();
    for (a, b) in got.iter().zip(&data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Streaming context + engine under sustained concurrent load from many
/// producers (stress): nothing lost, nothing duplicated.
#[test]
fn stress_concurrent_pipeline_exactly_once() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 8,
                ..BrokerConfig::new(vec![srv.addr()])
            },
            8,
            metrics.clone(),
        )
        .unwrap(),
    );
    let keys: Vec<String> = (0..8).map(|r| format!("u/{r}")).collect();
    let reader = StreamReader::connect(srv.addr(), keys, 0, ConnConfig::default()).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 8,
            batch_limit: 0,
        },
        vec![reader],
        |b: &MicroBatch| {
            b.records
                .iter()
                .map(|r| (r.rank, r.step))
                .collect::<Vec<_>>()
        },
        tx,
    );
    let producers: Vec<_> = (0..8u32)
        .map(|rank| {
            let broker = broker.clone();
            std::thread::spawn(move || {
                let ctxw = broker.init("u", rank).unwrap();
                let data = vec![0.1f32; 64];
                for step in 0..100u64 {
                    ctxw.write(step, &[64], &data).unwrap();
                    if step % 17 == 0 {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                ctxw.finalize().unwrap();
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    // allow the final trigger(s) to run, then stop (stop also drains)
    ctx.stop().unwrap();
    let mut seen: std::collections::HashSet<(u32, u64)> = std::collections::HashSet::new();
    let mut total = 0usize;
    for (_seq, pair) in rx.try_iter() {
        total += 1;
        assert!(seen.insert(pair), "duplicate delivery of {pair:?}");
    }
    assert_eq!(total, 800);
}

/// StreamRecord decoding rejects hostile wire data without panicking
/// (failure injection on the Cloud ingest path).
#[test]
fn hostile_wire_data_rejected() {
    let good = StreamRecord::from_f32("u", 0, 1, 2, &[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
    let buf = good.encode();
    let mut rng = elasticbroker::util::rng::Rng::new(0xBAD);
    for _ in 0..2000 {
        let mut fuzz = buf.clone();
        let flips = 1 + rng.next_below(8) as usize;
        for _ in 0..flips {
            let i = rng.next_below(fuzz.len() as u64) as usize;
            fuzz[i] ^= rng.next_u64() as u8;
        }
        let _ = StreamRecord::decode(&fuzz); // must not panic
    }
}
