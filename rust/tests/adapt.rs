//! Closed-loop adaptive reduction (ISSUE 8 tentpole), end to end:
//!
//! * **Degrade / recover** — a broker shipping through a throttled
//!   (WAN-simulated) link walks its stream down the reduction ladder
//!   under backlog pressure and back up to level 0 once the link is
//!   calm again, with every shipped frame carrying its `lvl:N@E`
//!   provenance tag.
//! * **Accuracy target** — a stream forced to the lossiest rung
//!   mid-run never ships a frame whose measured error exceeds
//!   `stages.max_err`; the write path disqualifies the offending rungs
//!   and re-encodes.  The streamed DMD over the mixed-fidelity history
//!   stays close to the offline oracle computed on the *original*
//!   (pre-reduction) snapshots.
//! * **Crash-restart** — mid-run level changes round-trip through a
//!   real WAL: the replayed frames are byte-identical and their EBR2
//!   meta still carries the exact level/epoch history that shipped.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elasticbroker::analysis::{AnalysisResult, DmdBackend, DmdConfig, DmdEngine};
use elasticbroker::broker::{
    AdaptConfig, AdaptController, BoundedQueue, Broker, BrokerConfig, Ladder,
    QueuePolicy, StagesConfig, StreamAdapt,
};
use elasticbroker::endpoint::{
    EndpointServer, EntryId, FsyncPolicy, Store, StoreConfig, WalConfig,
};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::{AdaptMetrics, StageMetrics, WorkflowMetrics};
use elasticbroker::record::{CodecKind, StreamRecord};
use elasticbroker::streamproc::{StreamReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::ConnConfig;

/// Deterministic smooth snapshot for (rank, step) — same family as the
/// stages suite, so reduction errors are small and well understood.
fn snapshot(rank: u32, step: u64, dim: usize) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..dim)
        .map(|i| {
            let phase = 0.13 * i as f64 + 0.31 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

/// The controller walks a stream lossier while a throttled link is
/// drowning, and back to full fidelity once the pressure stops.
#[test]
fn controller_degrades_under_pressure_and_recovers() {
    const DIM: usize = 16 * 1024; // 64 KiB/frame at f32

    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let adapt_cfg = AdaptConfig {
        sweep_ms: 20,
        // generous latency budget: this test pressures via backlog
        target_p95_us: 60_000_000,
        queue_hi: 4,
        hysteresis: 2,
    };
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 1,
                queue_cap: 8,
                batch_max_records: 2,
                conn: ConnConfig {
                    // ~200 KB/s WAN: one raw frame alone takes ~0.3 s
                    throttle_bytes_per_sec: Some(200_000.0),
                    ..ConnConfig::default()
                },
                adapt: adapt_cfg.clone(),
                ..BrokerConfig::new(vec![srv.addr()])
            },
            1,
            metrics.clone(),
        )
        .unwrap(),
    );
    assert!(broker.adapt_enabled());
    let controller = AdaptController::start(
        broker.adapt_registry(),
        broker.topology().clone(),
        metrics.clone(),
        adapt_cfg,
    );

    let ctx = broker.init("wan", 0).unwrap();
    let s = broker
        .adapt_registry()
        .stream("wan/0")
        .expect("context registered its adapt state");
    assert_eq!(s.ladder().len(), 6, "full f32 ladder");

    // Phase 1: offer far more than the link carries; the writer queue
    // backs up past queue_hi and the controller must step down.
    let data = snapshot(0, 3, DIM);
    let mut step = 0u64;
    let deadline = Instant::now() + Duration::from_secs(30);
    while s.level() == 0 && Instant::now() < deadline {
        ctx.write(step, &[DIM as u32], &data).unwrap();
        step += 1;
    }
    assert!(
        s.level() > 0,
        "controller never degraded under a 200 KB/s throttle"
    );
    assert!(metrics.adapt.steps_down.get() >= 1);

    // Phase 2: drop to a trickle the throttled link easily carries;
    // once the backlog drains and calm sweeps accumulate past the
    // hysteresis, the stream must walk all the way back to level 0.
    let tiny = snapshot(0, 5, 64);
    let deadline = Instant::now() + Duration::from_secs(30);
    while s.level() > 0 && Instant::now() < deadline {
        ctx.write(step, &[64], &tiny).unwrap();
        step += 1;
        std::thread::sleep(Duration::from_millis(40));
    }
    assert_eq!(s.level(), 0, "controller never recovered after the pressure");
    assert!(metrics.adapt.steps_up.get() >= 1);
    controller.stop();
    ctx.finalize().unwrap();

    // Every shipped frame is a self-describing EBR2 frame with its
    // level/epoch tag — and the run really changed levels on the wire.
    let entries = srv.store().read_after("wan/0", EntryId::ZERO, 0);
    assert_eq!(entries.len(), step as usize, "no frame lost");
    let mut tags = std::collections::BTreeSet::new();
    for e in &entries {
        let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
        let meta = rec.meta.expect("adaptive frames are EBR2");
        let tag = meta
            .provenance
            .split('|')
            .find(|p| p.starts_with("lvl:"))
            .unwrap_or_else(|| panic!("untagged frame: {}", meta.provenance))
            .to_string();
        tags.insert(tag);
    }
    assert!(
        tags.len() >= 2,
        "expected level transitions on the wire, saw only {tags:?}"
    );
    // dwell counters saw both the deep and the recovered levels
    let dwell = metrics.adapt.dwell_counts();
    assert!(dwell.iter().sum::<u64>() > 0, "controller never swept");
}

/// Accuracy is enforced per frame even when the stream is forced to
/// the lossiest rung mid-run, and the streamed DMD over what actually
/// shipped stays close to the offline oracle on the original data.
#[test]
fn forced_lossy_stream_respects_accuracy_target_and_dmd_tracks_oracle() {
    const RANKS: u32 = 2;
    const DIM: usize = 32;
    const STEPS: u64 = 20;
    const WINDOW: usize = 6;
    const DMD_RANK: usize = 4;
    const MAX_ERR: f32 = 1e-3;

    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: RANKS as usize,
                queue_cap: 32,
                batch_max_records: 8,
                linger_ms: 5,
                stages: StagesConfig {
                    max_err: MAX_ERR,
                    codec: CodecKind::ShuffleLz,
                    ..StagesConfig::default()
                },
                // adaptive write path on; levels driven by hand below,
                // no controller
                adapt: AdaptConfig { sweep_ms: 3_600_000, ..AdaptConfig::default() },
                ..BrokerConfig::new(vec![srv.addr()])
            },
            RANKS as usize,
            metrics.clone(),
        )
        .unwrap(),
    );
    // max_err 1e-3 prunes the coarsened qdelta rung (4e-3 / 2 > 1e-3):
    // [f32, f16, qdelta(1e-3), agg×2, agg×4]
    for rank in 0..RANKS {
        let ctx = broker.init("synth", rank).unwrap();
        let s = broker.adapt_registry().stream(&format!("synth/{rank}")).unwrap();
        assert_eq!(s.ladder().len(), 5);
        for step in 0..STEPS {
            if step == STEPS / 2 {
                // mid-run: slam the stream to the lossiest rung, as a
                // drowning controller would
                while s.step_down().is_some() {}
            }
            ctx.write(step, &[DIM as u32], &snapshot(rank, step, DIM)).unwrap();
        }
        ctx.finalize().unwrap();
        // both aggregate rungs measured over target on this data and
        // were disqualified by the write path, never shipped
        assert!(!s.admissible(3) && !s.admissible(4), "agg rungs must reject");
        assert!(s.level() <= 2, "stream settled on an accurate rung");
    }
    assert_eq!(metrics.dropped.get(), 0);
    assert_eq!(
        metrics.adapt.err_rejections.get(),
        2 * RANKS as u64,
        "each rank rejects exactly its two aggregate rungs"
    );

    // Every stored frame honours the target against the *original*.
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let entries = srv.store().read_after(&key, EntryId::ZERO, 0);
        assert_eq!(entries.len(), STEPS as usize);
        let mut lossy = 0;
        for e in &entries {
            let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
            let meta = rec.meta.as_ref().expect("EBR2");
            assert!(
                meta.err_bound <= MAX_ERR,
                "{key} step {}: shipped bound {} over target",
                rec.step,
                meta.err_bound
            );
            let got = rec.payload_f32().unwrap();
            assert_eq!(got.len(), DIM, "no shape-changing rung may ship here");
            let original = snapshot(rank, rec.step, DIM);
            for (a, b) in got.iter().zip(&original) {
                assert!(
                    (a - b).abs() <= meta.err_bound + 1e-6,
                    "{key} step {}: {b} → {a} over stated bound {}",
                    rec.step,
                    meta.err_bound
                );
            }
            if meta.err_bound > 0.0 {
                lossy += 1;
            }
        }
        assert!(lossy > 0, "{key}: the forced rungs never produced a lossy frame");
    }

    // Streamed DMD over the mixed-fidelity history vs the offline
    // oracle on the original snapshots: within the accuracy regime.
    let engine = Arc::new(
        DmdEngine::new(
            DmdConfig {
                window: WINDOW,
                rank: DMD_RANK,
                hop: 1,
                backend: DmdBackend::Rust,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap(),
    );
    let keys: Vec<String> = (0..RANKS).map(|r| format!("synth/{r}")).collect();
    let reader =
        StreamReader::connect(srv.addr(), keys, 0, ConnConfig::default()).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let eng = engine.clone();
    let sctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 2,
            batch_limit: 0,
        },
        vec![reader],
        move |b| eng.process(b),
        tx,
    );
    let expect = (STEPS as usize - WINDOW) * RANKS as usize;
    let mut results: Vec<AnalysisResult> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(15);
    while results.len() < expect && Instant::now() < deadline {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(100)) {
            results.push(res);
        }
    }
    sctx.stop().unwrap();
    results.extend(rx.try_iter().map(|(_, r)| r));
    assert_eq!(results.len(), expect, "analysis count");

    let m1 = WINDOW + 1;
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let streamed = results
            .iter()
            .filter(|r| r.key == key)
            .max_by_key(|r| r.step)
            .unwrap_or_else(|| panic!("no results for {key}"));
        assert_eq!(streamed.step, STEPS - 1);
        // oracle on the ORIGINAL snapshots of the final window (all
        // shipped at the quantized rung, err ≤ 5e-4)
        let mut x = vec![0.0f64; DIM * m1];
        for j in 0..m1 {
            let snap = snapshot(rank, STEPS - m1 as u64 + j as u64, DIM);
            for i in 0..DIM {
                x[i * m1 + j] = snap[i] as f64;
            }
        }
        let xm = Mat::from_slice(DIM, m1, &x).unwrap();
        let (eigs, _sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();
        assert!(
            (streamed.stability - stability).abs() <= 0.02,
            "{key}: stability {} drifted from oracle {} beyond the \
             accuracy regime",
            streamed.stability,
            stability
        );
        // near-equal moduli (conjugate pairs) may reorder under the
        // reduction perturbation — match each oracle eig to its nearest
        // streamed eig instead of relying on sort order
        for b in &eigs {
            let d = streamed
                .eigs
                .iter()
                .map(|a| ((a.re - b.re).powi(2) + (a.im - b.im).powi(2)).sqrt())
                .fold(f64::INFINITY, f64::min);
            assert!(d <= 0.02, "{key}: no streamed eig within 0.02 of oracle {b:?}");
        }
    }
}

/// Mid-run level changes survive an endpoint crash-restart: the WAL
/// replays the frames byte-identically and the EBR2 meta still tells
/// the exact fidelity history (`lvl:N@E` per frame).
#[test]
fn level_changes_replay_cleanly_across_crash_restart() {
    const DIM: usize = 64;
    let dir = std::env::temp_dir().join(format!("eb-adapt-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = StoreConfig {
        shards: 2,
        wal: Some(WalConfig {
            dir: dir.clone(),
            fsync: FsyncPolicy::Always,
            segment_bytes: 1 << 20,
        }),
        ..StoreConfig::default()
    };

    // Unconstrained ladder (max_err 0): every rung admissible, so the
    // level history below is exactly what ships.
    let base = StagesConfig { codec: CodecKind::ShuffleLz, ..StagesConfig::default() };
    let ladder = Ladder::build(&base, Arc::new(StageMetrics::new())).unwrap();
    let queue = Arc::new(BoundedQueue::new(8, QueuePolicy::Block));
    let s = StreamAdapt::new("u/0".into(), 0, ladder, queue);
    let am = AdaptMetrics::new();

    let mut frames: Vec<Vec<u8>> = Vec::new();
    {
        let store = Store::open(cfg.clone()).unwrap();
        for step in 0..15u64 {
            if step == 5 || step == 10 {
                s.step_down().unwrap();
            }
            let data = snapshot(0, step, DIM);
            let rec = s
                .encode("u", 0, step, step, 0, &[DIM as u32], &data, &am)
                .unwrap()
                .expect("nothing filtered here");
            let bytes = rec.encode();
            store
                .xadd("u/0", None, vec![(b"r".to_vec(), bytes.clone())])
                .unwrap();
            frames.push(bytes);
        }
    } // drop = crash

    let store = Store::open(cfg).unwrap();
    let entries = store.read_after("u/0", EntryId::ZERO, 0);
    assert_eq!(entries.len(), 15, "replay lost frames");
    for (i, e) in entries.iter().enumerate() {
        assert_eq!(
            e.fields[0].1, frames[i],
            "step {i}: WAL replay must not touch adaptive frames"
        );
        let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
        assert_eq!(rec.step, i as u64);
        let meta = rec.meta.expect("EBR2 meta survives the WAL");
        // the exact level/epoch history: 0@0 → 1@1 (f16) → 2@2 (qdelta)
        let expect = if i < 5 {
            "lvl:0@0"
        } else if i < 10 {
            "lvl:1@1"
        } else {
            "lvl:2@2"
        };
        assert!(
            meta.provenance.contains(expect),
            "step {i}: provenance '{}' missing {expect}",
            meta.provenance
        );
        // decoded payload still within the stated bound of the original
        let original = snapshot(0, rec.step, DIM);
        for (a, b) in rec.payload_f32().unwrap().iter().zip(&original) {
            assert!(
                (a - b).abs() <= meta.err_bound + 1e-6,
                "step {i}: {b} → {a} over bound {}",
                meta.err_bound
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
