//! ISSUE 9 integration: the flight recorder.
//!
//! Asserted end to end, over real TCP endpoints and real WALs:
//!
//! * a trace-stamped staged frame round-trips **byte-identically**
//!   through fenced ingest → endpoint crash → WAL replay → `XHANDOFF`
//!   migration to a second endpoint — the stamp is CRC-covered wire
//!   state, not an in-memory annotation;
//! * a server-side `XREAD STRIDE` reduced view re-encodes the frame
//!   but carries the stamp across, and a reader tailing the stream
//!   closes the chain with a monotone hop sequence
//!   (origin ≤ enqueue ≤ flush ≤ deliver);
//! * the `METRICS` wire command serves Prometheus text covering the
//!   store, WAL, server, ingest-hop and — when a workflow attached its
//!   registry — every broker/stage/trace series;
//! * WAL segment rotation lands as `wal.rotate` events in an attached
//!   control-plane journal.

use std::sync::Arc;

use elasticbroker::broker::{StagePipeline, StagesConfig};
use elasticbroker::endpoint::{
    EndpointServer, EntryId, FsyncPolicy, StoreConfig, WalConfig,
};
use elasticbroker::metrics::{EventJournal, WorkflowMetrics};
use elasticbroker::record::{CodecKind, StreamRecord, Trace};
use elasticbroker::streamproc::StreamReader;
use elasticbroker::transport::{ConnConfig, RespConn};

const KEY: &str = "u/0";

/// A real staged frame (stats sidecar + shuffle-lz wire codec) with a
/// hop stamp applied exactly like the broker's 1-in-N sampler does.
fn traced_record(step: u64, d: usize) -> (StreamRecord, Trace) {
    let cfg = StagesConfig {
        stats: true,
        codec: CodecKind::ShuffleLz,
        ..Default::default()
    };
    let pipe = StagePipeline::new(cfg, WorkflowMetrics::new().stages.clone()).unwrap();
    let data: Vec<f32> = (0..d)
        .map(|i| ((0.3 * i as f64 + step as f64).sin()) as f32)
        .collect();
    let origin = elasticbroker::util::epoch_micros();
    let mut rec = pipe
        .apply("u", 0, step, 0, origin, &[d as u32], &data)
        .unwrap()
        .expect("stats+codec stages never drop");
    let t = Trace {
        origin_us: origin,
        enqueue_us: origin + 10,
        flush_us: origin + 25,
        deliver_us: 0, // the reader's hop; never serialized non-zero
    };
    rec.meta.as_mut().expect("staged frames carry meta").trace = Some(t);
    (rec, t)
}

/// Fetch all of `key` through one XREAD with extra view options.
fn xread_records(c: &mut RespConn, extra: &[&[u8]], key: &str) -> Vec<StreamRecord> {
    let mut cmd: Vec<&[u8]> = vec![b"XREAD"];
    cmd.extend_from_slice(extra);
    let key_b = key.as_bytes();
    cmd.extend_from_slice(&[b"STREAMS", key_b, b"0-0"]);
    let reply = c.request(&cmd).unwrap();
    let streams = reply.as_array().expect("XREAD reply not an array");
    let stream = streams[0].as_array().unwrap();
    stream[1]
        .as_array()
        .unwrap()
        .iter()
        .map(|e| {
            let e = e.as_array().unwrap();
            let fields = e[1].as_array().unwrap();
            StreamRecord::decode(fields[1].as_bytes().unwrap()).unwrap()
        })
        .collect()
}

#[test]
fn trace_survives_wal_replay_migration_and_reduced_view() {
    let wal_root = std::env::temp_dir().join(format!(
        "eb-obs-trace-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&wal_root);
    let cfg = || StoreConfig {
        wal: Some(WalConfig {
            dir: wal_root.join("ep0"),
            fsync: FsyncPolicy::Always, // the crash below is loss-free
            segment_bytes: 1 << 20,
        }),
        ..Default::default()
    };

    let d = 64;
    let (rec, t) = traced_record(7, d);
    let bytes0 = rec.encode();
    // The stamp is CRC-covered wire state: decode round-trips it.
    let dec = StreamRecord::decode(&bytes0).unwrap();
    assert_eq!(dec.meta.as_ref().unwrap().trace, Some(t));

    // --- fenced ingest: the store-side hop histogram ticks once.
    let srv = EndpointServer::start("127.0.0.1:0", cfg()).unwrap();
    srv.store().hello(KEY, 1).unwrap();
    srv.store()
        .xadd_fenced(KEY, 1, 7, false, vec![(b"r".to_vec(), bytes0.clone())])
        .unwrap();
    assert_eq!(srv.store().hop_store_samples(), 1, "ingest hop must tick");

    // --- crash + WAL replay: the stored bytes are identical.
    drop(srv);
    let srv = EndpointServer::start("127.0.0.1:0", cfg()).unwrap();
    let entries = srv.store().read_after(KEY, EntryId::ZERO, 0);
    assert_eq!(entries.len(), 1);
    assert_eq!(
        &entries[0].fields[0].1[..],
        &bytes0[..],
        "WAL replay must reproduce the traced frame byte-for-byte"
    );

    // --- migration: tombstone the old segment, re-ship to a second
    // endpoint under the next epoch; the old epoch is fenced.
    let srv1 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    srv.store().xhandoff(KEY, 2, Some(1)).unwrap();
    assert!(
        srv.store().hello(KEY, 1).is_err(),
        "old epoch must be STALE after handoff"
    );
    srv1.store().hello(KEY, 2).unwrap();
    srv1.store()
        .xadd_fenced(KEY, 2, 7, false, vec![(b"r".to_vec(), bytes0.clone())])
        .unwrap();
    let entries = srv1.store().read_after(KEY, EntryId::ZERO, 0);
    assert_eq!(
        &entries[0].fields[0].1[..],
        &bytes0[..],
        "migrated bytes must be identical"
    );

    // --- server-side reduced view: re-encoded frame, same stamp.
    let mut c = RespConn::connect(srv1.addr(), ConnConfig::default()).unwrap();
    let got = xread_records(&mut c, &[b"STRIDE", b"2"], KEY);
    assert_eq!(got.len(), 1);
    let m = got[0].meta.as_ref().expect("reduced views are staged frames");
    assert_eq!(m.trace, Some(t), "trace must survive server-side reduction");
    assert!(m.provenance.contains("view.stride=2"), "{}", m.provenance);

    // --- reader delivery closes the chain; the hop sequence is
    // monotone and the deliver hop histogram ticked.
    let metrics = WorkflowMetrics::new();
    let mut reader = StreamReader::connect(
        srv1.addr(),
        vec![KEY.to_string()],
        0,
        ConnConfig::default(),
    )
    .unwrap();
    reader.set_trace(metrics.trace.clone());
    let mut delivered = Vec::new();
    for _ in 0..8 {
        for b in reader.poll().unwrap() {
            delivered.extend(b.records);
        }
        if !delivered.is_empty() {
            break;
        }
    }
    assert_eq!(delivered.len(), 1);
    let tr = delivered[0]
        .meta
        .as_ref()
        .unwrap()
        .trace
        .expect("delivered frame keeps its stamp");
    assert!(
        tr.origin_us <= tr.enqueue_us
            && tr.enqueue_us <= tr.flush_us
            && tr.flush_us <= tr.deliver_us
            && tr.deliver_us > 0,
        "hop chain must be monotone: {tr:?}"
    );
    assert_eq!(metrics.trace.hop_deliver_us.count(), 1);

    let _ = std::fs::remove_dir_all(&wal_root);
}

#[test]
fn metrics_command_serves_prometheus_text_including_attached_registry() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let wf = WorkflowMetrics::new();
    wf.trace.staleness_us.record(1234);
    srv.store().set_registry(wf.registry.clone());
    let (rec, _) = traced_record(3, 32);
    srv.store()
        .xadd(KEY, None, vec![(b"r".to_vec(), rec.encode())])
        .unwrap();

    let mut c = RespConn::connect(srv.addr(), ConnConfig::default()).unwrap();
    let reply = c.request(&[b"METRICS"]).unwrap();
    let text = String::from_utf8(reply.as_bytes().unwrap().to_vec()).unwrap();
    // store figures
    assert!(text.contains("# TYPE eb_store_used_bytes gauge"), "{text}");
    assert!(text.contains("eb_store_entries_added 1"), "{text}");
    // serving front-end counters (the connection running this scrape)
    assert!(text.contains("eb_server_connections"), "{text}");
    assert!(text.contains("eb_server_conn_paused_total"), "{text}");
    // ingest hop histogram is always exposed
    assert!(text.contains("eb_endpoint_hop_store_us"), "{text}");
    // the attached workflow registry rides the same exposition
    assert!(text.contains("eb_trace_staleness_us"), "{text}");
}

#[test]
fn wal_rotation_lands_in_the_event_journal() {
    let wal_root = std::env::temp_dir().join(format!(
        "eb-obs-rotate-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&wal_root);
    let srv = EndpointServer::start(
        "127.0.0.1:0",
        StoreConfig {
            wal: Some(WalConfig {
                dir: wal_root.clone(),
                fsync: FsyncPolicy::Never,
                segment_bytes: 4096, // rotate every few records
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let events = Arc::new(EventJournal::new(64));
    srv.store().set_events(events.clone());

    for step in 0..32u64 {
        let data: Vec<f32> = (0..256).map(|i| (i as f32) + step as f32).collect();
        let r = StreamRecord::from_f32("u", 0, step, 0, &[256], &data).unwrap();
        srv.store()
            .xadd(KEY, None, vec![(b"r".to_vec(), r.encode())])
            .unwrap();
    }
    let rotations = events
        .recent(0)
        .iter()
        .filter(|e| e.kind == "wal.rotate")
        .count();
    assert!(
        rotations >= 2,
        "32 KiB through 4 KiB segments must rotate (saw {rotations})"
    );
    let _ = std::fs::remove_dir_all(&wal_root);
}
