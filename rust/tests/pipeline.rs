//! End-to-end exercise of the batched write path (ISSUE 1 tentpole):
//! a 4-rank synthetic simulation ships through pipelined, coalesced
//! XADD batches into two sharded endpoints; every record must land
//! exactly once, and the streaming + windowed-DMD result must match the
//! offline `linalg::dmd` reference on the same window to 1e-6.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elasticbroker::analysis::{AnalysisResult, DmdConfig, DmdEngine};
use elasticbroker::broker::{Broker, BrokerConfig, QueuePolicy};
use elasticbroker::endpoint::{EndpointServer, EntryId, StoreConfig};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::StreamRecord;
use elasticbroker::streamproc::{StreamReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::ConnConfig;

const RANKS: u32 = 4;
const DIM: usize = 32;
const STEPS: u64 = 20;
const WINDOW: usize = 6; // m; the engine windows m+1 = 7 snapshots
const DMD_RANK: usize = 4;

/// Deterministic decaying-oscillation snapshot for (rank, step).
fn snapshot(rank: u32, step: u64) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..DIM)
        .map(|i| {
            let phase = 0.13 * i as f64 + 0.31 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

#[test]
fn batched_pipeline_exactly_once_and_dmd_matches_offline() {
    let e0 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let e1 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: 2, // ranks {0,1} → e0, {2,3} → e1
                queue_cap: 32,
                policy: QueuePolicy::Block,
                batch_max_records: 8,
                linger_ms: 10, // force real coalescing on the fast path
                ..BrokerConfig::new(vec![e0.addr(), e1.addr()])
            },
            RANKS as usize,
            metrics.clone(),
        )
        .unwrap(),
    );

    // --- HPC side: 4 synthetic rank threads through the batched broker.
    let writers: Vec<_> = (0..RANKS)
        .map(|rank| {
            let broker = broker.clone();
            std::thread::spawn(move || {
                let ctx = broker.init("synth", rank).unwrap();
                for step in 0..STEPS {
                    ctx.write(step, &[DIM as u32], &snapshot(rank, step)).unwrap();
                }
                ctx.finalize().unwrap();
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    assert_eq!(metrics.dropped.get(), 0, "Block policy must be lossless");
    assert_eq!(metrics.shipped.records(), (RANKS as u64) * STEPS);
    // the writers actually coalesced (the whole point of the tentpole)
    assert!(
        metrics.batch_records.count() < (RANKS as u64) * STEPS,
        "no batching: {} flushes for {} records",
        metrics.batch_records.count(),
        (RANKS as u64) * STEPS
    );

    // --- Exactly once, across shards: each endpoint holds exactly its
    // group's streams, each stream holds steps 0..STEPS in order.
    for (endpoint, ranks) in [(&e0, [0u32, 1]), (&e1, [2u32, 3])] {
        let store = endpoint.store();
        let mut keys = store.keys("*");
        keys.sort();
        let mut want: Vec<String> = ranks.iter().map(|r| format!("synth/{r}")).collect();
        want.sort();
        assert_eq!(keys, want);
        assert!(store.shard_count() > 1);
        for r in ranks {
            let key = format!("synth/{r}");
            assert_eq!(store.xlen(&key), STEPS as usize, "{key}");
            let entries = store.read_after(&key, EntryId::ZERO, 0);
            let steps: Vec<u64> = entries
                .iter()
                .map(|e| StreamRecord::decode(&e.fields[0].1).unwrap().step)
                .collect();
            assert_eq!(steps, (0..STEPS).collect::<Vec<_>>(), "{key}");
            // ids strictly increasing (the atomic per-shard allocator)
            for w in entries.windows(2) {
                assert!(w[1].id > w[0].id, "{key}: id order broken");
            }
        }
        assert_eq!(store.total_entries_added(), 2 * STEPS);
    }

    // --- Cloud side: streaming micro-batches + windowed DMD.
    let engine = Arc::new(
        DmdEngine::new(
            DmdConfig {
                window: WINDOW,
                rank: DMD_RANK,
                hop: 1,
                backend: elasticbroker::analysis::DmdBackend::Rust,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap(),
    );
    let readers = vec![
        StreamReader::connect(
            e0.addr(),
            vec!["synth/0".into(), "synth/1".into()],
            0,
            ConnConfig::default(),
        )
        .unwrap(),
        StreamReader::connect(
            e1.addr(),
            vec!["synth/2".into(), "synth/3".into()],
            0,
            ConnConfig::default(),
        )
        .unwrap(),
    ];
    let (tx, rx) = std::sync::mpsc::channel();
    let eng = engine.clone();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 4,
            batch_limit: 0,
        },
        readers,
        move |b| eng.process(b),
        tx,
    );

    // 20 snapshots, window 7 → 14 analyses per rank.
    let per_rank = STEPS as usize - WINDOW;
    let expect = per_rank * RANKS as usize;
    let mut results: Vec<AnalysisResult> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(15);
    while results.len() < expect && Instant::now() < deadline {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(100)) {
            results.push(res);
        }
    }
    ctx.stop().unwrap();
    results.extend(rx.try_iter().map(|(_, r)| r));
    assert_eq!(results.len(), expect, "analysis count");

    // --- Offline reference: for every rank, rebuild the final window
    // from what actually landed in the store and run the offline DMD;
    // the streamed result for the same window must agree to 1e-6.
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let streamed = results
            .iter()
            .filter(|r| r.key == key)
            .max_by_key(|r| r.step)
            .unwrap_or_else(|| panic!("no results for {key}"));
        assert_eq!(streamed.step, STEPS - 1);
        assert_eq!(streamed.rank, rank);
        assert_eq!(streamed.backend, "rust");

        let endpoint = if rank < 2 { &e0 } else { &e1 };
        let entries = endpoint.store().read_after(&key, EntryId::ZERO, 0);
        let m1 = WINDOW + 1;
        let window: Vec<Vec<f32>> = entries[entries.len() - m1..]
            .iter()
            .map(|e| {
                StreamRecord::decode(&e.fields[0].1)
                    .unwrap()
                    .payload_f32()
                    .unwrap()
            })
            .collect();
        // column j = snapshot j, exactly like the engine assembles it
        let mut x = vec![0.0f64; DIM * m1];
        for (j, snap) in window.iter().enumerate() {
            for i in 0..DIM {
                x[i * m1 + j] = snap[i] as f64;
            }
        }
        let xm = Mat::from_slice(DIM, m1, &x).unwrap();
        let (eigs, sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();

        assert!(
            (streamed.stability - stability).abs() <= 1e-6,
            "{key}: stability {} vs offline {}",
            streamed.stability,
            stability
        );
        assert_eq!(streamed.eigs.len(), eigs.len());
        for (a, b) in streamed.eigs.iter().zip(&eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-6 && (a.im - b.im).abs() <= 1e-6,
                "{key}: eig {a:?} vs offline {b:?}"
            );
        }
        assert_eq!(streamed.sigma.len(), sigma.len());
        for (a, b) in streamed.sigma.iter().zip(&sigma) {
            assert!((a - b).abs() <= 1e-6, "{key}: sigma {a} vs offline {b}");
        }
    }
}
