//! ISSUE 10 acceptance: chain-replicated endpoint streams survive
//! whole-machine loss.
//!
//! The tentpole test runs the full 4-rank pipeline (broker → chain of
//! sim endpoints → elastic reader → windowed DMD) with replication
//! factor 2, machine-kills the head of one chain **mid-batch with its
//! WAL directory destroyed**, promotes the chain successor via a
//! topology epoch bump, and then proves the failover was invisible:
//! the union of surviving segments is gap-free exactly-once, the
//! promoted head alone serves the entire history, and the streamed DMD
//! matches the offline `linalg::dmd` oracle to 1e-6 — i.e. losing a
//! machine is indistinguishable from never having lost one.
//!
//! Satellites covered here:
//! * `prop_replicated_exactly_once` — 64 seeded event scripts (kills
//!   of heads / mid-chain members / tails, concurrent rebalancer
//!   sweeps, adapt-style payload-shape changes, transient frame
//!   faults) asserting per-segment exactly-once, in-step-order
//!   delivery, and that no acked record is ever lost;
//! * fencing-edge regressions — a zombie old head is `STALE`-fenced
//!   *through the chain*, re-shipped unacked frames dedupe as `DUP`
//!   chain-wide, and a WAL-backed replica rejoins at the right
//!   watermark after a restart;
//! * failover transparency of the observability plane — traced hop
//!   stamps and consumer-group cursors survive promotion
//!   byte-identically, and the staleness histograms attribute the
//!   failover stall to the delivery hop.

use std::collections::BTreeSet;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use elasticbroker::analysis::{AnalysisResult, DmdConfig, DmdEngine};
use elasticbroker::broker::{
    rebalancer, Broker, BrokerConfig, BrokerCtx, EndpointSample, GroupMap,
    QosThresholds, QueuePolicy, Shipper, TopologyHandle,
};
use elasticbroker::endpoint::{
    EntryId, FsyncPolicy, ReplAck, Store, StoreConfig, WalConfig,
};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::{
    CodecKind, Encoding, FrameMeta, StreamRecord, Trace,
};
use elasticbroker::streamproc::{ElasticReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::sim::{FaultSchedule, SimDialer, SimNet};
use elasticbroker::transport::{Conn, Dialer, Request};
use elasticbroker::util::prop::{self, U64Range};
use elasticbroker::util::rng::Rng;
use elasticbroker::wire::Value;

const RANKS: u32 = 4;
const DIM: usize = 32;
const STEPS: u64 = 20;
const WINDOW: usize = 6; // m; the engine windows m+1 = 7 snapshots
const DMD_RANK: usize = 4;
const FIELD: &str = "synth";

fn dummy_addr() -> std::net::SocketAddr {
    "127.0.0.1:1".parse().unwrap()
}

/// Deterministic decaying-oscillation snapshot for (rank, step) — the
/// same pure function `tests/elastic.rs` uses, so the offline oracle
/// below reconstructs the exact window the streamed engine analysed.
fn snapshot(rank: u32, step: u64) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..DIM)
        .map(|i| {
            let phase = 0.17 * i as f64 + 0.29 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

/// Write one phase of steps on every rank, then wait for the writers'
/// queues to drain so the scripted machine loss lands between phases.
fn write_phase(ctxs: &[BrokerCtx], lo: u64, hi: u64) {
    for step in lo..hi {
        for (r, ctx) in ctxs.iter().enumerate() {
            ctx.write(step, &[DIM as u32], &snapshot(r as u32, step)).unwrap();
        }
    }
    let deadline = Instant::now() + Duration::from_secs(20);
    while ctxs.iter().any(|c| c.backlog() > 0) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        ctxs.iter().all(|c| c.backlog() == 0),
        "writer backlog did not drain"
    );
}

/// All record steps of `key` in `store`, tombstones excluded; asserts
/// the segment is strictly step-increasing (per-segment exactly-once).
fn segment_steps(store: &Store, key: &str) -> Vec<u64> {
    let mut steps = Vec::new();
    for e in store.read_after(key, EntryId::ZERO, 0) {
        if e.fields[0].0 == b"h" {
            continue;
        }
        let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
        if let Some(&prev) = steps.last() {
            assert!(rec.step > prev, "{key}: segment not strictly increasing");
        }
        steps.push(rec.step);
    }
    steps
}

/// Record entries of `key` as (id, stored bytes), tombstones excluded —
/// the byte-identity unit of the chain invariant.
fn record_bytes(store: &Store, key: &str) -> Vec<(EntryId, Vec<u8>)> {
    store
        .read_after(key, EntryId::ZERO, 0)
        .into_iter()
        .filter(|e| e.fields[0].0 != b"h")
        .map(|e| (e.id, e.fields[0].1.to_vec()))
        .collect()
}

fn hello(key: &str, epoch: u64) -> Request {
    Request::new("HELLO").arg(key).arg(epoch.to_string())
}

fn xaddf(key: &str, epoch: u64, step: u64, payload: impl Into<Vec<u8>>) -> Request {
    Request::new("XADDF")
        .arg(key)
        .arg(epoch.to_string())
        .arg(step.to_string())
        .arg("r")
        .arg(payload.into())
}

fn err_text(v: &Value) -> String {
    match v {
        Value::Error(m) => m.clone(),
        other => panic!("expected an error reply, got {other:?}"),
    }
}

/// The ISSUE 10 acceptance run.  Three WAL-backed endpoints, two
/// groups, chains `g0: [0,1]`, `g1: [1,2]`.  Endpoint 0 — the head of
/// g0's chain — loses its whole machine mid-batch (WAL directory
/// destroyed); the scripted `on_drop` hook performs the failover the
/// control plane would (drain → chain repair → successor re-wire) at
/// the exact break point.
#[test]
fn machine_loss_failover_is_exactly_once_and_matches_offline_dmd() {
    let dirs: Vec<std::path::PathBuf> = (0..3)
        .map(|i| {
            std::env::temp_dir()
                .join(format!("eb-repl-accept-{}-{i}", std::process::id()))
        })
        .collect();
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
    let net = SimNet::new();
    for d in &dirs {
        net.add_endpoint(StoreConfig {
            wal: Some(WalConfig {
                dir: d.clone(),
                fsync: FsyncPolicy::Always,
                segment_bytes: 1 << 20,
            }),
            ..Default::default()
        });
    }
    let metrics = WorkflowMetrics::new();

    // group_size 2 → two groups over three endpoints, factor 2.
    let groups = GroupMap::new(RANKS as usize, 2, 3).unwrap();
    let topology = TopologyHandle::new_replicated(
        groups.clone(),
        vec![dummy_addr(); 3],
        &[],
        2,
    )
    .unwrap();
    let keys: Vec<String> = (0..RANKS).map(|r| format!("{FIELD}/{r}")).collect();
    {
        let t = topology.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[0, 1]);
        assert_eq!(t.replica_chain(1).unwrap(), &[1, 2]);
    }
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail).unwrap();

    let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
    let broker = Arc::new(
        Broker::with_topology(
            BrokerConfig {
                group_size: 2,
                queue_cap: 32,
                policy: QueuePolicy::Block,
                batch_max_records: 4,
                trace_sample: 4, // every 4th write carries hop stamps
                ..BrokerConfig::new(vec![dummy_addr()])
            },
            topology.clone(),
            dialer.clone(),
            metrics.clone(),
        )
        .unwrap(),
    );

    let engine = Arc::new(
        DmdEngine::new(
            DmdConfig {
                window: WINDOW,
                rank: DMD_RANK,
                hop: 1,
                backend: elasticbroker::analysis::DmdBackend::Rust,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap(),
    );
    let mut reader =
        ElasticReader::new(topology.clone(), dialer.clone(), keys.clone(), 0).unwrap();
    reader.set_trace(metrics.trace.clone());
    reader.set_auto_ack(true); // consumer cursors gossip down the chain
    let (tx, rx) = channel();
    let eng = engine.clone();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 4,
            batch_limit: 0,
        },
        vec![reader],
        move |b| eng.process(b),
        tx,
    );

    let ctxs: Vec<BrokerCtx> =
        (0..RANKS).map(|r| broker.init(FIELD, r).unwrap()).collect();
    write_phase(&ctxs, 0, 7);

    // Script the machine loss: the second frame endpoint 0 serves after
    // this point breaks with one command applied, the machine dies (WAL
    // destroyed), and — at the exact break — the failover runs: drain
    // the dead head (epoch bump promotes its chain successor), repair
    // the now-short chain, re-wire the successor links.
    let (ft, fnet, fkeys) = (topology.clone(), net.clone(), keys.clone());
    net.inject(
        0,
        FaultSchedule {
            drop_after_frames: Some(1),
            partial_commands: 1,
            kill_machine_on_drop: true,
            on_drop: Some(Box::new(move || {
                ft.drain_endpoint(0).unwrap();
                ft.repair_chains().unwrap();
                fnet.apply_replication(&ft.snapshot(), &fkeys, ReplAck::Tail)
                    .unwrap();
            })),
            ..Default::default()
        },
    );

    write_phase(&ctxs, 7, 14);
    write_phase(&ctxs, 14, STEPS);
    for c in ctxs {
        c.finalize().unwrap();
    }

    // --- Failover happened: epoch bumped twice (drain + repair), the
    // chain successor is the new head, and the repaired chain excludes
    // the dead machine.
    let t = topology.snapshot();
    t.validate().unwrap();
    assert_eq!(t.epoch, 3, "drain (2) + chain repair (3)");
    assert!(!t.endpoints[0].live, "dead machine must be drained");
    assert_eq!(t.endpoint_of_group(0).unwrap(), 1, "successor promoted");
    assert_eq!(t.replica_chain(0).unwrap(), &[1, 2], "chain repaired");
    assert_eq!(t.replica_chain(1).unwrap(), &[1, 2]);

    // --- Exactly-once across the machine loss.  `shipped` may exceed
    // the write count: re-shipped frames that dedupe as DUP still ack.
    assert_eq!(metrics.dropped.get(), 0);
    assert!(metrics.shipped.records() >= (RANKS as u64) * STEPS);
    assert!(
        net.store(0).read_after(&keys[0], EntryId::ZERO, 0).is_empty(),
        "the killed machine's WAL is destroyed — nothing survives there"
    );
    for r in 0..RANKS {
        let key = &keys[r as usize];
        let s1 = segment_steps(&net.store(1), key);
        let s2 = segment_steps(&net.store(2), key);
        let mut union: Vec<u64> = s1.iter().chain(s2.iter()).copied().collect();
        union.sort_unstable();
        union.dedup();
        assert_eq!(
            union,
            (0..STEPS).collect::<Vec<_>>(),
            "{key}: union of surviving segments must be gap-free \
             (e1: {s1:?}, e2: {s2:?})"
        );
        // The failover guarantee: the promoted head *alone* serves the
        // entire history — pre-kill records arrived by forwarding,
        // post-kill records landed directly.
        let head = t.endpoint_of_group(t.groups.group_of_rank(r as usize).unwrap())
            .unwrap();
        assert_eq!(
            segment_steps(&net.store(head), key),
            (0..STEPS).collect::<Vec<_>>(),
            "{key}: promoted head must hold every step"
        );
    }

    // --- Chain byte-identity.  g1's chain [1,2] was never disturbed:
    // every record (trace stamps included — they ride the stored
    // payload) must be byte-identical on head and tail.  g0's tail
    // joined at repair time, so its records are a byte-identical
    // subset of the head's.
    for r in 0..RANKS {
        let key = &keys[r as usize];
        let on_head = record_bytes(&net.store(1), key);
        let on_tail = record_bytes(&net.store(2), key);
        let g = t.groups.group_of_rank(r as usize).unwrap();
        if g == 1 {
            assert_eq!(on_head, on_tail, "{key}: undisturbed chain must mirror");
        } else {
            let head_set: BTreeSet<_> = on_head.iter().collect();
            assert!(!on_tail.is_empty(), "{key}: repaired tail got new writes");
            for entry in &on_tail {
                assert!(
                    head_set.contains(entry),
                    "{key}: tail entry {:?} diverges from the head",
                    entry.0
                );
            }
        }
    }

    // --- The analysis saw every window fire, no gaps, no dupes.
    let per_rank = STEPS as usize - WINDOW;
    let expect = per_rank * RANKS as usize;
    let mut results: Vec<AnalysisResult> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(25);
    while results.len() < expect && Instant::now() < deadline {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(100)) {
            results.push(res);
        }
    }
    ctx.stop().unwrap();
    results.extend(rx.try_iter().map(|(_, r)| r));
    assert_eq!(results.len(), expect, "analysis count");
    for r in 0..RANKS {
        let key = &keys[r as usize];
        let mut steps: Vec<u64> = results
            .iter()
            .filter(|a| &a.key == key)
            .map(|a| a.step)
            .collect();
        steps.sort_unstable();
        assert_eq!(
            steps,
            (WINDOW as u64..STEPS).collect::<Vec<_>>(),
            "{key}: fire steps have gaps — records were lost or reordered"
        );
    }

    // --- Consumer cursors survived the failover byte-identically: the
    // reader acked the promoted head, which gossiped every cursor to
    // its chain tail.
    for key in &keys {
        let on_head = net.store(1).acked(key);
        assert!(on_head > EntryId::ZERO, "{key}: reader acked the new head");
        assert_eq!(
            on_head,
            net.store(2).acked(key),
            "{key}: cursor must be byte-identical down the chain"
        );
    }

    // --- Staleness trace: the sampled records crossed every hop, so
    // the per-hop histograms can attribute the failover stall (records
    // written just before the kill were only *delivered* after the
    // reader followed the promotion — that wait lands in the delivery
    // hop, not in queue/ack time).
    assert!(metrics.trace.sampled.get() >= 16, "1-in-4 of 80 writes");
    assert!(metrics.trace.hop_queue_us.count() > 0);
    assert!(metrics.trace.hop_ack_us.count() > 0);
    assert!(metrics.trace.hop_deliver_us.count() > 0);
    assert!(metrics.trace.hop_analysis_us.count() > 0);
    assert!(metrics.trace.staleness_us.count() > 0);

    // --- Oracle: the final window's DMD must match the offline
    // reference to 1e-6 — the machine loss is analytically invisible.
    for rank in 0..RANKS {
        let key = &keys[rank as usize];
        let streamed = results
            .iter()
            .filter(|a| &a.key == key)
            .max_by_key(|a| a.step)
            .unwrap();
        assert_eq!(streamed.step, STEPS - 1);
        assert_eq!(streamed.backend, "rust");

        let m1 = WINDOW + 1;
        let mut x = vec![0.0f64; DIM * m1];
        for (j, step) in (STEPS - m1 as u64..STEPS).enumerate() {
            let snap = snapshot(rank, step);
            for i in 0..DIM {
                x[i * m1 + j] = snap[i] as f64;
            }
        }
        let xm = Mat::from_slice(DIM, m1, &x).unwrap();
        let (eigs, sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();

        assert!(
            (streamed.stability - stability).abs() <= 1e-6,
            "{key}: stability {} vs offline {}",
            streamed.stability,
            stability
        );
        assert_eq!(streamed.eigs.len(), eigs.len());
        for (a, b) in streamed.eigs.iter().zip(&eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-6 && (a.im - b.im).abs() <= 1e-6,
                "{key}: eig {a:?} vs offline {b:?}"
            );
        }
        for (a, b) in streamed.sigma.iter().zip(&sigma) {
            assert!((a - b).abs() <= 1e-6, "{key}: sigma {a} vs offline {b}");
        }
    }
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
}

/// ISSUE 10 satellite: 64 seeded event scripts over replicated
/// topologies — machine kills of chain heads, mid-chain members and
/// tails, concurrent rebalancer sweeps, adapt-style payload-shape
/// changes, scale-outs and transient mid-frame faults.  Invariants:
///
/// 1. the topology stays valid and its epoch monotonic after every
///    event;
/// 2. every per-endpoint segment is strictly step-increasing
///    (exactly-once, in step order, per segment);
/// 3. no acked record is ever lost: the union of all surviving
///    segments is exactly the written step set, even though every
///    kill destroys a store outright.
///
/// Kills are restricted to endpoints whose every chain still has a
/// *full-history* survivor — a member present in that chain
/// continuously since the first write.  (Chain repair does not
/// backfill history; a member added mid-run only holds the suffix, so
/// killing the last continuous member would lose the prefix by
/// design.  The tracked `holders` sets encode exactly that rule.)
#[test]
fn prop_replicated_exactly_once() {
    prop::forall(0x10C4A1, 64, &U64Range(0, u64::MAX - 1), |seed| {
        run_replicated_case(*seed).map_err(|e| format!("{e:#}"))
    });
}

fn run_replicated_case(seed: u64) -> Result<()> {
    let mut rng = Rng::new(seed);
    let ranks = 1 + rng.next_below(5) as usize;
    let gsize = 1 + rng.next_below(2) as usize;
    let n_eps = 2 + rng.next_below(3) as usize; // 2..=4
    let factor = (2 + rng.next_below(2) as usize).min(n_eps); // 2..=3
    let n_groups = ranks.div_ceil(gsize);

    let net = SimNet::new();
    for _ in 0..n_eps {
        net.add_endpoint(StoreConfig::default());
    }
    let groups = GroupMap::new(ranks, gsize, n_eps)?;
    let topology = TopologyHandle::new_replicated(
        groups.clone(),
        vec![dummy_addr(); n_eps],
        &[],
        factor,
    )?;
    let keys: Vec<String> =
        (0..ranks).map(|r| elasticbroker::record::stream_key("u", r as u32)).collect();
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail)?;

    let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
    let metrics = WorkflowMetrics::new();
    let mut shippers: Vec<Shipper> = Vec::with_capacity(ranks);
    for r in 0..ranks {
        shippers.push(Shipper::register(
            keys[r].clone(),
            groups.group_of_rank(r)?,
            topology.clone(),
            dialer.clone(),
            metrics.clone(),
            8,
        )?);
    }

    // holders[g]: members of g's chain continuously since step 0 — the
    // only endpoints guaranteed to hold g's *entire* history (chain
    // repair forwards new writes but never backfills old ones).
    let mut holders: Vec<BTreeSet<usize>> = Vec::with_capacity(n_groups);
    let mut ever: Vec<BTreeSet<usize>> = Vec::with_capacity(n_groups);
    {
        let topo = topology.snapshot();
        for g in 0..n_groups {
            let chain: BTreeSet<usize> =
                topo.replica_chain(g)?.iter().copied().collect();
            holders.push(chain.clone());
            ever.push(chain);
        }
    }
    // Intersect holders with the current chains after every topology
    // mutation; `ever` accumulates everything that was ever a member.
    let refresh = |topology: &TopologyHandle,
                   holders: &mut [BTreeSet<usize>],
                   ever: &mut [BTreeSet<usize>]|
     -> Result<()> {
        let topo = topology.snapshot();
        for g in 0..holders.len() {
            let chain: BTreeSet<usize> =
                topo.replica_chain(g)?.iter().copied().collect();
            holders[g].retain(|m| chain.contains(m));
            ever[g].extend(chain);
        }
        Ok(())
    };

    let mut next_step = vec![0u64; ranks];
    // Adapt-style payload levels: the ladder shrinks the payload shape
    // mid-stream; replication must be shape-agnostic.
    let mut levels = vec![0usize; ranks];
    let mut last_epoch = topology.epoch();

    let n_events = 6 + rng.next_below(12);
    for _ in 0..n_events {
        match rng.next_below(10) {
            // write bursts dominate
            0..=4 => {
                for r in 0..ranks {
                    let k = 1 + rng.next_below(4);
                    let len = 4 >> levels[r].min(2); // 4, 2 or 1 floats
                    let records: Vec<StreamRecord> = (next_step[r]
                        ..next_step[r] + k)
                        .map(|s| {
                            StreamRecord::from_f32(
                                "u",
                                r as u32,
                                s,
                                0,
                                &[len as u32],
                                &vec![s as f32; len],
                            )
                        })
                        .collect::<Result<_>>()?;
                    shippers[r].ship(&records)?;
                    next_step[r] += k;
                }
            }
            // adapt level change on a random stream
            5 => {
                let r = rng.next_below(ranks as u64) as usize;
                levels[r] = rng.next_below(3) as usize;
            }
            // whole-machine loss + failover (drain → repair → re-wire)
            6 => {
                let topo = topology.snapshot();
                let live = topo.live_endpoints();
                if live.len() < 2 {
                    continue;
                }
                let v = live[rng.next_below(live.len() as u64) as usize];
                let safe = (0..n_groups).all(|g| {
                    !ever[g].contains(&v)
                        || holders[g].iter().any(|&m| m != v)
                });
                if !safe {
                    continue;
                }
                net.kill_machine(v);
                topology.drain_endpoint(v)?;
                topology.repair_chains()?;
                net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail)?;
                refresh(&topology, &mut holders, &mut ever)?;
            }
            // scale-out + chain repair onto the new machine
            7 => {
                if net.len() < 5 {
                    let idx = net.add_endpoint(StoreConfig::default());
                    let (slot, _) = topology.scale_out(dummy_addr())?;
                    anyhow::ensure!(slot == idx, "net/topology slot skew");
                    topology.repair_chains()?;
                    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail)?;
                    refresh(&topology, &mut holders, &mut ever)?;
                }
            }
            // transient mid-frame fault (drops also hit forward links,
            // exercising the REPL retry + chain-wide DUP dedupe path)
            8 => {
                let e = rng.next_below(net.len() as u64) as usize;
                net.inject(
                    e,
                    FaultSchedule {
                        drop_after_frames: Some(rng.next_below(2)),
                        partial_commands: rng.next_below(3) as usize,
                        refuse_connects: rng.next_below(2) as u32,
                        ..Default::default()
                    },
                );
            }
            // rebalancer sweep with a synthetically pressured endpoint:
            // sheds must stay chain-safe, apply() repairs short chains
            _ => {
                let topo = topology.snapshot();
                let slow = rng.next_below(topo.endpoints.len() as u64) as usize;
                let mut samples =
                    vec![EndpointSample::default(); topo.endpoints.len()];
                samples[slow].flush_p95_us = u64::MAX / 2;
                let plan =
                    rebalancer::evaluate(&topo, &samples, &QosThresholds::default());
                rebalancer::apply(&plan, &topology)?;
                net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail)?;
                refresh(&topology, &mut holders, &mut ever)?;
            }
        }
        // Invariant 1: valid replicated assignment, monotonic epoch.
        let topo = topology.snapshot();
        topo.validate()?;
        anyhow::ensure!(topo.epoch >= last_epoch, "epoch went backwards");
        last_epoch = topo.epoch;
    }

    // Invariants 2 + 3: replay every stream across all endpoints.
    for r in 0..ranks {
        let key = &keys[r];
        let mut union: BTreeSet<u64> = BTreeSet::new();
        for e in 0..net.len() {
            let mut prev: Option<u64> = None;
            for entry in net.store(e).read_after(key, EntryId::ZERO, 0) {
                if entry.fields[0].0 == b"h" {
                    continue;
                }
                let rec = StreamRecord::decode(&entry.fields[0].1)?;
                if let Some(p) = prev {
                    anyhow::ensure!(
                        rec.step > p,
                        "{key}: endpoint {e} segment not strictly increasing \
                         ({} after {p})",
                        rec.step
                    );
                }
                prev = Some(rec.step);
                union.insert(rec.step);
            }
        }
        let want: BTreeSet<u64> = (0..next_step[r]).collect();
        anyhow::ensure!(
            union == want,
            "{key}: acked records lost across machine kills — \
             {} of {} steps recovered",
            union.len(),
            want.len()
        );
    }
    Ok(())
}

/// Fencing edge: after a failover, the *old* head is a zombie — its
/// local fence still accepts the stale epoch, but its chain forward
/// hits the promoted successor's raised fence and the `STALE` bounces
/// back through the chain to the writer.  Without the forwarded fence
/// the zombie would keep acking writes nobody will ever read.
#[test]
fn zombie_old_head_is_fenced_stale_through_the_chain() {
    let net = SimNet::new();
    net.add_endpoint(StoreConfig::default());
    net.add_endpoint(StoreConfig::default());
    let topology = TopologyHandle::new_replicated(
        GroupMap::new(1, 1, 2).unwrap(),
        vec![dummy_addr(); 2],
        &[],
        2,
    )
    .unwrap();
    let keys = vec!["u/0".to_string()];
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail).unwrap();

    let dialer = SimDialer::new(net.clone());
    let mut old_head = dialer.dial(0).unwrap();
    let replies = old_head
        .exchange(&[hello("u/0", 1), xaddf("u/0", 1, 0, "a"), xaddf("u/0", 1, 1, "b")])
        .unwrap();
    assert!(replies.iter().all(|r| !r.is_error()), "{replies:?}");
    assert_eq!(net.store(1).fenced_last_step("u/0"), Some(1), "chain mirrored");

    // Failover: the successor is promoted and a new writer registers
    // there at epoch 2 (what the shipper does after the topology bump).
    let mut new_head = dialer.dial(1).unwrap();
    let replies = new_head.exchange(&[hello("u/0", 2)]).unwrap();
    assert!(!replies[0].is_error(), "{:?}", replies[0]);
    assert_eq!(net.store(1).stream_epoch("u/0"), 2);

    // The zombie writes on: its own fence still says epoch 1, so the
    // record lands locally — but the forward is rejected STALE by the
    // promoted successor and the error propagates back verbatim.
    let replies = old_head.exchange(&[xaddf("u/0", 1, 2, "c")]).unwrap();
    let msg = err_text(&replies[0]);
    assert!(msg.starts_with("STALE"), "zombie write must bounce: {msg}");
    assert_eq!(
        net.store(1).fenced_last_step("u/0"),
        Some(1),
        "the zombie's unreplicated orphan never reaches the new chain"
    );

    // Even re-registration at the stale epoch is refused through the
    // chain — the zombie cannot rejoin without a topology refresh.
    let replies = old_head.exchange(&[hello("u/0", 1)]).unwrap();
    let msg = err_text(&replies[0]);
    assert!(msg.starts_with("STALE"), "stale re-HELLO must bounce: {msg}");
}

/// Fencing edge: a frame that broke after the head applied (and
/// forwarded) a prefix is re-shipped whole; the head answers `DUP` for
/// the landed prefix and the forward keeps the chain converged — no
/// record is double-stored anywhere, and every chain copy keeps the
/// byte-identical id the head assigned on first landing.
#[test]
fn reshipped_unacked_frame_dedupes_chain_wide() {
    let net = SimNet::new();
    net.add_endpoint(StoreConfig::default());
    net.add_endpoint(StoreConfig::default());
    let topology = TopologyHandle::new_replicated(
        GroupMap::new(1, 1, 2).unwrap(),
        vec![dummy_addr(); 2],
        &[],
        2,
    )
    .unwrap();
    let keys = vec!["u/0".to_string()];
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail).unwrap();

    let dialer = SimDialer::new(net.clone());
    let mut conn = dialer.dial(0).unwrap();
    let replies = conn
        .exchange(&[hello("u/0", 1), xaddf("u/0", 1, 0, "a")])
        .unwrap();
    assert!(replies.iter().all(|r| !r.is_error()), "{replies:?}");

    // The next frame breaks after its first command fully executed —
    // stored on the head AND forwarded down the chain — but the writer
    // saw no reply for any of it.
    net.inject(
        0,
        FaultSchedule {
            drop_after_frames: Some(0),
            partial_commands: 1,
            ..Default::default()
        },
    );
    let err = conn
        .exchange(&[xaddf("u/0", 1, 1, "b"), xaddf("u/0", 1, 2, "c")])
        .unwrap_err();
    assert!(err.to_string().contains("dropped"), "{err}");
    assert_eq!(net.store(0).fenced_last_step("u/0"), Some(1));
    assert_eq!(net.store(1).fenced_last_step("u/0"), Some(1), "prefix forwarded");

    // Re-ship the whole frame: DUP for the landed record, fresh land
    // for the rest — on every chain member.
    conn.reconnect().unwrap();
    let replies = conn
        .exchange(&[xaddf("u/0", 1, 1, "b"), xaddf("u/0", 1, 2, "c")])
        .unwrap();
    assert_eq!(replies[0], Value::Simple("DUP".into()));
    assert!(!replies[1].is_error(), "{:?}", replies[1]);

    let head = record_bytes(&net.store(0), "u/0");
    let tail = record_bytes(&net.store(1), "u/0");
    assert_eq!(head.len(), 3, "no double-store on the head");
    assert_eq!(head, tail, "chain copies must stay byte-identical");
    assert_eq!(segment_steps(&net.store(0), "u/0"), vec![0, 1, 2]);
}

/// Fencing edge: a WAL-backed replica that crashes and restarts
/// replays its log and rejoins the chain at the exact watermark it had
/// acknowledged — the head's REPL-bounced retry then heals the gap the
/// outage left, and the chain converges again.
#[test]
fn replica_wal_restart_rejoins_at_the_right_watermark() {
    let dir = std::env::temp_dir()
        .join(format!("eb-repl-replica-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let net = SimNet::new();
    net.add_endpoint(StoreConfig::default()); // head: in-memory
    net.add_endpoint(StoreConfig {
        wal: Some(WalConfig {
            dir: dir.clone(),
            fsync: FsyncPolicy::Always,
            segment_bytes: 1 << 20,
        }),
        ..Default::default()
    });
    let topology = TopologyHandle::new_replicated(
        GroupMap::new(1, 1, 2).unwrap(),
        vec![dummy_addr(); 2],
        &[],
        2,
    )
    .unwrap();
    let keys = vec!["u/0".to_string()];
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail).unwrap();

    let dialer = SimDialer::new(net.clone());
    let mut conn = dialer.dial(0).unwrap();
    let replies = conn
        .exchange(&[
            hello("u/0", 1),
            xaddf("u/0", 1, 0, "a"),
            xaddf("u/0", 1, 1, "b"),
            xaddf("u/0", 1, 2, "c"),
        ])
        .unwrap();
    assert!(replies.iter().all(|r| !r.is_error()), "{replies:?}");

    // The replica's process dies.  Under tail-ack the head must now
    // bounce writes with REPL — stored locally, not yet durable
    // chain-wide — instead of acking into a one-copy window.
    net.kill(1);
    let replies = conn.exchange(&[xaddf("u/0", 1, 3, "d")]).unwrap();
    let msg = err_text(&replies[0]);
    assert!(msg.starts_with("REPL"), "unreachable successor: {msg}");

    // Restart: the WAL replays entries, the epoch fence and the step
    // high-water mark — the replica rejoins exactly where it acked.
    net.restart(1);
    let replica = net.store(1);
    assert_eq!(replica.fenced_last_step("u/0"), Some(2), "watermark replayed");
    assert_eq!(replica.stream_epoch("u/0"), 1, "fence replayed");
    assert!(replica.replayed_entries() >= 3);

    // The writer's retry heals the chain: the head dedupes (DUP) and
    // re-forwards, the recovered replica accepts the record it missed.
    let replies = conn.exchange(&[xaddf("u/0", 1, 3, "d")]).unwrap();
    assert_eq!(replies[0], Value::Simple("DUP".into()), "head already has it");
    assert_eq!(segment_steps(&net.store(1), "u/0"), vec![0, 1, 2, 3]);
    assert_eq!(net.store(1).fenced_last_step("u/0"), Some(3));

    // Steady state resumes — and the *whole* history is byte-identical
    // chain-wide: the head's DUP re-forward stamped the id it assigned
    // the healed record, so the recovered replica never invented its
    // own (a divergent id would also poison every later explicit-ID
    // forward via the duplicate check).
    let replies = conn.exchange(&[xaddf("u/0", 1, 4, "e")]).unwrap();
    assert!(!replies[0].is_error(), "{:?}", replies[0]);
    let head = record_bytes(&net.store(0), "u/0");
    let tail = record_bytes(&net.store(1), "u/0");
    assert_eq!(head.len(), 5);
    assert_eq!(head, tail, "post-heal copies byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 10 satellite: the observability plane survives failover.  A
/// record carrying sampled staleness-trace hop stamps and the
/// consumer-group cursors acked against the head must both be
/// byte-identical on the promoted successor after the head's machine
/// is lost — dashboards and subscriber fleets resume exactly where
/// they were.
#[test]
fn cursors_and_trace_stamps_survive_failover_byte_identically() {
    let net = SimNet::new();
    net.add_endpoint(StoreConfig::default());
    net.add_endpoint(StoreConfig::default());
    let topology = TopologyHandle::new_replicated(
        GroupMap::new(1, 1, 2).unwrap(),
        vec![dummy_addr(); 2],
        &[],
        2,
    )
    .unwrap();
    let keys = vec!["u/0".to_string()];
    net.apply_replication(&topology.snapshot(), &keys, ReplAck::Tail).unwrap();

    // A traced record, built the way the broker's 1-in-N sampler does:
    // a minimal lossless EBR2 header carrying the hop stamps
    // (deliver_us stays 0 in stored bytes — readers stamp in memory).
    let mut rec = StreamRecord::from_f32("u", 0, 0, 100, &[4], &[1.0, 2.0, 3.0, 4.0])
        .unwrap();
    let stamps = Trace {
        origin_us: 100,
        enqueue_us: 250,
        flush_us: 1_000,
        deliver_us: 0,
    };
    rec.meta = Some(FrameMeta {
        encoding: Encoding::F32,
        codec: CodecKind::None,
        enc_param: 0.0,
        err_bound: 0.0,
        raw_len: rec.payload.len() as u32,
        stats: None,
        trace: Some(stamps),
        provenance: String::new(),
    });

    let dialer = SimDialer::new(net.clone());
    let mut conn = dialer.dial(0).unwrap();
    let replies = conn
        .exchange(&[hello("u/0", 1), xaddf("u/0", 1, 0, rec.encode())])
        .unwrap();
    assert!(replies.iter().all(|r| !r.is_error()), "{replies:?}");
    let before = record_bytes(&net.store(0), "u/0");
    assert_eq!(before.len(), 1);

    // Two subscriber fleets ack their cursors against the head; the
    // cursor gossip rides the chain.
    let id = before[0].0;
    let replies = conn
        .exchange(&[
            Request::new("XACKPOS").arg("u/0").arg(id.to_string()),
            Request::new("XACKPOS")
                .arg("u/0")
                .arg("GROUP")
                .arg("dashboard")
                .arg(id.to_string()),
        ])
        .unwrap();
    assert!(replies.iter().all(|r| !r.is_error()), "{replies:?}");

    // The head's machine dies, WAL and all.  Everything the promoted
    // successor serves must be byte-for-byte what the head served.
    net.kill_machine(0);
    let after = record_bytes(&net.store(1), "u/0");
    assert_eq!(before, after, "stored record bytes survive promotion");
    let survived = StreamRecord::peek_trace(&after[0].1)
        .expect("trace stamps survive failover");
    assert_eq!(survived, stamps, "hop stamps byte-identical on the successor");
    assert_eq!(net.store(1).acked("u/0"), id, "default-group cursor survives");
    assert_eq!(
        net.store(1).acked_group("u/0", "dashboard"),
        id,
        "named consumer-group cursor survives"
    );
    assert_eq!(net.store(0).acked("u/0"), EntryId::ZERO, "old machine is gone");
}
