//! ISSUE 6 integration: the consumer fan-out serving layer.
//!
//! Asserted end to end, over real TCP endpoints:
//!
//! * three named consumer groups tail the same stream with independent
//!   cursors; each group sees every record exactly once and in order,
//!   even across an endpoint crash-restart (the group cursors are
//!   WAL-logged and replayed, and readers rebuilt after the crash
//!   resume from the persisted positions via `subscribe_from`);
//! * a server-side `XREAD STRIDE k` reduced view returns exactly what
//!   the broker's `stages::block_mean_last_axis` would produce —
//!   bit-for-bit — as a self-describing staged frame;
//! * a subscriber tailing the `results/<field>/<rank>` stream decodes
//!   the same eigenvalues / σ / stability the DMD engine fired
//!   (well inside the 1e-9 acceptance bound: the codec is bit-exact).

use elasticbroker::analysis::{
    results_key, AnalysisResult, DmdBackend, DmdConfig, DmdEngine,
};
use elasticbroker::broker::stages;
use elasticbroker::endpoint::{
    EndpointServer, EntryId, FsyncPolicy, StoreConfig, WalConfig,
};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::StreamRecord;
use elasticbroker::streamproc::StreamReader;
use elasticbroker::transport::{ConnConfig, RespConn};

const KEY: &str = "u/0";

fn snap(step: u64, d: usize) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..d)
        .map(|i| (decay * (0.4 * step as f64 + 0.17 * i as f64).cos()) as f32)
        .collect()
}

fn rec(step: u64, d: usize) -> StreamRecord {
    StreamRecord::from_f32("u", 0, step, 0, &[d as u32], &snap(step, d)).unwrap()
}

fn add(srv: &EndpointServer, key: &str, r: &StreamRecord) {
    srv.store()
        .xadd(key, None, vec![(b"r".to_vec(), r.encode())])
        .unwrap();
}

fn group_reader(
    srv: &EndpointServer,
    group: &str,
    batch_limit: usize,
) -> StreamReader {
    let mut r = StreamReader::connect(
        srv.addr(),
        vec![KEY.to_string()],
        batch_limit,
        ConnConfig::default(),
    )
    .unwrap();
    r.set_auto_ack(true);
    r.set_group(group);
    r
}

/// Steps delivered by draining `r` until a poll comes back empty.
fn drain_steps(r: &mut StreamReader) -> Vec<u64> {
    let mut steps = Vec::new();
    for _ in 0..64 {
        let batches = r.poll().unwrap();
        if batches.is_empty() {
            return steps;
        }
        for b in batches {
            for rec in b.records {
                steps.push(rec.step);
            }
        }
    }
    panic!("reader did not drain in 64 polls");
}

/// Steps delivered by exactly one poll.
fn poll_steps(r: &mut StreamReader) -> Vec<u64> {
    r.poll()
        .unwrap()
        .into_iter()
        .flat_map(|b| b.records)
        .map(|rec| rec.step)
        .collect()
}

#[test]
fn three_groups_exactly_once_across_crash_restart() {
    const N: u64 = 30;
    let wal_root = std::env::temp_dir().join(format!(
        "eb-fanout-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&wal_root);
    let cfg = || StoreConfig {
        retention: true,
        wal: Some(WalConfig {
            dir: wal_root.clone(),
            fsync: FsyncPolicy::Always, // crash below is loss-free
            segment_bytes: 1 << 20,
        }),
        ..Default::default()
    };

    let mut srv = EndpointServer::start("127.0.0.1:0", cfg()).unwrap();
    for step in 0..N {
        add(&srv, KEY, &rec(step, 16));
    }

    // Three groups at different positions: alpha drains everything,
    // beta takes one 10-record batch, gamma one 5-record batch.
    let mut alpha = group_reader(&srv, "alpha", 0);
    let mut beta = group_reader(&srv, "beta", 10);
    let mut gamma = group_reader(&srv, "gamma", 5);
    let mut alpha_steps = drain_steps(&mut alpha);
    let mut beta_steps = poll_steps(&mut beta);
    let mut gamma_steps = poll_steps(&mut gamma);
    assert_eq!(alpha_steps.len(), N as usize);
    assert_eq!(beta_steps.len(), 10);
    assert_eq!(gamma_steps.len(), 5);

    // Independent server-side cursors, one per group.
    let store = srv.store().clone();
    let last = store.last_id(KEY);
    assert_eq!(store.acked_group(KEY, "alpha"), last);
    let beta_pos = store.acked_group(KEY, "beta");
    let gamma_pos = store.acked_group(KEY, "gamma");
    assert!(EntryId::ZERO < gamma_pos && gamma_pos < beta_pos && beta_pos < last);
    // Retention floor = min across groups (gamma): entries above it
    // must all still be readable.
    assert!(store.read_after(KEY, gamma_pos, 0).len() >= (N as usize) - 5);
    drop(store);

    // Crash the endpoint and rebuild it from its log.
    drop(alpha);
    drop(beta);
    drop(gamma);
    srv.stop();
    drop(srv);
    let srv = EndpointServer::start("127.0.0.1:0", cfg()).unwrap();

    // Replay restored every group cursor.
    assert_eq!(srv.store().acked_group(KEY, "alpha"), last);
    assert_eq!(srv.store().acked_group(KEY, "beta"), beta_pos);
    assert_eq!(srv.store().acked_group(KEY, "gamma"), gamma_pos);

    // Readers rebuilt after the crash resume from the persisted
    // positions (subscribe_from repositions the existing subscription —
    // the ISSUE 6 cursor bugfix).
    let resume = |group: &str| -> StreamReader {
        let mut r = group_reader(&srv, group, 0);
        r.subscribe_from(KEY.to_string(), srv.store().acked_group(KEY, group));
        r
    };
    let mut alpha = resume("alpha");
    let mut beta = resume("beta");
    let mut gamma = resume("gamma");
    assert!(
        drain_steps(&mut alpha).is_empty(),
        "alpha consumed everything pre-crash"
    );
    beta_steps.extend(drain_steps(&mut beta));
    gamma_steps.extend(drain_steps(&mut gamma));

    // Exactly-once, in-order delivery per group: the union of pre- and
    // post-crash deliveries is 0..N with no gaps or duplicates.
    let want: Vec<u64> = (0..N).collect();
    alpha_steps.sort_unstable();
    assert_eq!(alpha_steps, want, "alpha");
    assert_eq!(beta_steps, want, "beta");
    assert_eq!(gamma_steps, want, "gamma");

    let _ = std::fs::remove_dir_all(&wal_root);
}

/// Fetch all of `key` through one XREAD with extra view options.
fn xread_records(c: &mut RespConn, extra: &[&[u8]], key: &str) -> Vec<StreamRecord> {
    let mut cmd: Vec<&[u8]> = vec![b"XREAD"];
    cmd.extend_from_slice(extra);
    let key_b = key.as_bytes();
    cmd.extend_from_slice(&[b"STREAMS", key_b, b"0-0"]);
    let reply = c.request(&cmd).unwrap();
    let streams = reply.as_array().expect("XREAD reply not an array");
    let stream = streams[0].as_array().unwrap();
    assert_eq!(stream[0].as_bytes().unwrap(), key.as_bytes());
    stream[1]
        .as_array()
        .unwrap()
        .iter()
        .map(|e| {
            let e = e.as_array().unwrap();
            let fields = e[1].as_array().unwrap();
            assert_eq!(fields[0].as_bytes().unwrap(), b"r");
            StreamRecord::decode(fields[1].as_bytes().unwrap()).unwrap()
        })
        .collect()
}

#[test]
fn stride_view_matches_block_mean_oracle_bit_exactly() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let shape = [2u32, 16];
    let data: Vec<f32> = (0..32).map(|i| i as f32 * 0.37 - 2.5).collect();
    let r = StreamRecord::from_f32("u", 0, 7, 0, &shape, &data).unwrap();
    add(&srv, KEY, &r);

    let mut c = RespConn::connect(srv.addr(), ConnConfig::default()).unwrap();
    let got = xread_records(&mut c, &[b"STRIDE", b"4"], KEY);
    assert_eq!(got.len(), 1);
    let got = &got[0];
    let (oshape, odata) = stages::block_mean_last_axis(&shape, &data, 4).unwrap();
    assert_eq!(got.shape, oshape);
    assert_eq!(got.step, 7);
    let gdata = got.payload_f32().unwrap();
    assert_eq!(gdata.len(), odata.len());
    for (g, o) in gdata.iter().zip(&odata) {
        assert_eq!(g.to_bits(), o.to_bits(), "STRIDE view diverged from oracle");
    }
    let prov = &got.meta.as_ref().expect("reduced views are staged frames").provenance;
    assert!(prov.contains("view.stride=4"), "provenance: {prov}");

    // ROI composes: crop first, then block-mean, same oracles.
    let got = xread_records(&mut c, &[b"ROI", b"4:12", b"STRIDE", b"2"], KEY);
    let got = &got[0];
    let (cshape, cdata) = stages::crop_last_axis(&shape, &data, 4, 12).unwrap();
    let (oshape, odata) = stages::block_mean_last_axis(&cshape, &cdata, 2).unwrap();
    assert_eq!(got.shape, oshape);
    let gdata = got.payload_f32().unwrap();
    for (g, o) in gdata.iter().zip(&odata) {
        assert_eq!(g.to_bits(), o.to_bits(), "ROI+STRIDE view diverged");
    }
}

#[test]
fn results_stream_subscriber_matches_engine_fires() {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let engine = DmdEngine::new(
        DmdConfig {
            window: 4,
            rank: 3,
            backend: DmdBackend::Rust,
            ..Default::default()
        },
        None,
        WorkflowMetrics::new(),
    )
    .unwrap();

    // Two streams, 12 snapshots each; publish every fire back into the
    // endpoint exactly like the workflow collector does.
    let d = 32;
    let mut fires: Vec<AnalysisResult> = Vec::new();
    for rank in 0..2u32 {
        for step in 0..12u64 {
            let data: Vec<f32> = snap(step, d)
                .iter()
                .map(|v| v + rank as f32 * 0.1)
                .collect();
            let r =
                StreamRecord::from_f32("u", rank, step, 0, &[d as u32], &data).unwrap();
            let key = r.stream_key();
            if let Some(res) = engine.push(&key, &r).unwrap() {
                let out = res.to_record();
                add(&srv, &out.stream_key(), &out);
                fires.push(res);
            }
        }
    }
    assert_eq!(fires.len(), 2 * 8, "window 4+1 fills at 5, fires per push");

    let keys: Vec<String> = (0..2u32)
        .map(|rank| results_key(&elasticbroker::record::stream_key("u", rank)))
        .collect();
    let mut sub =
        StreamReader::connect(srv.addr(), keys, 0, ConnConfig::default()).unwrap();
    let mut seen: Vec<AnalysisResult> = Vec::new();
    for b in sub.poll().unwrap() {
        for rec in &b.records {
            seen.push(AnalysisResult::from_record(rec).unwrap());
        }
    }
    assert_eq!(seen.len(), fires.len());
    for s in &seen {
        let orig = fires
            .iter()
            .find(|f| f.key == s.key && f.step == s.step)
            .unwrap_or_else(|| panic!("no engine fire for {}@{}", s.key, s.step));
        assert_eq!(orig.backend, s.backend);
        assert_eq!(orig.latency_us, s.latency_us);
        assert!((orig.stability - s.stability).abs() <= 1e-9);
        assert_eq!(orig.eigs.len(), s.eigs.len());
        for (a, b) in orig.eigs.iter().zip(&s.eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-9 && (a.im - b.im).abs() <= 1e-9,
                "λ {a:?} vs {b:?}"
            );
        }
        assert_eq!(orig.sigma.len(), s.sigma.len());
        for (a, b) in orig.sigma.iter().zip(&s.sigma) {
            assert!((a - b).abs() <= 1e-9, "σ {a} vs {b}");
        }
    }
    // in-order per stream: ids ascend, so steps must too
    for rank in 0..2u32 {
        let key = elasticbroker::record::stream_key("u", rank);
        let steps: Vec<u64> =
            seen.iter().filter(|s| s.key == key).map(|s| s.step).collect();
        assert_eq!(steps.len(), 8);
        assert!(steps.windows(2).all(|w| w[0] < w[1]), "{key}: {steps:?}");
    }
}
