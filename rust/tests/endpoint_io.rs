//! ISSUE 7 integration tests for the sharded endpoint I/O core, from
//! the outside: real sockets against a running [`EndpointServer`].
//!
//! * slowloris — a frame dribbled one byte at a time must decode
//!   exactly once, without a thread per connection and without
//!   unbounded event-loop wakeups,
//! * backpressure — a reader that stops draining its replies gets
//!   paused at the high-water mark and must not stall the *other*
//!   connections owned by the same shard,
//! * zero-copy — serving stored payloads over TCP must not copy a
//!   single payload byte (debug-asserted copy counter stays flat),
//! * stats — the per-server counters and the mirrored
//!   [`EndpointStats`] gauge agree with the observable connection
//!   lifecycle.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use elasticbroker::endpoint::poll::Poller;
use elasticbroker::endpoint::server::reply_payload_bytes_copied;
use elasticbroker::endpoint::{EndpointServer, ServerConfig, StoreConfig};
use elasticbroker::metrics::EndpointStats;
use elasticbroker::transport::{ConnConfig, Request, RespConn};
use elasticbroker::wire::{self, Decoder, Value};

fn start_default() -> EndpointServer {
    EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap()
}

/// A byte-dribbled frame (the classic slowloris shape) must decode
/// exactly once — the incremental decoder carries partial frames across
/// reads — and, on an accurate poller, the event loop must wake at most
/// a small constant per delivered byte, never spin.
#[test]
fn slowloris_dribbled_frame_decodes_once() {
    let srv = start_default();
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    s.set_nodelay(true).unwrap();

    let arg = vec![b'x'; 48];
    let mut frame = Vec::new();
    wire::encode_command(&[b"ECHO", &arg], &mut frame);

    // Settle the accept before sampling the wakeup counter so the
    // listener's thundering-herd readiness is not charged to the dribble.
    std::thread::sleep(Duration::from_millis(50));
    let wakeups_before = srv.stats().wakeups();

    for b in &frame {
        s.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }

    let mut dec = Decoder::new();
    let mut buf = [0u8; 4096];
    let reply = loop {
        if let Some(v) = dec.next().unwrap() {
            break v;
        }
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed mid-reply");
        dec.feed(&buf[..n]);
    };
    assert_eq!(reply, Value::Bulk(arg));

    if Poller::accurate() {
        let delta = srv.stats().wakeups() - wakeups_before;
        let bound = 4 * frame.len() as u64 + 64;
        assert!(
            delta <= bound,
            "event loop woke {delta} times for a {}-byte dribble (bound {bound})",
            frame.len()
        );
    }
}

/// One shard, two connections: a client that requests megabytes of
/// replies and never reads must get parked at the reply high-water mark
/// while the shard keeps serving its other connection at full speed —
/// and once the stalled client finally drains, every byte it was owed
/// arrives intact.
#[test]
fn stalled_reader_does_not_block_the_shard() {
    let srv = EndpointServer::start_with(
        "127.0.0.1:0",
        StoreConfig::default(),
        ServerConfig {
            io_shards: 1,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    // 16 × 256 KiB = 4 MiB in stream "big": one XRANGE reply spans the
    // whole 4 MiB high-water mark by itself.
    let mut writer = RespConn::connect(srv.addr(), ConnConfig::default()).unwrap();
    let payload = vec![7u8; 256 * 1024];
    let reqs: Vec<Request> = (0..16)
        .map(|_| {
            Request::new("XADD")
                .arg("big")
                .arg("*")
                .arg("r")
                .arg(payload.clone())
        })
        .collect();
    let replies = writer.pipeline(&reqs).unwrap();
    assert!(replies.iter().all(|r| !r.is_error()));

    // The stalled reader: three full-stream XRANGEs pipelined, zero
    // reads. The server renders until the reply queue crosses the
    // high-water mark, then pauses this connection.
    let mut stalled = TcpStream::connect(srv.addr()).unwrap();
    let mut frame = Vec::new();
    for _ in 0..3 {
        wire::encode_command(&[b"XRANGE", b"big", b"-", b"+"], &mut frame);
    }
    stalled.write_all(&frame).unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // The same (only) shard must keep serving this connection promptly.
    let t0 = Instant::now();
    for i in 0..20 {
        let v = writer
            .request(&[b"ECHO", format!("alive-{i}").as_bytes()])
            .unwrap();
        assert_eq!(v, Value::Bulk(format!("alive-{i}").into_bytes()));
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "healthy connection starved behind a stalled reader: {:?}",
        t0.elapsed()
    );

    // Drain the stalled connection: all three 16-entry replies must
    // arrive intact once the reader resumes (pause → resume must not
    // drop or reorder queued reply bytes).
    let mut dec = Decoder::new();
    let mut buf = vec![0u8; 256 * 1024];
    let mut got = 0;
    while got < 3 {
        if let Some(v) = dec.next().unwrap() {
            match v {
                Value::Array(entries) => assert_eq!(entries.len(), 16),
                other => panic!("unexpected XRANGE reply: {other}"),
            }
            got += 1;
            continue;
        }
        let n = stalled.read(&mut buf).unwrap();
        assert!(n > 0, "server closed before all replies were drained");
        dec.feed(&buf[..n]);
    }
}

/// The acceptance gate: shipping stored payloads over TCP copies zero
/// payload bytes — replies borrow the store's refcounted entry bytes
/// straight into `writev`.  The counter is only bumped by the
/// materializing (sim/inline) render path, which this test never takes.
#[test]
fn tcp_reply_path_copies_no_payload_bytes() {
    let srv = start_default();
    let mut conn = RespConn::connect(srv.addr(), ConnConfig::default()).unwrap();

    let payload = vec![42u8; 8 * 1024];
    let reqs: Vec<Request> = (0..32)
        .map(|_| {
            Request::new("XADD")
                .arg("zc")
                .arg("*")
                .arg("r")
                .arg(payload.clone())
        })
        .collect();
    assert!(conn.pipeline(&reqs).unwrap().iter().all(|r| !r.is_error()));

    let before = reply_payload_bytes_copied();
    let reply = conn.request(&[b"XRANGE", b"zc", b"-", b"+"]).unwrap();
    match reply {
        Value::Array(entries) => assert_eq!(entries.len(), 32),
        other => panic!("unexpected XRANGE reply: {other}"),
    }
    let reply = conn
        .request(&[b"XREAD", b"COUNT", b"32", b"STREAMS", b"zc", b"0"])
        .unwrap();
    assert!(!reply.is_error());
    assert_eq!(
        reply_payload_bytes_copied() - before,
        0,
        "TCP reply path copied payload bytes"
    );
}

/// The `connections` gauge and byte counters mirrored into a caller's
/// [`EndpointStats`] slot track the observable connection lifecycle.
#[test]
fn endpoint_stats_mirror_connection_lifecycle() {
    let slot = Arc::new(EndpointStats::default());
    let srv = EndpointServer::start_with(
        "127.0.0.1:0",
        StoreConfig::default(),
        ServerConfig {
            metrics: Some(slot.clone()),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut conn = RespConn::connect(srv.addr(), ConnConfig::default()).unwrap();
    conn.ping().unwrap();
    assert_eq!(slot.connections.get(), 1);
    assert_eq!(srv.stats().connections(), 1);
    assert_eq!(srv.stats().conns_total(), 1);
    assert_eq!(srv.stats().accept_errors(), 0);
    assert!(slot.bytes_read.get() > 0, "PING bytes not counted as read");
    assert!(slot.bytes_written.get() > 0, "PONG bytes not counted as written");

    drop(conn);
    let deadline = Instant::now() + Duration::from_secs(5);
    while slot.connections.get() != 0 {
        assert!(
            Instant::now() < deadline,
            "connection close never reflected in the gauge"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(srv.stats().connections(), 0);
}
