//! ISSUE 4 integration: 4 ranks streaming through WAL-backed endpoints,
//! one of which is killed mid-batch (via the `transport::sim`
//! kill+restart fault) and restarted from its log.
//!
//! Asserted end to end:
//! * replay restores entries, epoch fences and step high-water marks
//!   (a pre-crash zombie writer still gets `STALE` after recovery, a
//!   re-shipped landed step still gets `DUP`);
//! * the union of segments across endpoints is exactly-once and
//!   gap-free despite the crash;
//! * the streamed DMD on the delivered records matches the offline
//!   `linalg::dmd` oracle to 1e-6 — the crash is invisible to the
//!   analysis layer;
//! * reader acks (retention) bound the log without ever dropping
//!   unread data.

use std::sync::Arc;

use elasticbroker::analysis::{AnalysisResult, DmdConfig, DmdEngine};
use elasticbroker::broker::{GroupMap, Shipper, TopologyHandle};
use elasticbroker::endpoint::{EntryId, FsyncPolicy, StoreConfig, WalConfig};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::StreamRecord;
use elasticbroker::streamproc::ElasticReader;
use elasticbroker::transport::sim::{FaultSchedule, SimDialer, SimNet};
use elasticbroker::transport::{Conn as _, Dialer, Request};

const RANKS: u32 = 4;
const DIM: usize = 32;
const STEPS: u64 = 16;
const WINDOW: usize = 6; // m; the engine windows m+1 = 7 snapshots
const DMD_RANK: usize = 4;

/// Deterministic decaying-oscillation snapshot for (rank, step) — a
/// pure function, so the streamed windows are bit-identical to what a
/// crash-free static run would analyse.
fn snapshot(rank: u32, step: u64) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..DIM)
        .map(|i| {
            let phase = 0.17 * i as f64 + 0.29 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

fn rec(rank: u32, step: u64) -> StreamRecord {
    StreamRecord::from_f32("synth", rank, step, 0, &[DIM as u32], &snapshot(rank, step))
        .unwrap()
}

#[test]
fn endpoint_crash_restart_is_exactly_once_and_matches_offline_dmd() {
    let wal_root = std::env::temp_dir().join(format!(
        "eb-crash-restart-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&wal_root);

    // --- two durable sim endpoints (fsync=always: crash is loss-free)
    let net = SimNet::new();
    for i in 0..2usize {
        net.add_endpoint(StoreConfig {
            retention: true,
            wal: Some(WalConfig {
                dir: wal_root.join(format!("ep{i}")),
                fsync: FsyncPolicy::Always,
                segment_bytes: 1 << 20,
            }),
            ..Default::default()
        });
    }
    let dummy = || -> std::net::SocketAddr { "127.0.0.1:1".parse().unwrap() };
    let groups = GroupMap::new(RANKS as usize, 2, 2).unwrap();
    let topology =
        TopologyHandle::new_static(groups.clone(), vec![dummy(), dummy()]).unwrap();
    let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
    let metrics = WorkflowMetrics::new();

    let mut shippers: Vec<Shipper> = (0..RANKS)
        .map(|r| {
            Shipper::register(
                format!("synth/{r}"),
                groups.group_of_rank(r as usize).unwrap(),
                topology.clone(),
                dialer.clone(),
                metrics.clone(),
                8,
            )
            .unwrap()
        })
        .collect();

    // Cloud side: ElasticReader (auto-acking: retention trims by it)
    // feeding the windowed DMD engine, driven synchronously.
    let engine = DmdEngine::new(
        DmdConfig {
            window: WINDOW,
            rank: DMD_RANK,
            hop: 1,
            backend: elasticbroker::analysis::DmdBackend::Rust,
            ..Default::default()
        },
        None,
        metrics.clone(),
    )
    .unwrap();
    let keys: Vec<String> = (0..RANKS).map(|r| format!("synth/{r}")).collect();
    let mut reader =
        ElasticReader::new(topology.clone(), dialer.clone(), keys, 0).unwrap();
    reader.set_auto_ack(true);
    let mut results: Vec<AnalysisResult> = Vec::new();
    let mut delivered: Vec<Vec<u64>> = vec![Vec::new(); RANKS as usize];

    let drain =
        |reader: &mut ElasticReader,
         results: &mut Vec<AnalysisResult>,
         delivered: &mut Vec<Vec<u64>>| {
            for _ in 0..4 {
                for batch in reader.poll().unwrap() {
                    let (_, rank) =
                        elasticbroker::record::parse_stream_key(&batch.key).unwrap();
                    delivered[rank as usize]
                        .extend(batch.records.iter().map(|r| r.step));
                    results.extend(engine.process(&batch));
                }
            }
        };

    // --- phase 1: steps 0..8, two-record frames, no faults.
    for lo in (0..8u64).step_by(2) {
        for (r, shipper) in shippers.iter_mut().enumerate() {
            shipper
                .ship(&[rec(r as u32, lo), rec(r as u32, lo + 1)])
                .unwrap();
        }
    }
    drain(&mut reader, &mut results, &mut delivered);

    // --- the fault: endpoint 0 crashes mid-batch on its next frame —
    // 1 of 2 records lands (and is fsynced), the process dies, the
    // orchestrator restarts it from its WAL, and the first reconnect
    // is refused for good measure.
    let victim_rank = (0..RANKS)
        .find(|&r| {
            let g = groups.group_of_rank(r as usize).unwrap();
            topology.route(g).unwrap().0 == 0
        })
        .expect("some rank homed on endpoint 0");
    let victim_key = format!("synth/{victim_rank}");
    net.inject(
        0,
        FaultSchedule {
            drop_after_frames: Some(0),
            partial_commands: 1,
            crash_on_drop: true,
            refuse_connects: 1,
            ..Default::default()
        },
    );

    // --- phase 2: steps 8..16; the victim ships first and eats the
    // crash inside one `ship` call (recover → reconnect → HELLO against
    // the replayed fence → re-ship, DUP for the landed record).
    for lo in (8..STEPS).step_by(2) {
        shippers[victim_rank as usize]
            .ship(&[rec(victim_rank, lo), rec(victim_rank, lo + 1)])
            .unwrap();
        for r in 0..RANKS {
            if r != victim_rank {
                shippers[r as usize]
                    .ship(&[rec(r, lo), rec(r, lo + 1)])
                    .unwrap();
            }
        }
    }
    drain(&mut reader, &mut results, &mut delivered);

    // --- recovery restored the fencing state: replayed entries exist,
    // the high-water mark is intact, and a pre-crash zombie (epoch 0,
    // below the replayed fence) is still rejected — over the wire.
    let store0 = net.store(0);
    assert!(store0.replayed_entries() > 0, "endpoint 0 never replayed");
    assert!(store0.info().contains("wal_enabled:1"));
    assert_eq!(store0.fenced_last_step(&victim_key), Some(STEPS - 1));
    let mut zombie = SimDialer::new(net.clone()).dial(0).unwrap();
    let reply = zombie
        .exchange(&[Request::new("XADDF")
            .arg(victim_key.as_bytes())
            .arg("0")
            .arg("99")
            .arg("r")
            .arg("z")])
        .unwrap();
    assert!(
        reply[0].is_error() && reply[0].as_str_lossy().starts_with("STALE"),
        "zombie writer not fenced after recovery: {}",
        reply[0]
    );
    let err = store0.hello(&victim_key, 0).unwrap_err();
    assert!(err.to_string().starts_with("STALE"), "{err}");
    assert_eq!(
        metrics.replay_gaps.get(),
        0,
        "fsync=always recovery must be loss-free"
    );

    // --- exactly-once, gap-free: per-endpoint segments are strictly
    // increasing and their union is every step exactly once.
    for r in 0..RANKS {
        let key = format!("synth/{r}");
        let mut union: Vec<u64> = Vec::new();
        for e in 0..2usize {
            let mut prev: Option<u64> = None;
            for entry in net.store(e).read_after(&key, EntryId::ZERO, 0) {
                if entry.fields[0].0 == b"h" {
                    continue; // handoff tombstone
                }
                let rec = StreamRecord::decode(&entry.fields[0].1).unwrap();
                if let Some(p) = prev {
                    assert!(
                        rec.step > p,
                        "{key}: endpoint {e} segment not strictly increasing"
                    );
                }
                prev = Some(rec.step);
                union.push(rec.step);
            }
        }
        union.sort_unstable();
        assert_eq!(
            union,
            (0..STEPS).collect::<Vec<u64>>(),
            "{key}: union of segments must be every step exactly once"
        );
        // ...and delivery to the analysis layer saw the same thing.
        assert_eq!(
            delivered[r as usize],
            (0..STEPS).collect::<Vec<u64>>(),
            "{key}: delivered stream has gaps or reorders"
        );
    }

    // --- reader acks reached the durable endpoints (retention floor).
    assert!(
        net.store(1).acked(&format!(
            "synth/{}",
            (0..RANKS)
                .find(|&r| {
                    let g = groups.group_of_rank(r as usize).unwrap();
                    topology.route(g).unwrap().0 == 1
                })
                .unwrap()
        )) > EntryId::ZERO,
        "auto-ack never reached endpoint 1"
    );

    // --- the streamed DMD ≡ offline oracle at 1e-6 on the final window.
    let expect = (STEPS as usize - WINDOW) * RANKS as usize;
    assert_eq!(results.len(), expect, "analysis fire count");
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let fires: Vec<u64> = {
            let mut s: Vec<u64> = results
                .iter()
                .filter(|a| a.key == key)
                .map(|a| a.step)
                .collect();
            s.sort_unstable();
            s
        };
        assert_eq!(
            fires,
            (WINDOW as u64..STEPS).collect::<Vec<u64>>(),
            "{key}: fire steps have gaps"
        );
        let streamed = results
            .iter()
            .filter(|a| a.key == key)
            .max_by_key(|a| a.step)
            .unwrap();
        assert_eq!(streamed.step, STEPS - 1);

        let m1 = WINDOW + 1;
        let mut x = vec![0.0f64; DIM * m1];
        for (j, step) in (STEPS - m1 as u64..STEPS).enumerate() {
            let snap = snapshot(rank, step);
            for (i, v) in snap.iter().enumerate() {
                x[i * m1 + j] = *v as f64;
            }
        }
        let xm = Mat::from_slice(DIM, m1, &x).unwrap();
        let (eigs, sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();
        assert!(
            (streamed.stability - stability).abs() <= 1e-6,
            "{key}: stability {} vs offline {}",
            streamed.stability,
            stability
        );
        for (a, b) in streamed.eigs.iter().zip(&eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-6 && (a.im - b.im).abs() <= 1e-6,
                "{key}: eig {a:?} vs offline {b:?}"
            );
        }
        for (a, b) in streamed.sigma.iter().zip(&sigma) {
            assert!((a - b).abs() <= 1e-6, "{key}: sigma {a} vs offline {b}");
        }
    }

    let _ = std::fs::remove_dir_all(&wal_root);
}
