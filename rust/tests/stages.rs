//! End-to-end exercise of the broker-side data-reduction stage
//! pipeline (ISSUE 5 tentpole): 4 ranks ship staged (`EBR2`) frames —
//! filter → aggregate → convert → compress — through a real endpoint
//! into the streaming + windowed-DMD stack.
//!
//! Pinned invariants:
//!
//! * **Lossless stages** (aggregate + shuffle-lz): the streamed DMD
//!   matches the offline oracle on the same window to 1e-6, the
//!   decoded payloads are bit-exactly the block-mean of the source
//!   data, and wire bytes genuinely shrink.
//! * **Lossy stages** (f16 / qdelta): every decoded snapshot sits
//!   within the frame's *stated* error bound of the original, and the
//!   streamed DMD still matches the offline oracle (computed on the
//!   decoded snapshots, which is what the Cloud side can ever see) to
//!   1e-6.
//! * **Corruption**: staged frames reject every single-byte flip
//!   cleanly (CRC or schema — never a panic), and the codec layer
//!   itself never panics on corrupt compressed streams.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elasticbroker::analysis::{AnalysisResult, DmdBackend, DmdConfig, DmdEngine};
use elasticbroker::broker::{stages, Broker, BrokerConfig, StagesConfig};
use elasticbroker::endpoint::{EndpointServer, EntryId, StoreConfig};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::{codec, CodecKind, Encoding, StreamRecord};
use elasticbroker::streamproc::{StreamReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::ConnConfig;
use elasticbroker::util::prop::{self, F32Vec};

const RANKS: u32 = 4;
const DIM: usize = 32;
const STEPS: u64 = 20;
const WINDOW: usize = 6; // m; the engine windows m+1 = 7 snapshots
const DMD_RANK: usize = 4;

/// Deterministic decaying-oscillation snapshot for (rank, step) —
/// smooth in space, so the lossless codec genuinely compresses it.
fn snapshot(rank: u32, step: u64) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..DIM)
        .map(|i| {
            let phase = 0.13 * i as f64 + 0.31 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

/// Ship every (rank, step) snapshot through a broker configured with
/// `stages`, run the streaming + DMD stack, and return the collected
/// results plus the endpoint (for offline oracles).
fn run_staged(
    stages_cfg: StagesConfig,
) -> (Vec<AnalysisResult>, EndpointServer, WorkflowMetrics) {
    let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();
    let broker = Arc::new(
        Broker::new(
            BrokerConfig {
                group_size: RANKS as usize,
                queue_cap: 32,
                batch_max_records: 8,
                linger_ms: 5,
                stages: stages_cfg,
                ..BrokerConfig::new(vec![srv.addr()])
            },
            RANKS as usize,
            metrics.clone(),
        )
        .unwrap(),
    );

    let writers: Vec<_> = (0..RANKS)
        .map(|rank| {
            let broker = broker.clone();
            std::thread::spawn(move || {
                let ctx = broker.init("synth", rank).unwrap();
                for step in 0..STEPS {
                    ctx.write(step, &[DIM as u32], &snapshot(rank, step)).unwrap();
                }
                ctx.finalize().unwrap();
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    assert_eq!(metrics.dropped.get(), 0);

    let engine = Arc::new(
        DmdEngine::new(
            DmdConfig {
                window: WINDOW,
                rank: DMD_RANK,
                hop: 1,
                backend: DmdBackend::Rust,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap(),
    );
    let keys: Vec<String> = (0..RANKS).map(|r| format!("synth/{r}")).collect();
    let reader = StreamReader::connect(srv.addr(), keys, 0, ConnConfig::default()).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let eng = engine.clone();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 4,
            batch_limit: 0,
        },
        vec![reader],
        move |b| eng.process(b),
        tx,
    );
    let per_rank = STEPS as usize - WINDOW;
    let expect = per_rank * RANKS as usize;
    let mut results: Vec<AnalysisResult> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(15);
    while results.len() < expect && Instant::now() < deadline {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(100)) {
            results.push(res);
        }
    }
    ctx.stop().unwrap();
    results.extend(rx.try_iter().map(|(_, r)| r));
    assert_eq!(results.len(), expect, "analysis count");
    (results, srv, metrics)
}

/// Offline oracle on the *landed* (decoded) snapshots of the final
/// window, compared against the streamed result at 1e-6.
fn assert_streamed_matches_offline(
    results: &[AnalysisResult],
    srv: &EndpointServer,
    dim: usize,
) {
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let streamed = results
            .iter()
            .filter(|r| r.key == key)
            .max_by_key(|r| r.step)
            .unwrap_or_else(|| panic!("no results for {key}"));
        assert_eq!(streamed.step, STEPS - 1);
        assert_eq!(streamed.backend, "rust");

        let entries = srv.store().read_after(&key, EntryId::ZERO, 0);
        let m1 = WINDOW + 1;
        let window: Vec<Vec<f32>> = entries[entries.len() - m1..]
            .iter()
            .map(|e| {
                StreamRecord::decode(&e.fields[0].1)
                    .unwrap()
                    .payload_f32()
                    .unwrap()
            })
            .collect();
        let mut x = vec![0.0f64; dim * m1];
        for (j, snap) in window.iter().enumerate() {
            assert_eq!(snap.len(), dim, "{key}: decoded dim");
            for i in 0..dim {
                x[i * m1 + j] = snap[i] as f64;
            }
        }
        let xm = Mat::from_slice(dim, m1, &x).unwrap();
        let (eigs, sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();
        assert!(
            (streamed.stability - stability).abs() <= 1e-6,
            "{key}: stability {} vs offline {}",
            streamed.stability,
            stability
        );
        for (a, b) in streamed.eigs.iter().zip(&eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-6 && (a.im - b.im).abs() <= 1e-6,
                "{key}: eig {a:?} vs offline {b:?}"
            );
        }
        for (a, b) in streamed.sigma.iter().zip(&sigma) {
            assert!((a - b).abs() <= 1e-6, "{key}: sigma {a} vs offline {b}");
        }
    }
}

/// Losslessly-*coded* stages: aggregate-by-2 + shuffle-lz.  Streamed
/// DMD ≡ offline oracle, decoded payloads ≡ block-mean of the source
/// bit-exactly, wire bytes shrink — and (ISSUE 8) the frame owns up to
/// the block-mean residual in `err_bound` instead of claiming 0.
#[test]
fn staged_lossless_dmd_matches_offline_oracle() {
    let cfg = StagesConfig {
        aggregate: 2,
        codec: CodecKind::ShuffleLz,
        ..Default::default()
    };
    let dim = DIM / 2;
    let (results, srv, metrics) = run_staged(cfg);
    assert_streamed_matches_offline(&results, &srv, dim);

    // decoded payloads are bit-exactly the block-mean of the source
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let entries = srv.store().read_after(&key, EntryId::ZERO, 0);
        assert_eq!(entries.len(), STEPS as usize);
        for e in &entries {
            let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
            let meta = rec.meta.as_ref().expect("staged frame");
            // Aggregation of a varying field is lossy vs the original:
            // the bound must cover the measured block-mean residual
            // (the pre-ISSUE-8 pipeline shipped err_bound = 0 here).
            assert!(
                meta.err_bound > 0.0,
                "aggregate=2 on a varying field must report its residual"
            );
            assert!(meta.stats.is_some(), "aggregate carries sidecar stats");
            let original = snapshot(rank, rec.step);
            let (_, oracle) =
                stages::block_mean_last_axis(&[DIM as u32], &original, 2).unwrap();
            let got = rec.payload_f32().unwrap();
            assert_eq!(got.len(), oracle.len());
            for (a, b) in got.iter().zip(&oracle) {
                assert_eq!(a.to_bits(), b.to_bits(), "{key} step {}", rec.step);
            }
            // ...and the bound really covers |original − shipped mean|
            for (i, b) in original.iter().enumerate() {
                let a = got[i / 2];
                assert!(
                    (a - b).abs() <= meta.err_bound + 1e-6,
                    "{key} step {}: {b} → {a} over bound {}",
                    rec.step,
                    meta.err_bound
                );
            }
        }
    }

    // the reduction is real: raw input bytes vs shipped payload bytes
    let st = &metrics.stages;
    assert!(
        st.bytes_out.get() < st.bytes_in.get() / 2,
        "aggregate 2 must at least halve payloads: {} vs {}",
        st.bytes_out.get(),
        st.bytes_in.get()
    );
}

/// Lossy stages: every decoded snapshot within the stated bound, and
/// the streamed DMD ≡ the oracle on what actually landed.
#[test]
fn staged_lossy_dmd_within_stated_bound() {
    for (name, cfg) in [
        (
            "f16",
            StagesConfig {
                convert: Encoding::F16,
                codec: CodecKind::ShuffleLz,
                ..Default::default()
            },
        ),
        (
            "qdelta",
            StagesConfig {
                convert: Encoding::QDelta,
                qdelta_step: 1e-4,
                codec: CodecKind::ShuffleLz,
                ..Default::default()
            },
        ),
    ] {
        let (results, srv, _metrics) = run_staged(cfg);
        assert_streamed_matches_offline(&results, &srv, DIM);
        for rank in 0..RANKS {
            let key = format!("synth/{rank}");
            let entries = srv.store().read_after(&key, EntryId::ZERO, 0);
            for e in &entries {
                let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
                let meta = rec.meta.as_ref().expect("staged frame");
                let bound = meta.err_bound;
                assert!(
                    bound > 0.0 && bound < 1e-2,
                    "{name} {key}: implausible bound {bound}"
                );
                let original = snapshot(rank, rec.step);
                for (a, b) in rec.payload_f32().unwrap().iter().zip(&original) {
                    assert!(
                        (a - b).abs() <= bound + 1e-12,
                        "{name} {key} step {}: {b} → {a} over stated bound {bound}",
                        rec.step
                    );
                }
            }
        }
    }
}

/// Property: codec roundtrip identity over random payloads, and
/// every-byte-flip corruption of both the compressed stream and the
/// full staged frame fails cleanly — never panics, never slips
/// through the frame CRC.
#[test]
fn prop_codec_roundtrip_and_corruption_rejected() {
    let gen = F32Vec { max_len: 256, scale: 1e3 };
    prop::forall(0x57A6E5, 60, &gen, |data| {
        if data.is_empty() {
            return Ok(());
        }
        let raw: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let c = codec::codec_for(CodecKind::ShuffleLz);
        let comp = c.compress(&raw, 4);
        let back = c
            .decompress(&comp, raw.len(), 4)
            .map_err(|e| e.to_string())?;
        if back != raw {
            return Err("codec roundtrip not identity".into());
        }
        // corrupt compressed stream: must never panic (Ok-with-wrong-
        // bytes is fine at this layer; the frame CRC is the gate)
        for i in 0..comp.len() {
            let mut fuzzed = comp.clone();
            fuzzed[i] ^= 0xFF;
            let _ = c.decompress(&fuzzed, raw.len(), 4);
        }
        // full staged frame: every byte flip must be rejected
        let pipeline = elasticbroker::broker::StagePipeline::new(
            StagesConfig { codec: CodecKind::ShuffleLz, ..Default::default() },
            Arc::new(elasticbroker::metrics::StageMetrics::new()),
        )
        .map_err(|e| e.to_string())?;
        let rec = pipeline
            .apply("u", 0, 1, 0, 0, &[data.len() as u32], data)
            .map_err(|e| e.to_string())?
            .expect("no filter configured");
        let frame = rec.encode();
        for i in 0..frame.len() {
            let mut fuzzed = frame.clone();
            fuzzed[i] ^= 0xFF;
            if StreamRecord::decode(&fuzzed).is_ok() {
                return Err(format!("flip of staged frame byte {i} went undetected"));
            }
        }
        Ok(())
    });
}

/// Property: the lossy encodings hold their stated bound over random
/// fields (both through the pipeline and after a wire roundtrip).
#[test]
fn prop_lossy_bound_holds_over_random_fields() {
    let gen = F32Vec { max_len: 200, scale: 50.0 };
    for (convert_kind, step) in [(Encoding::F16, 0.0f32), (Encoding::QDelta, 1e-3)] {
        prop::forall(0xB0C5D + convert_kind as u64, 40, &gen, |data| {
            if data.is_empty() {
                return Ok(());
            }
            let pipeline = elasticbroker::broker::StagePipeline::new(
                StagesConfig {
                    convert: convert_kind,
                    qdelta_step: if step > 0.0 { step } else { 1e-3 },
                    codec: CodecKind::ShuffleLz,
                    ..Default::default()
                },
                Arc::new(elasticbroker::metrics::StageMetrics::new()),
            )
            .map_err(|e| e.to_string())?;
            let rec = match pipeline.apply("u", 0, 0, 0, 0, &[data.len() as u32], data) {
                Ok(Some(rec)) => rec,
                Ok(None) => return Err("unexpected filter drop".into()),
                // qdelta legitimately rejects values outside its
                // quantizer range; that is a clean error, not a bug
                Err(_) if convert_kind == Encoding::QDelta => return Ok(()),
                Err(e) => return Err(e.to_string()),
            };
            let bound = rec.meta.as_ref().unwrap().err_bound;
            let wire = StreamRecord::decode(&rec.encode()).map_err(|e| e.to_string())?;
            for (a, b) in wire.payload_f32().unwrap().iter().zip(data) {
                if (a - b).abs() > bound + 1e-9 {
                    return Err(format!(
                        "{convert_kind:?}: {b} → {a} over stated bound {bound}"
                    ));
                }
            }
            // qdelta's a-priori guarantee: bound ≤ step/2 (+ f32 eps)
            if convert_kind == Encoding::QDelta && bound > step / 2.0 + 1e-6 {
                return Err(format!("qdelta bound {bound} exceeds step/2"));
            }
            Ok(())
        });
    }
}
