//! ISSUE 3 integration: 4 ranks streaming through a mid-run endpoint
//! scale-out (1→2) and scale-in (2→1).  Every record must land exactly
//! once (union across endpoint segments, no per-endpoint duplicates),
//! the analysis layer must see every window fire with no gaps, and the
//! final per-stream DMD result must match the offline `linalg::dmd`
//! reference on the same window to 1e-6 — i.e. the elastic run is
//! indistinguishable from a static-topology run (same oracle pattern
//! as `tests/pipeline.rs`).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use elasticbroker::analysis::{AnalysisResult, DmdConfig, DmdEngine};
use elasticbroker::broker::{
    Broker, BrokerConfig, BrokerCtx, GroupMap, QueuePolicy, TopologyHandle,
};
use elasticbroker::endpoint::{EndpointServer, EntryId, StoreConfig};
use elasticbroker::linalg::{dmd, Mat};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::record::StreamRecord;
use elasticbroker::streamproc::{ElasticReader, StreamingConfig, StreamingContext};
use elasticbroker::transport::{ConnConfig, Dialer, TcpDialer};

const RANKS: u32 = 4;
const DIM: usize = 32;
const STEPS: u64 = 20;
const WINDOW: usize = 6; // m; the engine windows m+1 = 7 snapshots
const DMD_RANK: usize = 4;

/// Deterministic decaying-oscillation snapshot for (rank, step).
fn snapshot(rank: u32, step: u64) -> Vec<f32> {
    let decay = 0.95f64.powi(step as i32);
    (0..DIM)
        .map(|i| {
            let phase = 0.17 * i as f64 + 0.29 * rank as f64;
            (decay * (0.4 * step as f64 + phase).cos()) as f32
        })
        .collect()
}

/// Write one phase of steps on every rank, then wait for the writers'
/// queues to drain so topology changes land between phases.
fn write_phase(ctxs: &[BrokerCtx], lo: u64, hi: u64) {
    for step in lo..hi {
        for (r, ctx) in ctxs.iter().enumerate() {
            ctx.write(step, &[DIM as u32], &snapshot(r as u32, step)).unwrap();
        }
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while ctxs.iter().any(|c| c.backlog() > 0) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        ctxs.iter().all(|c| c.backlog() == 0),
        "writer backlog did not drain"
    );
}

/// All record steps of `key` on `srv`, tombstones excluded; asserts the
/// segment is strictly step-increasing (per-endpoint exactly-once).
fn segment_steps(srv: &EndpointServer, key: &str) -> Vec<u64> {
    let entries = srv.store().read_after(key, EntryId::ZERO, 0);
    let mut steps = Vec::new();
    for e in &entries {
        if e.fields[0].0 == b"h" {
            continue;
        }
        let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
        if let Some(&prev) = steps.last() {
            assert!(rec.step > prev, "{key}: segment not strictly increasing");
        }
        steps.push(rec.step);
    }
    steps
}

#[test]
fn elastic_scale_out_and_in_is_exactly_once_and_matches_static_dmd() {
    let e0 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let e1 = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
    let metrics = WorkflowMetrics::new();

    // group_size 1 → four groups; the topology starts with e0 only.
    let groups = GroupMap::new(RANKS as usize, 1, 1).unwrap();
    let topology = TopologyHandle::new_static(groups, vec![e0.addr()]).unwrap();
    let resolver = topology.clone();
    let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
        move |e| resolver.endpoint_addr(e),
        ConnConfig::default(),
    ));
    let broker = Arc::new(
        Broker::with_topology(
            BrokerConfig {
                group_size: 1,
                queue_cap: 32,
                policy: QueuePolicy::Block,
                batch_max_records: 4,
                ..BrokerConfig::new(vec![e0.addr()])
            },
            topology.clone(),
            dialer.clone(),
            metrics.clone(),
        )
        .unwrap(),
    );

    // Cloud side: one ElasticReader follows all four streams across
    // endpoints; windowed DMD per stream.
    let engine = Arc::new(
        DmdEngine::new(
            DmdConfig {
                window: WINDOW,
                rank: DMD_RANK,
                hop: 1,
                backend: elasticbroker::analysis::DmdBackend::Rust,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap(),
    );
    let keys: Vec<String> = (0..RANKS).map(|r| format!("synth/{r}")).collect();
    let reader = ElasticReader::new(topology.clone(), dialer.clone(), keys, 0).unwrap();
    let (tx, rx) = channel();
    let eng = engine.clone();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(25),
            executors: 4,
            batch_limit: 0,
        },
        vec![reader],
        move |b| eng.process(b),
        tx,
    );

    // --- HPC side: three phases around a scale-out and a scale-in.
    let ctxs: Vec<BrokerCtx> = (0..RANKS).map(|r| broker.init("synth", r).unwrap()).collect();
    write_phase(&ctxs, 0, 7);

    let (slot, epoch2) = topology.scale_out(e1.addr()).unwrap();
    assert_eq!(slot, 1);
    assert_eq!(epoch2, 2);
    write_phase(&ctxs, 7, 14);
    {
        // mid-run checkpoint: the rebalance moved two groups onto e1
        let t = topology.snapshot();
        assert_eq!(t.groups_of_endpoint(0).len(), 2);
        assert_eq!(t.groups_of_endpoint(1).len(), 2);
    }

    let epoch3 = topology.drain_endpoint(1).unwrap();
    assert_eq!(epoch3, 3);
    write_phase(&ctxs, 14, STEPS);
    for c in ctxs {
        c.finalize().unwrap();
    }

    // --- Exactly once across the migrations.
    assert_eq!(metrics.dropped.get(), 0);
    assert_eq!(metrics.shipped.records(), (RANKS as u64) * STEPS);
    assert_eq!(metrics.migrations.get(), 4, "2 groups out + 2 groups back");
    assert_eq!(metrics.handoffs.get(), 4);
    assert_eq!(metrics.stale_rejections.get(), 0, "graceful run: no fencing saves");
    for r in 0..RANKS {
        let key = format!("synth/{r}");
        let s0 = segment_steps(&e0, &key);
        let s1 = segment_steps(&e1, &key);
        let mut union: Vec<u64> = s0.iter().chain(s1.iter()).copied().collect();
        union.sort_unstable();
        assert_eq!(
            union,
            (0..STEPS).collect::<Vec<_>>(),
            "{key}: union of segments must be every step exactly once \
             (e0: {s0:?}, e1: {s1:?})"
        );
    }

    // --- The analysis saw every window fire, in order, no gaps.
    let per_rank = STEPS as usize - WINDOW;
    let expect = per_rank * RANKS as usize;
    let mut results: Vec<AnalysisResult> = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while results.len() < expect && Instant::now() < deadline {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(100)) {
            results.push(res);
        }
    }
    ctx.stop().unwrap();
    results.extend(rx.try_iter().map(|(_, r)| r));
    assert_eq!(results.len(), expect, "analysis count");
    for r in 0..RANKS {
        let key = format!("synth/{r}");
        let mut steps: Vec<u64> = results
            .iter()
            .filter(|a| a.key == key)
            .map(|a| a.step)
            .collect();
        steps.sort_unstable();
        assert_eq!(
            steps,
            (WINDOW as u64..STEPS).collect::<Vec<_>>(),
            "{key}: fire steps have gaps — records were lost or reordered"
        );
    }

    // --- Oracle: the final window's DMD must match the offline
    // reference (≡ a static-topology run; the snapshots are a pure
    // function of (rank, step), so this is the same window a static
    // run would analyse).
    for rank in 0..RANKS {
        let key = format!("synth/{rank}");
        let streamed = results
            .iter()
            .filter(|a| a.key == key)
            .max_by_key(|a| a.step)
            .unwrap();
        assert_eq!(streamed.step, STEPS - 1);
        assert_eq!(streamed.backend, "rust");

        let m1 = WINDOW + 1;
        let mut x = vec![0.0f64; DIM * m1];
        for (j, step) in (STEPS - m1 as u64..STEPS).enumerate() {
            let snap = snapshot(rank, step);
            for i in 0..DIM {
                x[i * m1 + j] = snap[i] as f64;
            }
        }
        let xm = Mat::from_slice(DIM, m1, &x).unwrap();
        let (eigs, sigma, stability) = dmd::analyze_window(&xm, DMD_RANK).unwrap();

        assert!(
            (streamed.stability - stability).abs() <= 1e-6,
            "{key}: stability {} vs offline {}",
            streamed.stability,
            stability
        );
        assert_eq!(streamed.eigs.len(), eigs.len());
        for (a, b) in streamed.eigs.iter().zip(&eigs) {
            assert!(
                (a.re - b.re).abs() <= 1e-6 && (a.im - b.im).abs() <= 1e-6,
                "{key}: eig {a:?} vs offline {b:?}"
            );
        }
        for (a, b) in streamed.sigma.iter().zip(&sigma) {
            assert!((a - b).abs() <= 1e-6, "{key}: sigma {a} vs offline {b}");
        }
    }
}
