//! # ElasticBroker
//!
//! A full reproduction of *ElasticBroker: Combining HPC with Cloud to
//! Provide Realtime Insights into Simulations* (Li, Wang, Yan, Song —
//! ICCS 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The Rust crate is **Layer 3**: the coordination system and every
//! substrate the paper depends on, with Python strictly at build time
//! (`make artifacts` AOT-lowers the Layer-2 JAX models — which call the
//! Layer-1 Pallas kernels — to HLO text that [`runtime`] loads and
//! executes through PJRT).
//!
//! ## Module map
//!
//! HPC side (the paper's §3.1):
//! * [`sim`] — the CFD simulation substrate: a D2Q9 lattice-Boltzmann
//!   *WindAroundBuildings* solver with MPI-style rank decomposition and
//!   halo exchange (stand-in for OpenFOAM `simpleFoam`).
//! * [`broker`] — the ElasticBroker C/C++-style API
//!   (`broker_init` / `broker_write` / `broker_finalize`), process
//!   groups → Cloud endpoints, asynchronous background writers that
//!   coalesce queued records into pipelined batches
//!   (`batch_max_records` / `batch_max_bytes` / `linger_ms`), and the
//!   *elasticity layer*: an epoch-versioned group→endpoint `Topology`,
//!   the epoch-fenced `Shipper` migration protocol (no record loss or
//!   duplication across endpoint changes) and a QoS-driven
//!   `Rebalancer`.
//! * [`synth`] — the synthetic data generator of §4.3.
//!
//! Cloud side (the paper's §3.2):
//! * [`endpoint`] — the Cloud endpoint: a stream store speaking the
//!   RESP wire protocol (stand-in for Redis 5), sharded across
//!   independent locks by stream-name hash, with an optional
//!   durability layer (`endpoint::wal`, the AOF analogue): a
//!   segmented CRC-framed write-ahead log with group-commit fsync,
//!   crash recovery that restores entries *and* fencing state, and
//!   ack-based retention.
//! * [`streamproc`] — the distributed micro-batch stream-processing
//!   engine (stand-in for Spark Streaming on Kubernetes).
//! * [`analysis`] — windowed Dynamic Mode Decomposition of the incoming
//!   streams (stand-in for PyDMD inside Spark executors).
//!
//! Substrates:
//! * [`wire`] — RESP2 protocol codec.
//! * [`record`] — the simulation→Cloud stream-record format.
//! * [`transport`] — framed TCP client with reconnect, throttling and
//!   request pipelining (N commands per round trip); the `Conn`/`Dialer`
//!   abstraction with a deterministic fault-injecting in-process
//!   implementation (`transport::sim`) for the elasticity tests.
//! * [`runtime`] — PJRT artifact registry / executor (the AOT bridge;
//!   a no-op stub unless the `pjrt` cargo feature is enabled).
//! * [`linalg`] — dense eigensolvers (Francis QR) for the DMD spectra.
//! * [`metrics`], [`config`], [`util`] — observability, configuration,
//!   logging/rng/property-test helpers.
//!
//! [`workflow`] wires whole experiments together; `main.rs`/[`cli`]
//! expose them as a launcher.

pub mod analysis;
pub mod broker;
pub mod cli;
pub mod config;
pub mod endpoint;
pub mod linalg;
pub mod metrics;
pub mod record;
pub mod runtime;
pub mod sim;
pub mod streamproc;
pub mod synth;
pub mod transport;
pub mod util;
pub mod wire;
pub mod workflow;

/// Crate-wide result type (anyhow-based, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
