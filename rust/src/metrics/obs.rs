//! The flight recorder (ISSUE 9): a hierarchical metric [`Registry`]
//! with Prometheus-text and JSONL renderers, per-hop staleness
//! [`TraceMetrics`], and the bounded control-plane [`EventJournal`].
//!
//! The registry holds handles (`Arc`s) to the same atomics the hot
//! paths already record into — registration happens once at wiring
//! time and rendering is pull-only, so nothing here adds work to the
//! write/ship/poll paths.  Composite bundles ([`StageMetrics`],
//! [`AdaptMetrics`], [`EndpointStats`]) register as one entry and
//! expand into their sub-metrics at render time.

use std::collections::VecDeque;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::{AdaptMetrics, Counter, EndpointStats, Gauge, Histogram, StageMetrics, Throughput};

/// Escape `s` for inclusion inside a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// A registered metric handle.  Composite variants expand into dotted
/// sub-names when rendered.
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    Throughput(Arc<Throughput>),
    Stages(Arc<StageMetrics>),
    Adapt(Arc<AdaptMetrics>),
    Endpoint(Arc<EndpointStats>),
}

/// Windowed-rate cadence used when rendering [`Metric::Throughput`]
/// entries (mirrors the `QosBoard::sweep` snapshot cadence).
const RATE_WINDOW: std::time::Duration = std::time::Duration::from_millis(250);

/// Hierarchical metric registry: insertion-ordered `(dotted name,
/// handle)` pairs.  Registration replaces an existing name (idempotent
/// re-wiring); rendering walks the list and reads the live atomics.
#[derive(Default)]
pub struct Registry {
    entries: RwLock<Vec<(String, Metric)>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `metric` under `name` (dotted hierarchy, e.g.
    /// `"broker.flush_us"`).  Last registration of a name wins.
    pub fn register(&self, name: &str, metric: Metric) {
        let mut entries = self.entries.write().unwrap();
        if let Some(e) = entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = metric;
        } else {
            entries.push((name.to_string(), metric));
        }
    }

    /// Number of registered entries (composites count once).
    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand composites into flat `(name, Metric)` leaves, where every
    /// leaf is a Counter/Gauge/Histogram/Throughput.
    fn leaves(&self) -> Vec<(String, Metric)> {
        let entries = self.entries.read().unwrap().clone();
        let mut out = Vec::with_capacity(entries.len() * 2);
        for (name, m) in entries {
            match m {
                Metric::Stages(s) => {
                    // composite fields are not individually Arc'd, so
                    // the expansion snapshots them by value
                    for (k, c) in [
                        ("records_in", &s.records_in),
                        ("records_filtered", &s.records_filtered),
                        ("bytes_in", &s.bytes_in),
                        ("bytes_out", &s.bytes_out),
                    ] {
                        out.push((format!("{name}.{k}"), Metric::Counter(snapshot_counter(c))));
                    }
                    for (k, h) in [
                        ("filter_us", &s.filter_us),
                        ("aggregate_us", &s.aggregate_us),
                        ("convert_us", &s.convert_us),
                        ("compress_us", &s.compress_us),
                    ] {
                        out.push((format!("{name}.{k}"), Metric::Histogram(snapshot_hist(h))));
                    }
                }
                Metric::Adapt(a) => {
                    for (k, c) in [
                        ("steps_down", &a.steps_down),
                        ("steps_up", &a.steps_up),
                        ("holds", &a.holds),
                        ("err_rejections", &a.err_rejections),
                    ] {
                        out.push((format!("{name}.{k}"), Metric::Counter(snapshot_counter(c))));
                    }
                    for (lvl, n) in a.dwell_counts().into_iter().enumerate() {
                        let c = Arc::new(Counter::new());
                        c.add(n);
                        out.push((format!("{name}.dwell.{lvl}"), Metric::Counter(c)));
                    }
                }
                Metric::Endpoint(e) => {
                    out.push((
                        format!("{name}.flush_us"),
                        Metric::Histogram(snapshot_hist(&e.flush_us)),
                    ));
                    for (k, c) in [
                        ("reconnects", &e.reconnects),
                        ("bytes_read", &e.bytes_read),
                        ("bytes_written", &e.bytes_written),
                        ("accept_errors", &e.accept_errors),
                    ] {
                        out.push((format!("{name}.{k}"), Metric::Counter(snapshot_counter(c))));
                    }
                    for (k, g) in [
                        ("queue_depth", &e.queue_depth),
                        ("durable", &e.durable),
                        ("connections", &e.connections),
                    ] {
                        let live = Arc::new(Gauge::new());
                        live.set(g.get());
                        out.push((format!("{name}.{k}"), Metric::Gauge(live)));
                    }
                }
                leaf => out.push((name, leaf)),
            }
        }
        out
    }

    /// Render the Prometheus text exposition format (what the endpoint
    /// `METRICS` wire command serves).  Dotted names become
    /// `eb_`-prefixed underscore names; histograms render as summaries.
    pub fn render_prometheus(&self, out: &mut String) {
        use std::fmt::Write as _;
        for (name, m) in self.leaves() {
            let pname = prom_name(&name);
            match m {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {pname} counter");
                    let _ = writeln!(out, "{pname} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {pname} gauge");
                    let _ = writeln!(out, "{pname} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = writeln!(out, "# TYPE {pname} summary");
                    for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{pname}{{quantile=\"{qs}\"}} {}",
                            h.quantile(q)
                        );
                    }
                    let _ = writeln!(out, "{pname}_count {}", h.count());
                    let _ = writeln!(out, "{pname}_sum {}", h.sum());
                    let _ = writeln!(out, "{pname}_max {}", h.max());
                }
                Metric::Throughput(t) => {
                    let (bps, rps) = t.windowed_rates(RATE_WINDOW);
                    let _ = writeln!(out, "# TYPE {pname}_bytes_total counter");
                    let _ = writeln!(out, "{pname}_bytes_total {}", t.bytes());
                    let _ = writeln!(out, "# TYPE {pname}_records_total counter");
                    let _ = writeln!(out, "{pname}_records_total {}", t.records());
                    let _ = writeln!(out, "# TYPE {pname}_bytes_per_sec gauge");
                    let _ = writeln!(out, "{pname}_bytes_per_sec {bps:.1}");
                    let _ = writeln!(out, "# TYPE {pname}_records_per_sec gauge");
                    let _ = writeln!(out, "{pname}_records_per_sec {rps:.1}");
                }
                _ => unreachable!("leaves() expands composites"),
            }
        }
    }

    /// Render one JSONL snapshot line (no trailing newline):
    /// `{"ts_us":…,"metrics":{"broker.flush_us":{…},…}}`.
    pub fn snapshot_json(&self, ts_us: u64, out: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(out, "{{\"ts_us\":{ts_us},\"metrics\":{{");
        for (i, (name, m)) in self.leaves().into_iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\":", json_escape(&name));
            match m {
                Metric::Counter(c) => {
                    let _ = write!(out, "{}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = write!(out, "{}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = write!(
                        out,
                        "{{\"count\":{},\"mean\":{:.1},\"p50\":{},\"p95\":{},\
                         \"p99\":{},\"max\":{}}}",
                        h.count(),
                        h.mean(),
                        h.quantile(0.5),
                        h.quantile(0.95),
                        h.quantile(0.99),
                        h.max()
                    );
                }
                Metric::Throughput(t) => {
                    let (bps, rps) = t.windowed_rates(RATE_WINDOW);
                    let _ = write!(
                        out,
                        "{{\"bytes\":{},\"records\":{},\"bytes_per_sec\":{bps:.1},\
                         \"records_per_sec\":{rps:.1}}}",
                        t.bytes(),
                        t.records()
                    );
                }
                _ => unreachable!("leaves() expands composites"),
            }
        }
        out.push_str("}}");
    }
}

fn snapshot_counter(c: &Counter) -> Arc<Counter> {
    let live = Arc::new(Counter::new());
    live.add(c.get());
    live
}

/// Value-snapshot of a histogram that is a *field* of a composite
/// bundle (not individually `Arc`'d): bucket counts and count/sum/
/// min/max are copied once into a fresh histogram the renderer owns.
fn snapshot_hist(h: &Histogram) -> Arc<Histogram> {
    let s = Histogram::new();
    s.copy_from(h);
    Arc::new(s)
}

fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 3);
    out.push_str("eb_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// Per-hop latency histograms for the sampled end-to-end staleness
/// trace (ISSUE 9).  All values are µs.  Every histogram is fed only
/// by records whose frame carries a [`crate::record::Trace`] stamp —
/// the unsampled hot path records nothing here.
#[derive(Default)]
pub struct TraceMetrics {
    /// Records stamped with a trace (the 1-in-N sample).
    pub sampled: Arc<Counter>,
    /// origin → broker enqueue (stage pipeline + queue admission).
    pub hop_enqueue_us: Arc<Histogram>,
    /// enqueue → batch flush encode (broker queue wait).
    pub hop_queue_us: Arc<Histogram>,
    /// flush → endpoint append ack at the shipper (wire RTT + store).
    pub hop_ack_us: Arc<Histogram>,
    /// flush → store ingest, stamped endpoint-side (one-way wire +
    /// store append; cross-host clock skew applies).
    pub hop_store_us: Arc<Histogram>,
    /// flush → reader decode (store residency + poll + wire out).
    pub hop_deliver_us: Arc<Histogram>,
    /// reader decode → DMD fire (window assembly + trigger wait).
    pub hop_analysis_us: Arc<Histogram>,
    /// origin → DMD fire: the end-to-end staleness of an insight —
    /// the paper's Fig 6 metric, continuously observable.
    pub staleness_us: Arc<Histogram>,
}

impl TraceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register every hop histogram under `prefix` (e.g. `"trace"`).
    pub fn register(&self, registry: &Registry, prefix: &str) {
        registry.register(
            &format!("{prefix}.sampled"),
            Metric::Counter(self.sampled.clone()),
        );
        for (k, h) in [
            ("hop_enqueue_us", &self.hop_enqueue_us),
            ("hop_queue_us", &self.hop_queue_us),
            ("hop_ack_us", &self.hop_ack_us),
            ("hop_store_us", &self.hop_store_us),
            ("hop_deliver_us", &self.hop_deliver_us),
            ("hop_analysis_us", &self.hop_analysis_us),
            ("staleness_us", &self.staleness_us),
        ] {
            registry.register(&format!("{prefix}.{k}"), Metric::Histogram(h.clone()));
        }
    }
}

/// One structured control-plane event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotone sequence number (gap-free; `dropped` counts ring
    /// evictions, not lost sequence numbers).
    pub seq: u64,
    /// µs-since-epoch when the event was emitted.
    pub ts_us: u64,
    /// Dotted kind, e.g. `"adapt.down"`, `"fence.stale"`,
    /// `"wal.rotate"`, `"conn.pause"`.
    pub kind: &'static str,
    /// Pre-rendered JSON *object* with the event's fields (may be
    /// empty).  Stored verbatim; [`Event::to_json`] splices it.
    pub detail: String,
}

impl Event {
    /// The event as one JSON object line (no trailing newline).
    pub fn to_json(&self) -> String {
        let head = format!(
            "{{\"seq\":{},\"ts_us\":{},\"kind\":\"{}\"",
            self.seq, self.ts_us, self.kind
        );
        let d = self.detail.trim();
        if let Some(body) = d.strip_prefix('{').and_then(|b| b.strip_suffix('}')) {
            let body = body.trim();
            if body.is_empty() {
                format!("{head}}}")
            } else {
                format!("{head},{body}}}")
            }
        } else if d.is_empty() {
            format!("{head}}}")
        } else {
            format!("{head},\"detail\":\"{}\"}}", json_escape(d))
        }
    }
}

/// Bounded in-memory ring + optional JSONL sink of control-plane
/// events (ISSUE 9): topology epoch bumps, rebalancer decisions with
/// their QoS evidence, adapt transitions, writer fencing, WAL
/// rotation/GC, reconnects, backpressure pause/resume.  Emission is a
/// short mutex hold plus an optional buffered file write — all call
/// sites are control-plane (per-decision, not per-record).
pub struct EventJournal {
    seq: AtomicU64,
    cap: AtomicUsize,
    ring: Mutex<VecDeque<Event>>,
    /// Events evicted from the ring (still in the sink, if any).
    pub dropped: Arc<Counter>,
    sink: Mutex<Option<std::io::BufWriter<std::fs::File>>>,
}

impl Default for EventJournal {
    fn default() -> Self {
        Self::new(1024)
    }
}

impl EventJournal {
    pub fn new(cap: usize) -> Self {
        EventJournal {
            seq: AtomicU64::new(0),
            cap: AtomicUsize::new(cap.max(1)),
            ring: Mutex::new(VecDeque::new()),
            dropped: Arc::new(Counter::new()),
            sink: Mutex::new(None),
        }
    }

    /// Resize the ring (config wiring happens after construction).
    pub fn set_capacity(&self, cap: usize) {
        self.cap.store(cap.max(1), Ordering::Relaxed);
    }

    /// Attach a JSONL sink file (append mode); every subsequent emit
    /// also writes one line there.
    pub fn set_sink(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        *self.sink.lock().unwrap() = Some(std::io::BufWriter::new(f));
        Ok(())
    }

    /// Emit one event.  `detail` must be a JSON object (`"{…}"`) or
    /// empty; use [`json_escape`] for embedded strings.
    pub fn emit(&self, kind: &'static str, detail: String) {
        let ev = Event {
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            ts_us: crate::util::epoch_micros(),
            kind,
            detail,
        };
        if let Some(w) = self.sink.lock().unwrap().as_mut() {
            let _ = writeln!(w, "{}", ev.to_json());
        }
        let cap = self.cap.load(Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        while ring.len() >= cap {
            ring.pop_front();
            self.dropped.inc();
        }
        ring.push_back(ev);
    }

    /// Total events emitted so far.
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// The most recent `n` events, oldest first (`n = 0` → all
    /// retained).
    pub fn recent(&self, n: usize) -> Vec<Event> {
        let ring = self.ring.lock().unwrap();
        let skip = if n == 0 { 0 } else { ring.len().saturating_sub(n) };
        ring.iter().skip(skip).cloned().collect()
    }

    /// Flush the JSONL sink (end-of-run, snapshot cadence).
    pub fn flush(&self) {
        if let Some(w) = self.sink.lock().unwrap().as_mut() {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_renders_all_leaf_kinds() {
        let r = Registry::new();
        let c = Arc::new(Counter::new());
        c.add(7);
        r.register("broker.dropped", Metric::Counter(c));
        let g = Arc::new(Gauge::new());
        g.set(3);
        r.register("queue.depth", Metric::Gauge(g));
        let h = Arc::new(Histogram::new());
        h.record(100);
        h.record(200);
        r.register("broker.flush_us", Metric::Histogram(h));
        let t = Arc::new(Throughput::new());
        t.record(4096);
        r.register("broker.shipped", Metric::Throughput(t));

        let mut prom = String::new();
        r.render_prometheus(&mut prom);
        assert!(prom.contains("eb_broker_dropped 7"), "{prom}");
        assert!(prom.contains("eb_queue_depth 3"), "{prom}");
        assert!(prom.contains("eb_broker_flush_us{quantile=\"0.95\"}"), "{prom}");
        assert!(prom.contains("eb_broker_flush_us_count 2"), "{prom}");
        assert!(prom.contains("eb_broker_flush_us_sum 300"), "{prom}");
        assert!(prom.contains("eb_broker_shipped_bytes_total 4096"), "{prom}");
        assert!(prom.contains("eb_broker_shipped_records_total 1"), "{prom}");

        let mut json = String::new();
        r.snapshot_json(123, &mut json);
        assert!(json.starts_with("{\"ts_us\":123,"), "{json}");
        assert!(json.contains("\"broker.dropped\":7"), "{json}");
        assert!(json.contains("\"broker.flush_us\":{\"count\":2"), "{json}");
        assert!(json.ends_with("}}"), "{json}");
    }

    #[test]
    fn registry_expands_composites() {
        let r = Registry::new();
        let s = Arc::new(StageMetrics::new());
        s.records_in.add(5);
        s.compress_us.record(42);
        r.register("stages", Metric::Stages(s));
        let a = Arc::new(AdaptMetrics::new());
        a.steps_down.inc();
        a.dwell(1).inc();
        r.register("adapt", Metric::Adapt(a));
        let e = Arc::new(EndpointStats::new());
        e.flush_us.record(1000);
        e.connections.set(2);
        r.register("endpoint0", Metric::Endpoint(e));

        let mut prom = String::new();
        r.render_prometheus(&mut prom);
        assert!(prom.contains("eb_stages_records_in 5"), "{prom}");
        assert!(prom.contains("eb_stages_compress_us_count 1"), "{prom}");
        assert!(prom.contains("eb_adapt_steps_down 1"), "{prom}");
        assert!(prom.contains("eb_adapt_dwell_1 1"), "{prom}");
        assert!(prom.contains("eb_endpoint0_flush_us_count 1"), "{prom}");
        assert!(prom.contains("eb_endpoint0_connections 2"), "{prom}");
    }

    #[test]
    fn registry_reregistration_replaces() {
        let r = Registry::new();
        let a = Arc::new(Counter::new());
        a.add(1);
        r.register("x", Metric::Counter(a));
        let b = Arc::new(Counter::new());
        b.add(9);
        r.register("x", Metric::Counter(b));
        assert_eq!(r.len(), 1);
        let mut prom = String::new();
        r.render_prometheus(&mut prom);
        assert!(prom.contains("eb_x 9"), "{prom}");
    }

    #[test]
    fn event_journal_ring_bounds_and_sink() {
        let j = EventJournal::new(3);
        for i in 0..5u64 {
            j.emit("test.tick", format!("{{\"i\":{i}}}"));
        }
        assert_eq!(j.total(), 5);
        assert_eq!(j.dropped.get(), 2);
        let recent = j.recent(0);
        assert_eq!(recent.len(), 3);
        assert_eq!(recent[0].detail, "{\"i\":2}");
        assert_eq!(recent[2].seq, 4);
        // seq stays monotone and to_json splices the detail object
        let line = recent[2].to_json();
        assert!(line.starts_with("{\"seq\":4,"), "{line}");
        assert!(line.contains("\"kind\":\"test.tick\""), "{line}");
        assert!(line.ends_with(",\"i\":4}"), "{line}");

        // JSONL sink gets every emit, ring evictions included
        let dir = std::env::temp_dir().join(format!("eb-obs-{}", std::process::id()));
        let path = dir.join("events.jsonl");
        let _ = std::fs::remove_file(&path);
        let j2 = EventJournal::new(2);
        j2.set_sink(&path).unwrap();
        for i in 0..4u64 {
            j2.emit("test.tick", format!("{{\"i\":{i}}}"));
        }
        j2.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4, "{text}");
        assert!(text.lines().next().unwrap().contains("\"i\":0"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn event_to_json_escapes_plain_detail() {
        let ev = Event {
            seq: 0,
            ts_us: 1,
            kind: "x",
            detail: "said \"hi\"".into(),
        };
        assert_eq!(
            ev.to_json(),
            "{\"seq\":0,\"ts_us\":1,\"kind\":\"x\",\"detail\":\"said \\\"hi\\\"\"}"
        );
    }

    #[test]
    fn json_escape_handles_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn trace_metrics_register_under_prefix() {
        let r = Registry::new();
        let t = TraceMetrics::new();
        t.staleness_us.record(5000);
        t.register(&r, "trace");
        let mut prom = String::new();
        r.render_prometheus(&mut prom);
        assert!(prom.contains("eb_trace_staleness_us_count 1"), "{prom}");
        assert!(prom.contains("eb_trace_hop_queue_us_count 0"), "{prom}");
        assert!(prom.contains("eb_trace_sampled 0"), "{prom}");
    }
}
