//! Lightweight observability: counters, latency histograms and
//! throughput meters.  Everything is lock-free on the hot path (atomics)
//! because the broker writer threads and endpoint connection threads
//! record into these concurrently.
//!
//! The flight-recorder layer (ISSUE 9) lives in [`obs`]: a
//! hierarchical [`Registry`] every metric here is registered into, the
//! per-hop staleness [`TraceMetrics`], and the control-plane
//! [`EventJournal`].

pub mod obs;

pub use obs::{Event, EventJournal, Metric, Registry, TraceMetrics};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Monotonic event counter.
#[derive(Default, Debug)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn inc(&self) {
        self.add(1)
    }
    pub fn add(&self, v: u64) {
        self.n.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }
}

/// Log-linear histogram: 64 power-of-two major buckets × 16 linear
/// sub-buckets (HdrHistogram-lite).  Records are µs values in the
/// latency paths; quantile error is bounded by 1/16 ≈ 6% per bucket,
/// plenty for the Fig 7a latency table.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
    /// Max sample since the last [`windowed_quantile`] drain — the
    /// clamp that keeps a windowed quantile from reporting a bucket
    /// upper edge no real sample ever reached (ISSUE 6 bugfix).
    ///
    /// [`windowed_quantile`]: Histogram::windowed_quantile
    win_max: AtomicU64,
}

const SUB: usize = 16;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64 * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            win_max: AtomicU64::new(0),
        }
    }

    fn index(v: u64) -> usize {
        let v = v.max(1);
        let major = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let sub = if major == 0 {
            0
        } else {
            // top `log2(SUB)` bits below the leading bit
            ((v >> major.saturating_sub(4)) as usize) & (SUB - 1)
        };
        (major * SUB + sub).min(64 * SUB - 1)
    }

    /// Representative (upper-edge) value of a bucket index.  Saturating:
    /// the top bucket's nominal upper edge (2^63 + 2^63) would otherwise
    /// overflow u64.
    fn value(idx: usize) -> u64 {
        let major = idx / SUB;
        let sub = idx % SUB;
        if major < 4 {
            return 1u64 << major;
        }
        (1u64 << major).saturating_add((sub as u64 + 1) << (major - 4))
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.win_max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values (the Prometheus `_sum` series).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Copy another histogram's state into this one (bucket counts,
    /// count/sum/min/max) — the registry's value-snapshot of composite
    /// bundle fields.  Not atomic across buckets; renders are
    /// best-effort reads of live counters anyway.
    pub fn copy_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.store(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.store(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.store(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.store(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        let c = self.count();
        if c == 0 {
            0
        } else {
            self.max.load(Ordering::Relaxed)
        }
    }

    pub fn min(&self) -> u64 {
        let c = self.count();
        if c == 0 {
            0
        } else {
            self.min.load(Ordering::Relaxed)
        }
    }

    /// Approximate quantile (0.0 ..= 1.0).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::value(i).min(self.max());
            }
        }
        self.max()
    }

    /// Quantile over only the samples recorded since `prev` was last
    /// captured — the windowed view control loops need (a lifetime
    /// quantile never decays, so a brief slow spell would otherwise
    /// look like permanent saturation).  Updates `prev` to the current
    /// bucket counts.
    ///
    /// Returns `None` when no new samples arrived in the window.  An
    /// empty window is a *stall*, not "fast" (ISSUE 8 bugfix: the old
    /// `0` return was indistinguishable from a healthy sub-µs flush, so
    /// a controller watching it would happily walk fidelity back up
    /// while the link was wedged).  Callers decide what silence means:
    /// the rebalancer treats it as quiet, the adapt controller holds.
    ///
    /// The result is clamped to the max sample seen in the window
    /// (mirroring how the lifetime [`quantile`] clamps with
    /// [`Histogram::max`]) — without the clamp a single last-bucket
    /// sample would report the bucket's upper edge (up to 2^63), and
    /// the rebalancer would shed a healthy endpoint off one borderline
    /// flush.
    ///
    /// [`quantile`]: Histogram::quantile
    pub fn windowed_quantile(&self, prev: &mut Vec<u64>, q: f64) -> Option<u64> {
        let n = self.buckets.len();
        if prev.len() != n {
            prev.clear();
            prev.resize(n, 0);
        }
        let mut deltas = vec![0u64; n];
        let mut total = 0u64;
        for (i, d) in deltas.iter_mut().enumerate() {
            let cur = self.buckets[i].load(Ordering::Relaxed);
            *d = cur.saturating_sub(prev[i]);
            total += *d;
            prev[i] = cur;
        }
        if total == 0 {
            return None;
        }
        // Drain the windowed max; a racing `record` may have bumped the
        // bucket but not yet the max, so 0 means "no clamp available".
        let wmax = self.win_max.swap(0, Ordering::Relaxed);
        let cap = if wmax == 0 { u64::MAX } else { wmax };
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, d) in deltas.iter().enumerate() {
            seen += d;
            if seen >= target {
                return Some(Self::value(i).min(cap));
            }
        }
        Some(Self::value(n - 1).min(cap))
    }

    /// Compact single-line summary for bench tables.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1} p50={} p95={} p99={} max={}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Last-value gauge with a high-watermark (`set_max`) mode, for
/// sampled quantities like queue depth where the *peak since the last
/// rebalancer sweep* is the interesting signal.
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }
    /// Raise the gauge to `v` if higher (concurrent writers keep the max).
    pub fn set_max(&self, v: u64) {
        self.v.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
    /// Read and reset to zero (one sweep's worth of signal).
    pub fn take(&self) -> u64 {
        self.v.swap(0, Ordering::Relaxed)
    }
}

/// Per-endpoint QoS signals the [`crate::broker::Rebalancer`] watches:
/// flush latency, reconnect pressure and peak writer-queue depth, all
/// recorded by the broker writer threads against the endpoint they are
/// currently shipping to.
#[derive(Default)]
pub struct EndpointStats {
    /// Batch flush latency to this endpoint (µs).
    pub flush_us: Histogram,
    /// Reconnect attempts against this endpoint (successes + failures);
    /// a dead endpoint shows up as a burst of these.
    pub reconnects: Counter,
    /// Peak writer-queue depth observed since the last rebalancer sweep
    /// (set via [`Gauge::set_max`], drained via [`Gauge::take`]).
    pub queue_depth: Gauge,
    /// 1 when the endpoint persists its streams to a WAL (ISSUE 4) —
    /// set by whoever provisions the endpoint; the rebalancer prefers
    /// durable endpoints as migration targets, ties being equal.
    pub durable: Gauge,
    /// Live connections on the endpoint server (ISSUE 7) — the
    /// rebalancer's view of *reader* pressure, which flush latency
    /// alone (a writer-side signal) cannot see.
    pub connections: Gauge,
    /// Bytes read off endpoint server sockets (commands in).
    pub bytes_read: Counter,
    /// Bytes written to endpoint server sockets (replies out).
    pub bytes_written: Counter,
    /// Connections refused/dropped by the accept path (accept(2)
    /// errors, per-shard connection cap sheds, registration failures).
    pub accept_errors: Counter,
}

impl EndpointStats {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One endpoint's QoS over one sweep window — the shared snapshot every
/// sampler (rebalancer, adapt controller) reads.
#[derive(Clone, Copy, Debug, Default)]
pub struct QosSample {
    /// Windowed flush p95 (µs); `None` when no flushes landed in the
    /// window — a stall, not "fast" (see
    /// [`Histogram::windowed_quantile`]).
    pub flush_p95_us: Option<u64>,
    /// Peak writer-queue depth observed during the window.
    pub queue_depth: u64,
    /// *Cumulative* reconnect count — consumers that want a per-sweep
    /// delta keep their own last-seen value (deltas are consumer-local
    /// because consumers sweep at different cadences).
    pub reconnects_total: u64,
    /// Endpoint persists to a WAL.
    pub durable: bool,
}

/// A whole board's worth of [`QosSample`]s from one destructive drain.
#[derive(Clone, Debug, Default)]
pub struct QosSweep {
    /// Monotone drain sequence number — two readers holding sweeps with
    /// the same `seq` observed the *same* window.
    pub seq: u64,
    pub samples: Vec<QosSample>,
}

/// Board-owned state behind the shared sweep: the per-endpoint
/// windowed-quantile cursors and the cached last snapshot.
#[derive(Default)]
struct SweepState {
    seq: u64,
    last_drain: Option<Instant>,
    flush_windows: Vec<Vec<u64>>,
    cached: QosSweep,
}

/// Growable slot board of per-endpoint stats, indexed by topology
/// endpoint slot.  Slots are created on first touch and never removed
/// (endpoint indices are stable for a topology's lifetime).
///
/// QoS *sampling* goes through [`QosBoard::sweep`], never through raw
/// `Gauge::take` / `windowed_quantile` on the slots (ISSUE 8 bugfix:
/// those drains are destructively single-reader — with the rebalancer
/// and the adapt controller both sampling, whoever drained second read
/// zeros and never saw pressure).
#[derive(Default)]
pub struct QosBoard {
    slots: std::sync::RwLock<Vec<Arc<EndpointStats>>>,
    sweep: std::sync::Mutex<SweepState>,
}

impl QosBoard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stats slot for endpoint `idx`, growing the board as needed.
    pub fn slot(&self, idx: usize) -> Arc<EndpointStats> {
        {
            let slots = self.slots.read().unwrap();
            if let Some(s) = slots.get(idx) {
                return s.clone();
            }
        }
        let mut slots = self.slots.write().unwrap();
        while slots.len() <= idx {
            slots.push(Arc::new(EndpointStats::new()));
        }
        slots[idx].clone()
    }

    /// Number of slots touched so far.
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sweep-windowed, shareable QoS snapshot.
    ///
    /// The destructive per-slot drains (peak-gauge take, windowed flush
    /// quantile) run at most once per `min_interval`; callers arriving
    /// inside that window get the cached snapshot of the *same* sweep.
    /// This is what lets the rebalancer and the adapt controller sample
    /// concurrently and agree on what they saw.  Pass
    /// `Duration::ZERO` to force a fresh drain (single-sampler tests).
    pub fn sweep(&self, min_interval: Duration) -> QosSweep {
        let slots: Vec<Arc<EndpointStats>> =
            self.slots.read().unwrap().clone();
        let mut st = self.sweep.lock().unwrap();
        let fresh = match st.last_drain {
            None => true,
            Some(t) => t.elapsed() >= min_interval,
        };
        if fresh || st.cached.samples.len() < slots.len() {
            st.seq += 1;
            st.last_drain = Some(Instant::now());
            if st.flush_windows.len() < slots.len() {
                st.flush_windows.resize_with(slots.len(), Vec::new);
            }
            let seq = st.seq;
            let mut samples = Vec::with_capacity(slots.len());
            for (i, slot) in slots.iter().enumerate() {
                let p95 =
                    slot.flush_us.windowed_quantile(&mut st.flush_windows[i], 0.95);
                samples.push(QosSample {
                    flush_p95_us: p95,
                    queue_depth: slot.queue_depth.take(),
                    reconnects_total: slot.reconnects.get(),
                    durable: slot.durable.get() != 0,
                });
            }
            st.cached = QosSweep { seq, samples };
        }
        st.cached.clone()
    }
}

/// Cost and reduction accounting for the broker-side data-reduction
/// stage pipeline (`crate::broker::stages`, ISSUE 5).  Writers record
/// into this concurrently; everything is atomics underneath.
#[derive(Default)]
pub struct StageMetrics {
    /// Records entering the pipeline, before any stage.  Per-field
    /// `broker::Filter` transforms are folded into the filter stage
    /// (ISSUE 6), so `bytes_in` measures the raw snapshot and
    /// `reduction_factor` covers *every* reduction — transforms
    /// included, nothing evades the accounting.
    pub records_in: Counter,
    /// Records the filter stage decided never ship (step decimation /
    /// rank subsetting) — intentional reduction, distinct from the
    /// queue-pressure `dropped` counter.
    pub records_filtered: Counter,
    /// Raw f32 payload bytes entering the pipeline.
    pub bytes_in: Counter,
    /// Encoded payload bytes leaving it — what the wire, the endpoint
    /// store and the WAL actually carry.
    pub bytes_out: Counter,
    /// Per-record filter stage cost (µs).
    pub filter_us: Histogram,
    /// Per-record aggregate stage cost (µs).
    pub aggregate_us: Histogram,
    /// Per-record format-conversion stage cost (µs).
    pub convert_us: Histogram,
    /// Per-record compression stage cost (µs).
    pub compress_us: Histogram,
}

impl StageMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Achieved payload reduction factor so far (≥ 1.0 once data has
    /// flowed; 1.0 before).
    pub fn reduction_factor(&self) -> f64 {
        let out = self.bytes_out.get();
        if out == 0 {
            return 1.0;
        }
        self.bytes_in.get() as f64 / out as f64
    }
}

/// Decision accounting for the closed-loop reduction controller
/// (`crate::broker::adapt`, ISSUE 8).  One bundle per workflow; the
/// per-level dwell board is indexed by ladder level and grows on first
/// touch like [`QosBoard`].
#[derive(Default)]
pub struct AdaptMetrics {
    /// Controller sweeps that walked a stream *down* the ladder
    /// (lossier) under bandwidth pressure.
    pub steps_down: Counter,
    /// Controller sweeps that walked a stream back *up* (more faithful)
    /// after sustained calm.
    pub steps_up: Counter,
    /// Sweeps that held the current level (calm-but-under-hysteresis,
    /// stalled window, or nowhere left to go).
    pub holds: Counter,
    /// Frames whose measured error bound exceeded the stream's accuracy
    /// target — each one permanently disqualified a ladder level and
    /// was re-encoded at a safer one (the write-path admission check).
    pub err_rejections: Counter,
    /// Controller sweeps spent at each ladder level, across streams —
    /// the dwell distribution (`dwell[0]` high = mostly faithful).
    dwell: std::sync::RwLock<Vec<Arc<Counter>>>,
}

impl AdaptMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Dwell counter for ladder `level`, growing the board as needed.
    pub fn dwell(&self, level: usize) -> Arc<Counter> {
        {
            let d = self.dwell.read().unwrap();
            if let Some(c) = d.get(level) {
                return c.clone();
            }
        }
        let mut d = self.dwell.write().unwrap();
        while d.len() <= level {
            d.push(Arc::new(Counter::new()));
        }
        d[level].clone()
    }

    /// Dwell counts per level touched so far.
    pub fn dwell_counts(&self) -> Vec<u64> {
        self.dwell.read().unwrap().iter().map(|c| c.get()).collect()
    }
}

/// Bytes/records meter with since-start averages *and* sweep-windowed
/// rates.
///
/// ISSUE 9 satellite: [`lifetime_bytes_per_sec`] is an average over
/// the whole process lifetime — during a run it lags reality by
/// however long the process has idled, so it must never be labelled a
/// "rate".  Live consumers (the report, the exposition) read
/// [`windowed_rates`], which measures over the interval since the last
/// drain using the same cached-snapshot cadence as [`QosBoard::sweep`].
///
/// [`lifetime_bytes_per_sec`]: Throughput::lifetime_bytes_per_sec
/// [`windowed_rates`]: Throughput::windowed_rates
pub struct Throughput {
    start: Instant,
    bytes: Counter,
    records: Counter,
    win: Mutex<RateWindow>,
}

/// Cursor + cached result behind [`Throughput::windowed_rates`].
#[derive(Default)]
struct RateWindow {
    at: Option<Instant>,
    bytes: u64,
    records: u64,
    rates: (f64, f64),
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            start: Instant::now(),
            bytes: Counter::new(),
            records: Counter::new(),
            win: Mutex::new(RateWindow::default()),
        }
    }

    pub fn record(&self, bytes: u64) {
        self.bytes.add(bytes);
        self.records.inc();
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.get()
    }

    pub fn records(&self) -> u64 {
        self.records.get()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Since-meter-creation average bytes/s — a *lifetime average*, not
    /// a rate (see struct docs).
    pub fn lifetime_bytes_per_sec(&self) -> f64 {
        self.bytes.get() as f64 / self.elapsed_secs().max(1e-9)
    }

    /// Since-meter-creation average records/s (lifetime average).
    pub fn lifetime_records_per_sec(&self) -> f64 {
        self.records.get() as f64 / self.elapsed_secs().max(1e-9)
    }

    /// `(bytes/s, records/s)` over the window since the last drain.
    ///
    /// The drain runs at most once per `min_interval`; callers inside
    /// that window get the cached result of the same window (the
    /// [`QosBoard::sweep`] cadence pattern), so concurrent consumers
    /// do not fragment each other's windows.  The first call returns
    /// the since-start average (there is no window yet).
    pub fn windowed_rates(&self, min_interval: Duration) -> (f64, f64) {
        let now_b = self.bytes.get();
        let now_r = self.records.get();
        let mut w = self.win.lock().unwrap();
        match w.at {
            None => {
                let el = self.start.elapsed().as_secs_f64().max(1e-9);
                w.rates = (now_b as f64 / el, now_r as f64 / el);
                w.at = Some(Instant::now());
                w.bytes = now_b;
                w.records = now_r;
            }
            Some(t) => {
                let el = t.elapsed();
                if el >= min_interval {
                    let secs = el.as_secs_f64().max(1e-9);
                    w.rates = (
                        now_b.saturating_sub(w.bytes) as f64 / secs,
                        now_r.saturating_sub(w.records) as f64 / secs,
                    );
                    w.at = Some(Instant::now());
                    w.bytes = now_b;
                    w.records = now_r;
                }
            }
        }
        w.rates
    }
}

/// Shared metrics bundle threaded through a whole workflow run.
#[derive(Clone)]
pub struct WorkflowMetrics {
    /// broker_write call → enqueued (the simulation-visible cost).
    pub write_call_us: Arc<Histogram>,
    /// record generation → analysis completion (Fig 7a latency).
    pub e2e_latency_us: Arc<Histogram>,
    /// bytes shipped HPC → endpoints.
    pub shipped: Arc<Throughput>,
    /// bytes ingested by analysis executors.
    pub analyzed: Arc<Throughput>,
    /// records dropped by broker queue policy (0 under Block).
    pub dropped: Arc<Counter>,
    /// records per flushed broker batch (1 = no coalescing happened).
    pub batch_records: Arc<Histogram>,
    /// broker batch flush latency µs: drain → every reply drained
    /// (includes OOM backoff stalls, so p99 here surfaces endpoint
    /// pressure).
    pub flush_us: Arc<Histogram>,
    /// per-fire DMD analysis time µs (Gram sync / window assembly +
    /// reduction + eigenvalues + metric — everything a fire pays) — the
    /// Cloud-side cost that must stay under the snapshot inter-arrival
    /// time for the §4.3 QoS story.
    pub analysis_us: Arc<Histogram>,
    /// Data-reduction stage pipeline accounting (bytes in/out, per-
    /// stage µs) — the ISSUE 5 wire/WAL-bytes lever.
    pub stages: Arc<StageMetrics>,
    /// window slides served by the O(d·m) incremental Gram update.
    pub gram_incremental: Arc<Counter>,
    /// full O(d·m²) Gram recomputes (window fill, refresh cadence, or
    /// non-finite fallback).
    pub gram_full: Arc<Counter>,
    /// Per-endpoint QoS board the rebalancer and adapt controller
    /// sample (via [`QosBoard::sweep`]).
    pub qos: Arc<QosBoard>,
    /// Closed-loop reduction controller decisions + per-level dwell
    /// (ISSUE 8).
    pub adapt: Arc<AdaptMetrics>,
    /// Stream migrations completed by broker writers (epoch-fenced
    /// endpoint switches, including rebalancer-driven ones).
    pub migrations: Arc<Counter>,
    /// Writes/HELLOs the broker had rejected as stale-epoch (each one
    /// is a fencing save: a would-be split-brain write that did not
    /// land).
    pub stale_rejections: Arc<Counter>,
    /// Handoff tombstones written during migrations.
    pub handoffs: Arc<Counter>,
    /// Transport reconnect attempts by broker writers (all endpoints).
    pub reconnects: Arc<Counter>,
    /// Frames bounced with `REPL` — the chain head stored the write
    /// but could not reach its successor under tail-ack (ISSUE 10);
    /// each is a writer-side retry while the chain heals.
    pub repl_blocked: Arc<Counter>,
    /// Records dropped on the consumer poll path because their payload
    /// failed to decode (ISSUE 6 bugfix: these were warn-only and
    /// invisible to operators).  Endpoints keep their own server-side
    /// twin, surfaced as `records_corrupt` in `INFO`.
    pub records_corrupt: Arc<Counter>,
    /// Re-registrations where the endpoint's recovered step high-water
    /// mark sat *below* what this writer had already been acked for —
    /// an endpoint restarted from a stale WAL (fsync policy looser than
    /// `always`) lost acked records it can never get back.  Should stay
    /// 0 under `fsync=always`.
    pub replay_gaps: Arc<Counter>,
    /// Hierarchical registry every metric above is registered into
    /// (ISSUE 9) — what the `METRICS` exposition and the JSONL
    /// snapshot writer render.
    pub registry: Arc<Registry>,
    /// Per-hop staleness-trace histograms (ISSUE 9); fed only by
    /// records carrying a sampled [`crate::record::Trace`] stamp.
    pub trace: Arc<TraceMetrics>,
    /// Control-plane event journal (ISSUE 9): ring + optional JSONL
    /// sink of epoch bumps, rebalancer/adapt decisions, fencing, WAL
    /// rotation/GC, reconnects, backpressure pause/resume.
    pub events: Arc<EventJournal>,
}

impl Default for WorkflowMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkflowMetrics {
    pub fn new() -> Self {
        let m = WorkflowMetrics {
            write_call_us: Arc::new(Histogram::new()),
            e2e_latency_us: Arc::new(Histogram::new()),
            shipped: Arc::new(Throughput::new()),
            analyzed: Arc::new(Throughput::new()),
            dropped: Arc::new(Counter::new()),
            batch_records: Arc::new(Histogram::new()),
            flush_us: Arc::new(Histogram::new()),
            analysis_us: Arc::new(Histogram::new()),
            stages: Arc::new(StageMetrics::new()),
            gram_incremental: Arc::new(Counter::new()),
            gram_full: Arc::new(Counter::new()),
            qos: Arc::new(QosBoard::new()),
            adapt: Arc::new(AdaptMetrics::new()),
            migrations: Arc::new(Counter::new()),
            stale_rejections: Arc::new(Counter::new()),
            handoffs: Arc::new(Counter::new()),
            reconnects: Arc::new(Counter::new()),
            repl_blocked: Arc::new(Counter::new()),
            records_corrupt: Arc::new(Counter::new()),
            replay_gaps: Arc::new(Counter::new()),
            registry: Arc::new(Registry::new()),
            trace: Arc::new(TraceMetrics::new()),
            events: Arc::new(EventJournal::default()),
        };
        // Register everything under a stable hierarchical namespace —
        // this is the contract the JSONL snapshots and the `METRICS`
        // exposition serve (ISSUE 9).
        let r = &m.registry;
        r.register("broker.write_call_us", Metric::Histogram(m.write_call_us.clone()));
        r.register("broker.batch_records", Metric::Histogram(m.batch_records.clone()));
        r.register("broker.flush_us", Metric::Histogram(m.flush_us.clone()));
        r.register("broker.shipped", Metric::Throughput(m.shipped.clone()));
        r.register("broker.dropped", Metric::Counter(m.dropped.clone()));
        r.register("broker.migrations", Metric::Counter(m.migrations.clone()));
        r.register("broker.stale_rejections", Metric::Counter(m.stale_rejections.clone()));
        r.register("broker.handoffs", Metric::Counter(m.handoffs.clone()));
        r.register("broker.reconnects", Metric::Counter(m.reconnects.clone()));
        r.register("broker.repl_blocked", Metric::Counter(m.repl_blocked.clone()));
        r.register("broker.replay_gaps", Metric::Counter(m.replay_gaps.clone()));
        r.register("stages", Metric::Stages(m.stages.clone()));
        r.register("adapt", Metric::Adapt(m.adapt.clone()));
        r.register("analysis.analyzed", Metric::Throughput(m.analyzed.clone()));
        r.register("analysis.analysis_us", Metric::Histogram(m.analysis_us.clone()));
        r.register("analysis.e2e_latency_us", Metric::Histogram(m.e2e_latency_us.clone()));
        r.register("analysis.gram_incremental", Metric::Counter(m.gram_incremental.clone()));
        r.register("analysis.gram_full", Metric::Counter(m.gram_full.clone()));
        r.register("reader.records_corrupt", Metric::Counter(m.records_corrupt.clone()));
        m.trace.register(r, "trace");
        r.register("events.dropped", Metric::Counter(m.events.dropped.clone()));
        m
    }

    /// Register endpoint `idx`'s QoS slot under `endpoint<idx>.` so
    /// the exposition and snapshots cover the server side too.
    pub fn register_endpoint(&self, idx: usize) {
        self.registry.register(
            &format!("endpoint{idx}"),
            Metric::Endpoint(self.qos.slot(idx)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, U64Range};

    #[test]
    fn counter_concurrent() {
        let c = Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn histogram_exact_small_values() {
        let h = Histogram::new();
        for v in [1, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bounded_error() {
        let h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, want) in [(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "q={q}: got {got} want {want} (rel {rel:.3})");
        }
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    /// Property: quantile(1.0) never exceeds max; quantile is monotone in q.
    #[test]
    fn prop_quantile_monotone_and_bounded() {
        prop::forall(9, 50, &U64Range(1, 1_000_000), |seed| {
            let h = Histogram::new();
            let mut rng = crate::util::rng::Rng::new(*seed);
            for _ in 0..200 {
                h.record(rng.next_below(10_000_000) + 1);
            }
            let mut prev = 0;
            for i in 0..=10 {
                let q = h.quantile(i as f64 / 10.0);
                if q < prev {
                    return Err(format!("quantile not monotone at {i}: {q} < {prev}"));
                }
                prev = q;
            }
            if h.quantile(1.0) > h.max() {
                return Err("q(1.0) > max".into());
            }
            Ok(())
        });
    }

    /// ISSUE 3: the rebalancer's saturation signal must see only the
    /// last sweep's samples, not the lifetime distribution.
    #[test]
    fn windowed_quantile_sees_only_new_samples() {
        let h = Histogram::new();
        let mut win = Vec::new();
        // warmup: a slow spell
        for _ in 0..100 {
            h.record(1_000_000);
        }
        assert!(h.windowed_quantile(&mut win, 0.95).unwrap() >= 500_000);
        // no new samples → None, even though lifetime p95 stays high
        assert_eq!(h.windowed_quantile(&mut win, 0.95), None);
        assert!(h.quantile(0.95) >= 500_000, "lifetime view unchanged");
        // fast spell: the window reflects it immediately
        for _ in 0..100 {
            h.record(100);
        }
        let w = h.windowed_quantile(&mut win, 0.95).unwrap();
        assert!(w < 10_000, "windowed p95 {w} should be fast");
    }

    /// ISSUE 8 bugfix: an empty window (no flushes this sweep — a
    /// stall) must be distinguishable from a fast one.  The old `0`
    /// return read as "sub-µs flush latency" and would walk the adapt
    /// controller's fidelity back up mid-stall.
    #[test]
    fn windowed_quantile_empty_window_is_none_not_fast() {
        let h = Histogram::new();
        let mut win = Vec::new();
        // never-recorded histogram: None, not 0
        assert_eq!(h.windowed_quantile(&mut win, 0.95), None);
        h.record(500);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), Some(500));
        // stall: two consecutive empty windows both report None
        assert_eq!(h.windowed_quantile(&mut win, 0.95), None);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), None);
        // recovery is visible again
        h.record(700);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), Some(700));
    }

    /// ISSUE 6 bugfix: a windowed quantile must never exceed the max
    /// sample actually recorded in the window.  Before the clamp a
    /// single 249ms flush reported the bucket upper edge (253,952µs) —
    /// over the rebalancer's 250ms default threshold — and a single
    /// top-bucket sample reported ≈2^63.
    #[test]
    fn windowed_quantile_clamps_to_window_max() {
        let h = Histogram::new();
        let mut win = Vec::new();
        h.record(249_000);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), Some(249_000));
        // top-bucket sample: no overflow, no astronomical edge value
        h.record(u64::MAX);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), Some(u64::MAX));
        // windowed max resets between drains: a later fast window is
        // not clamped against (or inflated by) the old spike
        h.record(100);
        assert_eq!(h.windowed_quantile(&mut win, 0.95), Some(100));
    }

    /// The shed decision itself: one borderline-but-under-threshold
    /// flush must not mark an endpoint pressured (the false-shed the
    /// unclamped windowed p95 caused).
    #[test]
    fn single_borderline_flush_does_not_shed() {
        use crate::broker::rebalancer::{evaluate, EndpointSample, QosThresholds};
        use crate::broker::{GroupMap, TopologyHandle};

        let groups = GroupMap::new(4, 2, 2).unwrap();
        let addrs = (0..2)
            .map(|i| format!("127.0.0.1:{}", 7300 + i).parse().unwrap())
            .collect();
        let handle = TopologyHandle::new_static(groups, addrs).unwrap();
        let thr = QosThresholds::default(); // flush_p95_us = 250_000

        let h = Histogram::new();
        let mut win = Vec::new();
        h.record(249_000); // under threshold — endpoint is healthy
        let samples = vec![
            EndpointSample {
                flush_p95_us: h.windowed_quantile(&mut win, 0.95).unwrap_or(0),
                ..Default::default()
            },
            EndpointSample::default(),
        ];
        let plan = evaluate(&handle.snapshot(), &samples, &thr);
        assert!(
            plan.is_empty(),
            "healthy endpoint shed off a borderline flush: {plan:?}"
        );
    }

    #[test]
    fn gauge_set_max_and_take() {
        let g = Gauge::new();
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5);
        g.set_max(9);
        assert_eq!(g.take(), 9);
        assert_eq!(g.get(), 0);
        g.set(4);
        assert_eq!(g.get(), 4);
    }

    /// ISSUE 8 bugfix: two concurrent samplers (rebalancer + adapt
    /// controller) must observe the *same* sweep.  Before the shared
    /// sweep, whoever called `queue_depth.take()` second read 0 and
    /// never saw pressure.
    #[test]
    fn qos_sweep_is_shared_across_concurrent_samplers() {
        let b = QosBoard::new();
        let slot = b.slot(0);
        slot.queue_depth.set_max(42);
        slot.flush_us.record(300_000);
        slot.reconnects.inc();
        slot.durable.set(1);

        // two samplers inside the same min_interval: same sweep
        let a = b.sweep(Duration::from_secs(3600));
        let c = b.sweep(Duration::from_secs(3600));
        assert_eq!(a.seq, c.seq, "second sampler must join the sweep");
        for s in [&a, &c] {
            assert_eq!(s.samples[0].queue_depth, 42, "peak visible to both");
            assert_eq!(s.samples[0].flush_p95_us, Some(300_000));
            assert_eq!(s.samples[0].reconnects_total, 1);
            assert!(s.samples[0].durable);
        }

        // a forced fresh drain starts a new window: peak cleared,
        // no flushes → None (not "fast"), reconnects stay cumulative
        let d = b.sweep(Duration::ZERO);
        assert!(d.seq > a.seq);
        assert_eq!(d.samples[0].queue_depth, 0);
        assert_eq!(d.samples[0].flush_p95_us, None);
        assert_eq!(d.samples[0].reconnects_total, 1);

        // slots added after a sweep show up on the next one even
        // within min_interval (scale-out must not be invisible)
        b.slot(2).queue_depth.set_max(7);
        let e = b.sweep(Duration::from_secs(3600));
        assert_eq!(e.samples.len(), 3);
        assert_eq!(e.samples[2].queue_depth, 7);
    }

    #[test]
    fn adapt_dwell_board_grows_and_counts() {
        let m = AdaptMetrics::new();
        m.dwell(2).inc();
        m.dwell(0).inc();
        m.dwell(2).inc();
        assert_eq!(m.dwell_counts(), vec![1, 0, 2]);
    }

    #[test]
    fn qos_board_grows_and_slots_are_stable() {
        let b = QosBoard::new();
        assert!(b.is_empty());
        let s3 = b.slot(3);
        assert_eq!(b.len(), 4);
        s3.reconnects.inc();
        // same underlying slot on re-fetch
        assert_eq!(b.slot(3).reconnects.get(), 1);
        // earlier slots exist and are independent
        assert_eq!(b.slot(0).reconnects.get(), 0);
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.record(1000);
        t.record(500);
        assert_eq!(t.bytes(), 1500);
        assert_eq!(t.records(), 2);
        assert!(t.lifetime_bytes_per_sec() > 0.0);
    }

    /// ISSUE 9 satellite: windowed rates measure the *last window*, not
    /// the lifetime average — a meter that went quiet must read ~0,
    /// and two consumers inside one cadence window see the same rates.
    #[test]
    fn throughput_windowed_rates_see_the_window_not_the_lifetime() {
        let t = Throughput::new();
        t.record(1_000_000);
        // first call: no window yet → since-start average, cursor set
        let (b0, r0) = t.windowed_rates(Duration::ZERO);
        assert!(b0 > 0.0 && r0 > 0.0);
        // a second consumer inside the cadence window shares the result
        let shared = t.windowed_rates(Duration::from_secs(3600));
        assert_eq!(shared, (b0, r0));
        // quiet spell: a fresh drain must read ~0 even though the
        // lifetime average stays high
        std::thread::sleep(Duration::from_millis(5));
        let (b1, _) = t.windowed_rates(Duration::ZERO);
        assert_eq!(b1, 0.0, "no bytes moved in the window");
        assert!(t.lifetime_bytes_per_sec() > 0.0, "lifetime view unchanged");
        // traffic resumes: visible on the next drain
        t.record(4096);
        std::thread::sleep(Duration::from_millis(2));
        let (b2, r2) = t.windowed_rates(Duration::ZERO);
        assert!(b2 > 0.0 && r2 > 0.0);
    }

    /// ISSUE 9: the workflow bundle self-registers; a render covers
    /// broker, stages, adapt, analysis, trace and events namespaces.
    #[test]
    fn workflow_metrics_self_register() {
        let m = WorkflowMetrics::new();
        m.dropped.inc();
        m.flush_us.record(123);
        m.trace.staleness_us.record(5_000);
        m.register_endpoint(0);
        let mut prom = String::new();
        m.registry.render_prometheus(&mut prom);
        for needle in [
            "eb_broker_dropped 1",
            "eb_broker_flush_us_count 1",
            "eb_stages_records_in 0",
            "eb_adapt_steps_down 0",
            "eb_analysis_e2e_latency_us_count 0",
            "eb_trace_staleness_us_count 1",
            "eb_events_dropped 0",
            "eb_endpoint0_flush_us_count 0",
        ] {
            assert!(prom.contains(needle), "missing {needle} in:\n{prom}");
        }
    }
}
