//! Multi-rank simulation driver: decomposition, halo exchange, I/O.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::geometry;
use super::lbm::{self, LbmParams};
use crate::broker::Broker;
use crate::config::IoMode;
use crate::runtime::{ArtifactSet, Executable};

/// Simulation configuration (a subset of
/// [`crate::config::WorkflowConfig`], decoupled so the sim can run
/// standalone against remote endpoints).
#[derive(Clone)]
pub struct SimConfig {
    pub ranks: usize,
    pub height: usize,
    pub width: usize,
    pub steps: u64,
    pub write_interval: u64,
    pub io_mode: IoMode,
    /// Directory for `IoMode::File` output.
    pub out_dir: String,
    /// Field name registered with the broker.
    pub field: String,
    pub params: LbmParams,
    /// Prefer the PJRT artifact; falls back to pure Rust when absent.
    pub use_pjrt: bool,
    /// Modeled parallel-filesystem commit latency per collated step
    /// (`IoMode::File` only).  Local NVMe fsync is ~2 ms; the paper's
    /// Lustre writes from 16 ranks stall far longer — this knob stands
    /// in for the shared-PFS round trip (DESIGN.md §2).  0 = raw disk.
    pub pfs_commit_ms: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            ranks: 16,
            height: 256,
            width: 128,
            steps: 2000,
            write_interval: 5,
            io_mode: IoMode::None,
            out_dir: "sim_out".into(),
            field: "velocity".into(),
            params: LbmParams::default(),
            use_pjrt: true,
            pfs_commit_ms: 25,
        }
    }
}

/// What a run produced.
pub struct SimReport {
    /// Wall-clock from first step to last rank finished.
    pub elapsed: Duration,
    pub steps: u64,
    pub ranks: usize,
    /// Snapshots written per rank.
    pub writes_per_rank: u64,
    /// Final interior velocity field per rank (`2 × h_loc × w` each) —
    /// used by the examples for visualization and by equivalence tests.
    pub final_u: Vec<Vec<f32>>,
    /// Which backend stepped the lattice ("pjrt" or "rust").
    pub backend: &'static str,
}

/// Messages between ranks: one packed halo row (9 channels × w).
type HaloRow = Vec<f32>;

/// The simulation driver.
pub struct SimRunner;

impl SimRunner {
    /// Run the full simulation; blocks until every rank finishes.
    ///
    /// `broker` must be `Some` when `cfg.io_mode == IoMode::Broker`;
    /// `artifacts` enables the PJRT backend.
    pub fn run(
        cfg: &SimConfig,
        broker: Option<Arc<Broker>>,
        artifacts: Option<Arc<ArtifactSet>>,
    ) -> Result<SimReport> {
        anyhow::ensure!(cfg.ranks > 0, "ranks must be > 0");
        anyhow::ensure!(
            cfg.height % cfg.ranks == 0,
            "height {} not divisible by ranks {}",
            cfg.height,
            cfg.ranks
        );
        let h_loc = cfg.height / cfg.ranks;
        let hp = h_loc + 2;
        let w = cfg.width;

        // Resolve the stepping backend once (shared executable).
        let exe: Option<(Arc<Executable>, Arc<Executable>)> = if cfg.use_pjrt {
            match &artifacts {
                Some(arts) => {
                    let key = format!("h{h_loc}_w{w}");
                    match (arts.executable("lbm_step", &key), arts.executable("lbm_init", &key)) {
                        (Ok(step), Ok(init)) => Some((step, init)),
                        _ => {
                            log::warn!(
                                "sim: no lbm artifacts for key h{h_loc}_w{w}; using Rust fallback"
                            );
                            None
                        }
                    }
                }
                None => None,
            }
        } else {
            None
        };
        let backend = if exe.is_some() { "pjrt" } else { "rust" };

        if cfg.io_mode == IoMode::Broker {
            anyhow::ensure!(
                broker.is_some(),
                "broker required for IoMode::Broker"
            );
        }

        // Geometry.
        let global_mask = geometry::build_mask(cfg.height, w);
        let masks: Vec<Vec<f32>> = (0..cfg.ranks)
            .map(|r| geometry::rank_mask(&global_mask, cfg.height, w, cfg.ranks, r))
            .collect();

        // Halo channels: down[i] carries rank i → i+1; up[i] carries
        // rank i+1 → i.  Capacity 1 keeps ranks in lockstep without
        // blocking the sender.
        let mut down_tx: Vec<Option<SyncSender<HaloRow>>> = vec![None; cfg.ranks];
        let mut down_rx: Vec<Option<Receiver<HaloRow>>> = (0..cfg.ranks).map(|_| None).collect();
        let mut up_tx: Vec<Option<SyncSender<HaloRow>>> = vec![None; cfg.ranks];
        let mut up_rx: Vec<Option<Receiver<HaloRow>>> = (0..cfg.ranks).map(|_| None).collect();
        for i in 0..cfg.ranks.saturating_sub(1) {
            let (dtx, drx) = sync_channel::<HaloRow>(1);
            down_tx[i] = Some(dtx);
            down_rx[i + 1] = Some(drx);
            let (utx, urx) = sync_channel::<HaloRow>(1);
            up_tx[i + 1] = Some(utx);
            up_rx[i] = Some(urx);
        }

        // File-mode collated writer.
        let (file_tx, file_writer) = if cfg.io_mode == IoMode::File {
            std::fs::create_dir_all(&cfg.out_dir)
                .with_context(|| format!("creating {}", cfg.out_dir))?;
            // Rendezvous channel: ranks block until the collated writer
            // accepts their chunk — OpenFOAM's synchronous collated
            // write semantics, which is what makes file-based I/O stall
            // the simulation (Fig 6).
            let (tx, rx) = sync_channel::<(usize, u64, Vec<f32>)>(0);
            let dir = cfg.out_dir.clone();
            let ranks = cfg.ranks;
            let commit_ms = cfg.pfs_commit_ms;
            let writer = std::thread::Builder::new()
                .name("sim-file-writer".into())
                .spawn(move || collated_writer(rx, &dir, ranks, commit_ms))?;
            (Some(tx), Some(writer))
        } else {
            (None, None)
        };

        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(cfg.ranks);
        for rank in 0..cfg.ranks {
            let mask = masks[rank].clone();
            let cfg = cfg.clone();
            let exe = exe.clone();
            let broker = broker.clone();
            let file_tx = file_tx.clone();
            let dtx = down_tx[rank].take();
            let drx = down_rx[rank].take();
            let utx = up_tx[rank].take();
            let urx = up_rx[rank].take();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sim-rank-{rank}"))
                    .spawn(move || -> Result<(u64, Vec<f32>)> {
                        rank_loop(
                            rank, &cfg, hp, w, mask, exe, broker, file_tx, dtx, drx, utx, urx,
                        )
                    })?,
            );
        }
        drop(file_tx);

        let mut writes = 0u64;
        let mut final_u = Vec::with_capacity(cfg.ranks);
        for (rank, h) in handles.into_iter().enumerate() {
            let (w_count, u) = h
                .join()
                .map_err(|_| anyhow::anyhow!("sim rank {rank} panicked"))?
                .with_context(|| format!("sim rank {rank} failed"))?;
            writes = w_count; // identical across ranks
            final_u.push(u);
        }
        if let Some(fw) = file_writer {
            fw.join()
                .map_err(|_| anyhow::anyhow!("file writer panicked"))??;
        }
        let elapsed = t0.elapsed();
        log::info!(
            "sim: {} ranks × {} steps ({}x{}) in {:.2}s [{}] io={}",
            cfg.ranks,
            cfg.steps,
            cfg.height,
            cfg.width,
            elapsed.as_secs_f64(),
            backend,
            cfg.io_mode.name(),
        );
        Ok(SimReport {
            elapsed,
            steps: cfg.steps,
            ranks: cfg.ranks,
            writes_per_rank: writes,
            final_u,
            backend,
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn rank_loop(
    rank: usize,
    cfg: &SimConfig,
    hp: usize,
    w: usize,
    mask: Vec<f32>,
    exe: Option<(Arc<Executable>, Arc<Executable>)>,
    broker: Option<Arc<Broker>>,
    file_tx: Option<SyncSender<(usize, u64, Vec<f32>)>>,
    down_tx: Option<SyncSender<HaloRow>>,
    down_rx: Option<Receiver<HaloRow>>,
    up_tx: Option<SyncSender<HaloRow>>,
    up_rx: Option<Receiver<HaloRow>>,
) -> Result<(u64, Vec<f32>)> {
    let plane = hp * w;
    let h_loc = hp - 2;

    // Initial state (PJRT init artifact or Rust mirror — identical).
    let mut f: Vec<f32> = match &exe {
        Some((_, init_exe)) => init_exe.run_f32(&[&mask])?.remove(0),
        None => lbm::init(&mask, hp, w, cfg.params),
    };

    // Broker context for this rank (the paper's broker_init).
    let ctx = match (&cfg.io_mode, &broker) {
        (IoMode::Broker, Some(b)) => Some(b.init(&cfg.field, rank as u32)?),
        _ => None,
    };

    let mut scratch: Vec<f32> = Vec::new();
    let mut u: Vec<f32> = vec![0.0; 2 * h_loc * w];
    let mut writes = 0u64;

    for step in 1..=cfg.steps {
        // Advance one lattice step.
        match &exe {
            Some((step_exe, _)) => {
                let mut out = step_exe.run_f32(&[&f, &mask])?;
                u = out.pop().context("missing u output")?;
                f = out.pop().context("missing f output")?;
            }
            None => {
                u = lbm::step(&mut f, &mask, hp, w, cfg.params, true, &mut scratch);
            }
        }

        // Halo exchange (send first; capacity-1 channels never block
        // because each is drained every step).
        if let Some(tx) = &up_tx {
            tx.send(pack_row(&f, plane, w, 1))
                .map_err(|_| anyhow::anyhow!("up neighbour of rank {rank} gone"))?;
        }
        if let Some(tx) = &down_tx {
            tx.send(pack_row(&f, plane, w, hp - 2))
                .map_err(|_| anyhow::anyhow!("down neighbour of rank {rank} gone"))?;
        }
        if let Some(rx) = &down_rx {
            let row = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("halo recv from above failed at rank {rank}"))?;
            unpack_row(&mut f, plane, w, 0, &row);
        }
        if let Some(rx) = &up_rx {
            let row = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("halo recv from below failed at rank {rank}"))?;
            unpack_row(&mut f, plane, w, hp - 1, &row);
        }

        // I/O at the write interval (the paper's runTime().write()
        // replacement).
        if step % cfg.write_interval == 0 {
            writes += 1;
            match cfg.io_mode {
                IoMode::Broker => {
                    ctx.as_ref()
                        .unwrap()
                        .write(step, &[2, h_loc as u32, w as u32], &u)?;
                }
                IoMode::File => {
                    file_tx
                        .as_ref()
                        .unwrap()
                        .send((rank, step, u.clone()))
                        .map_err(|_| anyhow::anyhow!("file writer gone"))?;
                }
                IoMode::None => {}
            }
        }
    }

    if let Some(ctx) = ctx {
        ctx.finalize()?;
    }
    Ok((writes, u))
}

fn pack_row(f: &[f32], plane: usize, w: usize, y: usize) -> HaloRow {
    let mut out = Vec::with_capacity(9 * w);
    for c in 0..9 {
        out.extend_from_slice(&f[c * plane + y * w..c * plane + (y + 1) * w]);
    }
    out
}

fn unpack_row(f: &mut [f32], plane: usize, w: usize, y: usize, row: &HaloRow) {
    debug_assert_eq!(row.len(), 9 * w);
    for c in 0..9 {
        f[c * plane + y * w..c * plane + (y + 1) * w]
            .copy_from_slice(&row[c * w..(c + 1) * w]);
    }
}

/// Collated file writer: assembles all ranks of a step into one file
/// (the paper's OpenFOAM "collated" Lustre write), fsyncing each file
/// to model the parallel-filesystem commit the paper pays for.
fn collated_writer(
    rx: Receiver<(usize, u64, Vec<f32>)>,
    dir: &str,
    ranks: usize,
    commit_ms: u64,
) -> Result<()> {
    let mut pending: BTreeMap<u64, Vec<Option<Vec<f32>>>> = BTreeMap::new();
    while let Ok((rank, step, data)) = rx.recv() {
        let slot = pending
            .entry(step)
            .or_insert_with(|| vec![None; ranks]);
        slot[rank] = Some(data);
        if slot.iter().all(|s| s.is_some()) {
            let chunks = pending.remove(&step).unwrap();
            let path = format!("{dir}/step_{step:06}.bin");
            let mut file = std::fs::File::create(&path)
                .with_context(|| format!("creating {path}"))?;
            let mut buf = Vec::new();
            for chunk in chunks.into_iter().flatten() {
                for v in chunk {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            file.write_all(&buf)?;
            file.sync_all()?; // local durability
            if commit_ms > 0 {
                // modeled shared-PFS commit latency (see SimConfig docs)
                std::thread::sleep(std::time::Duration::from_millis(commit_ms));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(ranks: usize, io: IoMode) -> SimConfig {
        SimConfig {
            ranks,
            height: 32,
            width: 64,
            steps: 40,
            write_interval: 10,
            io_mode: io,
            out_dir: std::env::temp_dir()
                .join(format!("eb-sim-{}-{ranks}", std::process::id()))
                .to_string_lossy()
                .into_owned(),
            field: "velocity".into(),
            params: LbmParams::default(),
            use_pjrt: false, // unit tests use the Rust mirror
            pfs_commit_ms: 0, // raw local disk in unit tests
        }
    }

    #[test]
    fn single_rank_runs_and_reports() {
        let cfg = small_cfg(1, IoMode::None);
        let rep = SimRunner::run(&cfg, None, None).unwrap();
        assert_eq!(rep.ranks, 1);
        assert_eq!(rep.writes_per_rank, 4);
        assert_eq!(rep.final_u.len(), 1);
        assert_eq!(rep.final_u[0].len(), 2 * 32 * 64);
        assert!(rep.final_u[0].iter().all(|v| v.is_finite()));
        assert_eq!(rep.backend, "rust");
    }

    #[test]
    fn multi_rank_matches_single_rank() {
        // The decomposition invariant: N ranks with halo exchange must
        // reproduce the single-rank whole-domain run.
        let rep1 = SimRunner::run(&small_cfg(1, IoMode::None), None, None).unwrap();
        let rep4 = SimRunner::run(&small_cfg(4, IoMode::None), None, None).unwrap();
        let whole = &rep1.final_u[0]; // (2, 32, 64)
        let (h, w) = (32usize, 64usize);
        let h_loc = h / 4;
        for rank in 0..4 {
            let part = &rep4.final_u[rank]; // (2, 8, 64)
            for comp in 0..2 {
                for y in 0..h_loc {
                    for x in 0..w {
                        let got = part[comp * h_loc * w + y * w + x];
                        let want = whole[comp * h * w + (rank * h_loc + y) * w + x];
                        assert!(
                            (got - want).abs() <= 1e-5,
                            "rank {rank} comp {comp} ({y},{x}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn file_mode_writes_collated_steps() {
        let cfg = small_cfg(2, IoMode::File);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
        let rep = SimRunner::run(&cfg, None, None).unwrap();
        assert_eq!(rep.writes_per_rank, 4);
        let mut files: Vec<_> = std::fs::read_dir(&cfg.out_dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        files.sort();
        assert_eq!(
            files,
            vec![
                "step_000010.bin",
                "step_000020.bin",
                "step_000030.bin",
                "step_000040.bin"
            ]
        );
        // collated file holds every rank's interior field
        let len = std::fs::metadata(format!("{}/step_000010.bin", cfg.out_dir))
            .unwrap()
            .len();
        assert_eq!(len, (2 * 32 * 64 * 4) as u64);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn broker_mode_requires_broker() {
        let cfg = small_cfg(1, IoMode::Broker);
        assert!(SimRunner::run(&cfg, None, None).is_err());
    }

    #[test]
    fn invalid_decomposition_rejected() {
        let mut cfg = small_cfg(3, IoMode::None); // 32 % 3 != 0
        cfg.ranks = 3;
        assert!(SimRunner::run(&cfg, None, None).is_err());
    }
}
