//! Pure-Rust D2Q9 lattice-Boltzmann step — the exact mirror of the
//! Layer-2 JAX graph in `python/compile/model.py::lbm_step`.
//!
//! Used when artifacts are absent (tests, quickstart) and to
//! cross-validate the PJRT path (integration test
//! `pjrt_and_fallback_agree`).  Keep this in lock-step with the Python:
//! collision (BGK, solids pass through) → streaming (periodic roll) →
//! full-way bounce-back → inflow (west, equilibrium at ρ=1,u=(u0,0)) →
//! outflow (east, zero-gradient) → interior velocity moments.

/// D2Q9 velocity set (must match `kernels/ref.py`).
pub const EX: [i32; 9] = [0, 1, 0, -1, 0, 1, -1, -1, 1];
pub const EY: [i32; 9] = [0, 0, 1, 0, -1, 1, 1, -1, -1];
pub const OPP: [usize; 9] = [0, 3, 4, 1, 2, 7, 8, 5, 6];
pub const W9: [f32; 9] = [
    4.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
];

/// Physics parameters (must match the AOT defaults in `model.py`).
#[derive(Clone, Copy, Debug)]
pub struct LbmParams {
    pub tau: f32,
    pub u0: f32,
}

impl Default for LbmParams {
    fn default() -> Self {
        // Must match model.py DEFAULT_TAU/DEFAULT_U0 (stability-checked
        // for the full WindAroundBuildings geometry over 2000 steps).
        LbmParams { tau: 0.60, u0: 0.10 }
    }
}

/// Equilibrium distribution for one cell.
#[inline]
pub fn equilibrium(rho: f32, ux: f32, uy: f32) -> [f32; 9] {
    let usq = ux * ux + uy * uy;
    let mut out = [0.0f32; 9];
    for c in 0..9 {
        let cu = EX[c] as f32 * ux + EY[c] as f32 * uy;
        out[c] = W9[c] * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq);
    }
    out
}

/// Initial state: equilibrium at ρ=1 with the inflow wind (solids at
/// rest) — mirror of `model.lbm_init`.
pub fn init(mask: &[f32], hp: usize, w: usize, params: LbmParams) -> Vec<f32> {
    let plane = hp * w;
    let mut f = vec![0.0f32; 9 * plane];
    for cell in 0..plane {
        let ux = if mask[cell] > 0.5 { 0.0 } else { params.u0 };
        let feq = equilibrium(1.0, ux, 0.0);
        for c in 0..9 {
            f[c * plane + cell] = feq[c];
        }
    }
    f
}

/// One fused LBM step over an extended `(9, hp, w)` subdomain.
///
/// `f` is updated in place; returns the interior `(2, hp-2, w)` velocity
/// field `(ux rows..., uy rows...)`.  `inflow=false` gives the closed
/// periodic box used by conservation tests.
pub fn step(
    f: &mut Vec<f32>,
    mask: &[f32],
    hp: usize,
    w: usize,
    params: LbmParams,
    inflow: bool,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    let plane = hp * w;
    debug_assert_eq!(f.len(), 9 * plane);
    debug_assert_eq!(mask.len(), plane);
    let omega = 1.0 / params.tau;

    // 1. collision (solids pass through)
    scratch.clear();
    scratch.resize(9 * plane, 0.0);
    for y in 0..hp {
        for x in 0..w {
            let cell = y * w + x;
            let mut fc = [0.0f32; 9];
            for c in 0..9 {
                fc[c] = f[c * plane + cell];
            }
            if mask[cell] > 0.5 {
                for c in 0..9 {
                    scratch[c * plane + cell] = fc[c];
                }
                continue;
            }
            let rho: f32 = fc.iter().sum();
            let inv = 1.0 / rho;
            let mut ux = 0.0;
            let mut uy = 0.0;
            for c in 1..9 {
                ux += EX[c] as f32 * fc[c];
                uy += EY[c] as f32 * fc[c];
            }
            ux *= inv;
            uy *= inv;
            let feq = equilibrium(rho, ux, uy);
            for c in 0..9 {
                scratch[c * plane + cell] = fc[c] + omega * (feq[c] - fc[c]);
            }
        }
    }

    // 2. streaming: f_new[c][y][x] = f_post[c][y - ey][x - ex] (periodic)
    for c in 0..9 {
        let (ex, ey) = (EX[c], EY[c]);
        let src_plane = &scratch[c * plane..(c + 1) * plane];
        let dst_plane = &mut f[c * plane..(c + 1) * plane];
        for y in 0..hp {
            let sy = ((y as i32 - ey).rem_euclid(hp as i32)) as usize;
            for x in 0..w {
                let sx = ((x as i32 - ex).rem_euclid(w as i32)) as usize;
                dst_plane[y * w + x] = src_plane[sy * w + sx];
            }
        }
    }

    // 3. full-way bounce-back at solids
    for y in 0..hp {
        for x in 0..w {
            let cell = y * w + x;
            if mask[cell] > 0.5 {
                let mut fc = [0.0f32; 9];
                for c in 0..9 {
                    fc[c] = f[c * plane + cell];
                }
                for c in 0..9 {
                    f[c * plane + cell] = fc[OPP[c]];
                }
            }
        }
    }

    if inflow {
        // 4. inflow: west column to equilibrium(1, u0, 0) on fluid cells
        let feq_in = equilibrium(1.0, params.u0, 0.0);
        for y in 0..hp {
            let cell = y * w;
            if mask[cell] <= 0.5 {
                for c in 0..9 {
                    f[c * plane + cell] = feq_in[c];
                }
            }
        }
        // 5. outflow: east column copies its west neighbour
        for y in 0..hp {
            let dst = y * w + (w - 1);
            let src = y * w + (w - 2);
            for c in 0..9 {
                f[c * plane + dst] = f[c * plane + src];
            }
        }
    }

    // 6. interior velocity moments (rows 1..hp-1)
    let h = hp - 2;
    let mut u = vec![0.0f32; 2 * h * w];
    for y in 0..h {
        for x in 0..w {
            let cell = (y + 1) * w + x;
            let mut rho = 0.0;
            let mut ux = 0.0;
            let mut uy = 0.0;
            for c in 0..9 {
                let v = f[c * plane + cell];
                rho += v;
                ux += EX[c] as f32 * v;
                uy += EY[c] as f32 * v;
            }
            u[y * w + x] = ux / rho;
            u[h * w + y * w + x] = uy / rho;
        }
    }
    u
}

/// Total mass (Σf) — conservation diagnostics.
pub fn total_mass(f: &[f32]) -> f64 {
    f.iter().map(|&v| v as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn noisy_state(hp: usize, w: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let plane = hp * w;
        let mask: Vec<f32> = (0..plane)
            .map(|_| if rng.next_f64() < 0.15 { 1.0 } else { 0.0 })
            .collect();
        let mut f = init(&mask, hp, w, LbmParams::default());
        for v in f.iter_mut() {
            *v *= 1.0 + 0.05 * (rng.next_f32() - 0.5);
        }
        (f, mask)
    }

    #[test]
    fn closed_box_conserves_mass() {
        let (hp, w) = (12, 24);
        let (mut f, mask) = noisy_state(hp, w, 3);
        let m0 = total_mass(&f);
        let mut scratch = Vec::new();
        for _ in 0..50 {
            step(&mut f, &mask, hp, w, LbmParams::default(), false, &mut scratch);
        }
        let m1 = total_mass(&f);
        assert!(((m1 - m0) / m0).abs() < 1e-5, "mass drift {m0} -> {m1}");
    }

    #[test]
    fn equilibrium_moments() {
        let feq = equilibrium(1.2, 0.05, -0.03);
        let rho: f32 = feq.iter().sum();
        assert!((rho - 1.2).abs() < 1e-6);
        let ux: f32 = (0..9).map(|c| EX[c] as f32 * feq[c]).sum();
        let uy: f32 = (0..9).map(|c| EY[c] as f32 * feq[c]).sum();
        assert!((ux / rho - 0.05).abs() < 1e-6);
        assert!((uy / rho + 0.03).abs() < 1e-6);
    }

    #[test]
    fn init_has_unit_density_and_wind() {
        let (hp, w) = (6, 8);
        let mask = vec![0.0f32; hp * w];
        let f = init(&mask, hp, w, LbmParams::default());
        let plane = hp * w;
        for cell in 0..plane {
            let rho: f32 = (0..9).map(|c| f[c * plane + cell]).sum();
            assert!((rho - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn stays_finite_with_buildings_600_steps() {
        let (hp, w) = (34, 96);
        let plane = hp * w;
        let mut mask = vec![0.0f32; plane];
        for x in 0..w {
            mask[w + x] = 1.0; // bottom wall (row 1)
            mask[(hp - 2) * w + x] = 1.0; // top wall
        }
        for y in 12..22 {
            for x in 30..36 {
                mask[y * w + x] = 1.0;
            }
        }
        let params = LbmParams::default();
        let mut f = init(&mask, hp, w, params);
        let mut scratch = Vec::new();
        let mut u = Vec::new();
        for _ in 0..600 {
            u = step(&mut f, &mask, hp, w, params, true, &mut scratch);
        }
        assert!(u.iter().all(|v| v.is_finite()));
        let max_u = u.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        assert!(max_u < 0.5, "lattice velocity {max_u} unstable");
        // wake: slower flow right behind the building than upstream
        let h = hp - 2;
        let row = 15usize; // interior row index within the building band
        let upstream: f32 = (10..20).map(|x| u[row * w + x]).sum::<f32>() / 10.0;
        let wake: f32 = (37..45).map(|x| u[row * w + x]).sum::<f32>() / 8.0;
        assert!(upstream > 0.05, "no free stream ({upstream})");
        assert!(wake < upstream * 0.8, "no wake: up={upstream} wake={wake}");
        let _ = h;
    }

    #[test]
    fn solid_cells_report_zero_velocity_after_init() {
        let (hp, w) = (8, 8);
        let plane = hp * w;
        let mut mask = vec![0.0f32; plane];
        mask[3 * w + 3] = 1.0;
        let f = init(&mask, hp, w, LbmParams::default());
        let mut fc = [0.0f32; 9];
        for c in 0..9 {
            fc[c] = f[c * plane + 3 * w + 3];
        }
        let ux: f32 = (0..9).map(|c| EX[c] as f32 * fc[c]).sum();
        assert!(ux.abs() < 1e-7);
    }

    #[test]
    fn velocity_set_is_consistent() {
        // opposite directions really are opposite; weights sum to 1
        for c in 0..9 {
            assert_eq!(EX[OPP[c]], -EX[c]);
            assert_eq!(EY[OPP[c]], -EY[c]);
        }
        let sum: f32 = W9.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }
}
