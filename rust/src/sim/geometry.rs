//! WindAroundBuildings geometry: walls + a deterministic cluster of
//! rectangular buildings (the paper's Fig 4 case, reduced to 2-D).

/// A solid rectangle in global (row, col) coordinates, half-open.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rect {
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
}

impl Rect {
    pub fn contains(&self, y: usize, x: usize) -> bool {
        y >= self.y0 && y < self.y1 && x >= self.x0 && x < self.x1
    }
    pub fn area(&self) -> usize {
        (self.y1 - self.y0) * (self.x1 - self.x0)
    }
}

/// The building cluster, scaled to the lattice size.  Proportions give
/// an urban-canyon wake structure: staggered blocks of varying size in
/// the upstream two-thirds of the channel.
pub fn buildings(h: usize, w: usize) -> Vec<Rect> {
    let r = |fy0: f64, fy1: f64, fx0: f64, fx1: f64| Rect {
        y0: (h as f64 * fy0) as usize,
        y1: (h as f64 * fy1) as usize,
        x0: (w as f64 * fx0) as usize,
        x1: (w as f64 * fx1) as usize,
    };
    vec![
        r(0.20, 0.45, 0.20, 0.28),
        r(0.55, 0.80, 0.24, 0.33),
        r(0.32, 0.62, 0.42, 0.50),
        r(0.12, 0.34, 0.58, 0.66),
        r(0.60, 0.86, 0.57, 0.68),
    ]
    .into_iter()
    .filter(|r| r.area() > 0)
    .collect()
}

/// Global solid mask `(h, w)`: channel walls on the first/last row plus
/// the building cluster.
pub fn build_mask(h: usize, w: usize) -> Vec<f32> {
    let mut mask = vec![0.0f32; h * w];
    for x in 0..w {
        mask[x] = 1.0; // bottom wall (row 0)
        mask[(h - 1) * w + x] = 1.0; // top wall
    }
    for b in buildings(h, w) {
        for y in b.y0..b.y1.min(h) {
            for x in b.x0..b.x1.min(w) {
                mask[y * w + x] = 1.0;
            }
        }
    }
    mask
}

/// Extract the extended per-rank mask (`h_loc + 2` rows with halos).
/// Halo rows beyond the global domain are solid (they sit behind the
/// channel walls and never influence the interior).
pub fn rank_mask(global: &[f32], h: usize, w: usize, ranks: usize, rank: usize) -> Vec<f32> {
    assert_eq!(global.len(), h * w);
    assert!(h % ranks == 0, "h {h} not divisible by ranks {ranks}");
    let h_loc = h / ranks;
    let hp = h_loc + 2;
    let mut out = vec![0.0f32; hp * w];
    let base = rank * h_loc;
    for yy in 0..hp {
        let gy = base as isize + yy as isize - 1;
        for x in 0..w {
            out[yy * w + x] = if gy < 0 || gy >= h as isize {
                1.0 // beyond the walls: solid
            } else {
                global[gy as usize * w + x]
            };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_has_walls_and_buildings() {
        let (h, w) = (64, 128);
        let mask = build_mask(h, w);
        for x in 0..w {
            assert_eq!(mask[x], 1.0);
            assert_eq!(mask[(h - 1) * w + x], 1.0);
        }
        let solid: usize = mask.iter().filter(|&&v| v > 0.5).count();
        let total = h * w;
        // walls are 2 rows; buildings add a noticeable but minor fraction
        assert!(solid > 2 * w, "no buildings present");
        assert!(solid < total / 3, "domain mostly solid: {solid}/{total}");
        // inflow column must be fluid away from the walls
        for y in 2..h - 2 {
            assert_eq!(mask[y * w], 0.0, "inflow blocked at row {y}");
        }
    }

    #[test]
    fn buildings_scale_with_domain() {
        for (h, w) in [(32usize, 64usize), (256, 128), (128, 512)] {
            let bs = buildings(h, w);
            assert!(!bs.is_empty());
            for b in &bs {
                assert!(b.y1 <= h && b.x1 <= w, "{b:?} out of {h}x{w}");
                assert!(b.area() > 0);
            }
        }
    }

    #[test]
    fn rank_masks_tile_the_domain() {
        let (h, w, ranks) = (64, 32, 8);
        let global = build_mask(h, w);
        let h_loc = h / ranks;
        for rank in 0..ranks {
            let rm = rank_mask(&global, h, w, ranks, rank);
            assert_eq!(rm.len(), (h_loc + 2) * w);
            // interior rows match the global mask exactly
            for yy in 0..h_loc {
                for x in 0..w {
                    assert_eq!(
                        rm[(yy + 1) * w + x],
                        global[(rank * h_loc + yy) * w + x],
                        "rank {rank} row {yy} col {x}"
                    );
                }
            }
        }
        // boundary halos solid
        let top = rank_mask(&global, h, w, ranks, 0);
        assert!(top[..w].iter().all(|&v| v == 1.0));
        let bot = rank_mask(&global, h, w, ranks, ranks - 1);
        assert!(bot[(h_loc + 1) * w..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn neighbour_halos_mirror_interiors() {
        let (h, w, ranks) = (32, 16, 4);
        let global = build_mask(h, w);
        let h_loc = h / ranks;
        for rank in 0..ranks - 1 {
            let a = rank_mask(&global, h, w, ranks, rank);
            let b = rank_mask(&global, h, w, ranks, rank + 1);
            // a's bottom halo row == b's first interior row
            assert_eq!(
                &a[(h_loc + 1) * w..(h_loc + 2) * w],
                &b[w..2 * w],
                "rank {rank} halo mismatch"
            );
            // b's top halo row == a's last interior row
            assert_eq!(&b[..w], &a[h_loc * w..(h_loc + 1) * w]);
        }
    }
}
