//! The CFD simulation substrate — our OpenFOAM `simpleFoam`
//! *WindAroundBuildings* stand-in (paper §4.1).
//!
//! A D2Q9 lattice-Boltzmann channel flow around a cluster of rectangular
//! buildings, decomposed across MPI-style ranks along the height axis
//! (the paper decomposes along Z), one thread per rank, with per-step
//! halo exchange over channels.  Each rank advances its extended
//! subdomain through either the **AOT-compiled PJRT artifact**
//! (`lbm_step`, the Pallas collision kernel inlined) or the pure-Rust
//! mirror ([`lbm`]), and every `write_interval` steps emits its interior
//! velocity field through one of the paper's three I/O modes:
//!
//! * `Broker` — `broker_write` into the ElasticBroker pipeline,
//! * `File`   — collated per-step files on a shared directory (the
//!   paper's Lustre baseline; fsync models the PFS commit), or
//! * `None`   — the simulation-only baseline.

pub mod geometry;
pub mod lbm;
mod runner;

pub use geometry::{build_mask, buildings, Rect};
pub use runner::{SimConfig, SimReport, SimRunner};
