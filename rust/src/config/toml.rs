//! A pragmatic TOML-subset parser (see module docs in `config/mod.rs`).
//!
//! Supported: `[a.b]` tables, `key = value` with string / integer /
//! float / boolean / flat array values, `#` comments, blank lines.
//! Unsupported (rejected loudly rather than misparsed): multi-line
//! strings, inline tables, arrays-of-tables, datetimes.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// Flat `section.key → value` map.
#[derive(Default, Debug)]
pub struct ConfigMap {
    entries: BTreeMap<String, TomlValue>,
}

impl ConfigMap {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    pub fn get_str(&self, key: &str) -> Result<Option<String>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(s.clone())),
            Some(other) => bail!("{key}: expected string, got {other:?}"),
        }
    }

    pub fn get_i64(&self, key: &str) -> Result<Option<i64>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) => Ok(Some(*i)),
            Some(other) => bail!("{key}: expected integer, got {other:?}"),
        }
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.get_i64(key)? {
            None => Ok(None),
            Some(i) if i >= 0 => Ok(Some(i as u64)),
            Some(i) => bail!("{key}: expected non-negative integer, got {i}"),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        Ok(self.get_u64(key)?.map(|v| v as usize))
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(TomlValue::Float(f)) => Ok(Some(*f)),
            Some(TomlValue::Int(i)) => Ok(Some(*i as f64)),
            Some(other) => bail!("{key}: expected float, got {other:?}"),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(other) => bail!("{key}: expected bool, got {other:?}"),
        }
    }

    /// String list: either a TOML array of strings or one
    /// comma-separated string (`"a,b,c"` — the CLI-friendly spelling).
    pub fn get_str_list(&self, key: &str) -> Result<Option<Vec<String>>> {
        match self.entries.get(key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(
                s.split(',')
                    .map(|p| p.trim().to_string())
                    .filter(|p| !p.is_empty())
                    .collect(),
            )),
            Some(TomlValue::Array(items)) => {
                let mut out = Vec::with_capacity(items.len());
                for it in items {
                    match it {
                        TomlValue::Str(s) => out.push(s.clone()),
                        other => {
                            bail!("{key}: expected string elements, got {other:?}")
                        }
                    }
                }
                Ok(Some(out))
            }
            Some(other) => bail!("{key}: expected string list, got {other:?}"),
        }
    }
}

/// Parse TOML-subset text into a flat map.
pub fn parse(text: &str) -> Result<ConfigMap> {
    let mut map = ConfigMap::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() || name.starts_with('[') {
                bail!("line {}: unsupported table syntax '{raw}'", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected 'key = value'", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let parsed = parse_scalar(value.trim())
            .with_context(|| format!("line {}: bad value for {full_key}", lineno + 1))?;
        map.entries.insert(full_key, parsed);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_scalar(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let body = rest
            .strip_suffix('"')
            .context("unterminated string literal")?;
        // minimal escape handling
        let body = body.replace("\\\"", "\"").replace("\\\\", "\\");
        return Ok(TomlValue::Str(body));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        if !body.trim().is_empty() {
            for part in split_top_level(body) {
                items.push(parse_scalar(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&body[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let m = parse(
            r##"
            top = 1
            [server]           # trailing comment
            host = "127.0.0.1" # with a "#" in mind
            port = 6379
            ratio = 0.5
            fast = true
            tags = ["a", "b"]
            counts = [1, 2, 3]
            big = 1_000_000
            neg = -17
            "##,
        )
        .unwrap();
        assert_eq!(m.get_i64("top").unwrap(), Some(1));
        assert_eq!(m.get_str("server.host").unwrap(), Some("127.0.0.1".into()));
        assert_eq!(m.get_i64("server.port").unwrap(), Some(6379));
        assert_eq!(m.get_f64("server.ratio").unwrap(), Some(0.5));
        assert_eq!(m.get_bool("server.fast").unwrap(), Some(true));
        assert_eq!(m.get_i64("server.big").unwrap(), Some(1_000_000));
        assert_eq!(m.get_i64("server.neg").unwrap(), Some(-17));
        match m.get("server.tags").unwrap() {
            TomlValue::Array(v) => assert_eq!(v.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hash_inside_string_survives() {
        let m = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(m.get_str("s").unwrap(), Some("a#b".into()));
    }

    #[test]
    fn type_mismatch_is_error() {
        let m = parse("x = 5\n").unwrap();
        assert!(m.get_str("x").is_err());
        assert!(m.get_bool("x").is_err());
    }

    #[test]
    fn negative_u64_is_error() {
        let m = parse("x = -5\n").unwrap();
        assert!(m.get_u64("x").is_err());
    }

    #[test]
    fn bad_syntax_rejected() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("noequals\n").is_err());
        assert!(parse("x = \n").is_err());
        assert!(parse("x = \"open\n").is_err());
        assert!(parse("[[array.of.tables]]\n").is_err());
    }

    #[test]
    fn empty_array_ok() {
        let m = parse("xs = []\n").unwrap();
        assert_eq!(m.get("xs").unwrap(), &TomlValue::Array(vec![]));
    }

    #[test]
    fn later_keys_win() {
        let m = parse("x = 1\nx = 2\n").unwrap();
        assert_eq!(m.get_i64("x").unwrap(), Some(2));
    }
}
