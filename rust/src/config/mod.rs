//! Configuration: a TOML-subset parser plus the typed experiment config.
//!
//! No serde in the offline crate set, so we parse a pragmatic subset of
//! TOML ourselves: `[section.subsection]` headers, `key = value` lines,
//! strings / integers / floats / booleans / flat arrays, `#` comments.
//! This covers everything the launcher and the bench harnesses need.

mod toml;

pub use toml::{parse, ConfigMap, TomlValue};

use anyhow::{Context, Result};

use crate::broker::StagesConfig;
use crate::endpoint::{FsyncPolicy, ReplAck};
use crate::record::{CodecKind, Encoding};

/// How the simulation emits its per-interval output (paper §4.2 modes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Write snapshots to files ("collated" per-step files, like the
    /// paper's Lustre runs).
    File,
    /// Ship snapshots through the ElasticBroker pipeline.
    Broker,
    /// Discard output (the paper's "simulation-only" baseline).
    None,
}

impl IoMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "file" => Ok(IoMode::File),
            "broker" => Ok(IoMode::Broker),
            "none" | "simulation-only" => Ok(IoMode::None),
            other => anyhow::bail!("unknown io mode '{other}' (file|broker|none)"),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            IoMode::File => "file",
            IoMode::Broker => "broker",
            IoMode::None => "none",
        }
    }
}

/// Full-workflow configuration (defaults reproduce the paper's §4.2
/// 16-rank WindAroundBuildings experiment, scaled to one host).
#[derive(Clone, Debug)]
pub struct WorkflowConfig {
    // --- simulation (HPC side) ---
    /// Number of MPI-style simulation ranks.
    pub ranks: usize,
    /// Global lattice height (decomposed across ranks along this axis).
    pub height: usize,
    /// Global lattice width.
    pub width: usize,
    /// Total simulation timesteps.
    pub steps: u64,
    /// Emit output every `write_interval` steps.
    pub write_interval: u64,
    /// Output mode.
    pub io_mode: IoMode,
    /// Directory for file-mode output.
    pub out_dir: String,
    /// Use the PJRT artifacts (true) or the pure-Rust fallback solver.
    pub use_pjrt: bool,
    /// Modeled parallel-filesystem commit latency (ms) per collated
    /// step in file mode (see `sim::SimConfig::pfs_commit_ms`).
    pub pfs_commit_ms: u64,

    // --- broker ---
    /// Ranks per process group (one group per endpoint; paper ratio 16:1).
    pub group_size: usize,
    /// Per-context bounded queue capacity (records).
    pub queue_cap: usize,
    /// Drop-oldest instead of blocking when a queue is full.
    pub drop_oldest: bool,
    /// Max records per pipelined XADD batch (writer-side coalescing).
    pub batch_max_records: usize,
    /// Max payload bytes per batch (0 = unbounded).
    pub batch_max_bytes: usize,
    /// Writer linger before shipping a non-full batch (ms; 0 = none).
    pub linger_ms: u64,

    // --- data-reduction stages (ISSUE 5) ---
    /// Broker-side stage pipeline: filter (decimation / rank subset /
    /// ROI) → aggregate (block-mean + stats) → convert (f16 / qdelta)
    /// → compress (shuffle-lz).  Defaults to a passthrough.
    pub stages: StagesConfig,

    // --- cloud side ---
    /// Number of endpoints (None → ranks / group_size).
    pub endpoints: Option<usize>,
    /// Stream-store shards per endpoint (cross-stream lock isolation).
    pub store_shards: usize,
    /// Number of stream-processing executors (paper ratio: = ranks).
    pub executors: usize,
    /// Micro-batch trigger interval, milliseconds (paper: 3000).
    pub trigger_ms: u64,
    /// DMD window length m (snapshots per analysis; artifact uses m+1).
    pub dmd_window: usize,
    /// DMD truncation rank.
    pub dmd_rank: usize,
    /// Run the DMD reduction through the PJRT artifact (true) or the
    /// pure-Rust mirror (false).  On CPU-only PJRT the per-dispatch
    /// overhead can dominate small windows — see EXPERIMENTS.md §Perf.
    pub dmd_use_pjrt: bool,
    /// Analyse once per micro-batch per stream (the paper's per-trigger
    /// cadence) instead of once per snapshot.
    pub dmd_per_batch: bool,
    /// Rebuild each stream's cached Gram matrix from the stored
    /// snapshots every `dmd_gram_refresh` incremental window slides
    /// (0 = only on the automatic non-finite fallback).
    pub dmd_gram_refresh: usize,
    /// Shards the analysis engine's per-stream window map is hashed
    /// across (cross-stream lock isolation, like `store_shards`).
    pub dmd_shards: usize,
    /// CSV output path for analysis results ("" → none).
    pub analysis_csv: String,

    // --- consumer fan-out (ISSUE 6) ---
    /// Named consumer group the workflow's readers ack under ("" = the
    /// endpoint's default group).  Each group keeps an independent
    /// persisted cursor per stream; retention/GC only trims below the
    /// *minimum* cursor across groups, so side-car consumers
    /// (dashboards, archivers) never lose unread entries.
    pub consumer_group: String,
    /// Publish every DMD fire back into the first endpoint as a
    /// compact `results/<field>/<rank>` stream that subscribers tail
    /// through the same reader machinery as the data streams.
    pub results_stream: bool,

    // --- durability (ISSUE 4) ---
    /// Directory for the endpoints' write-ahead logs ("" = in-memory
    /// endpoints, the pre-ISSUE-4 behaviour).  Each endpoint gets its
    /// own `ep<i>/` subdirectory.
    pub wal_dir: String,
    /// WAL fsync policy: `never` | `always` | `every_ms(N)`.  Only
    /// meaningful when `wal_dir` is set; `always` makes crash-restart
    /// loss-free, `every_ms(N)` bounds loss to N ms per endpoint.
    pub wal_fsync: FsyncPolicy,
    /// WAL segment rotation threshold (bytes).
    pub wal_segment_bytes: usize,
    /// Ack-based retention: readers acknowledge consumed cursors and
    /// endpoints never trim (or GC) unread entries.  Requires
    /// `wal_dir` (validation rejects it otherwise).
    pub retention: bool,

    // --- endpoint I/O core (ISSUE 7) ---
    /// Event-loop shard threads per endpoint; each shard owns its
    /// accepted connections outright (no cross-shard locking).
    pub io_shards: usize,
    /// Per-shard reusable read buffer size (bytes) — the unit of one
    /// `read()` into the incremental RESP decoder.
    pub read_ring_bytes: usize,
    /// Max connections one shard will hold; accepts beyond the total
    /// (`io_shards * max_conns_per_shard`) are shed at accept time.
    pub max_conns_per_shard: usize,

    // --- elasticity (ISSUE 3) ---
    /// Rebalancer sweep cadence in ms (0 = elasticity disabled: static
    /// topology, the pre-elastic behaviour).
    pub rebalance_ms: u64,
    /// QoS threshold: per-endpoint flush p95 (µs) above which the
    /// endpoint is saturated and sheds a group (0 = signal disabled).
    pub qos_flush_p95_us: u64,
    /// QoS threshold: peak writer-queue depth at/above which an
    /// endpoint is saturated (0 = signal disabled).
    pub qos_queue_depth: u64,
    /// QoS threshold: reconnect attempts per sweep at/above which an
    /// endpoint is presumed dead and drained (0 = signal disabled).
    pub qos_reconnects: u64,

    // --- chain replication (ISSUE 10) ---
    /// Replica-chain length per group (1 = replication off, the
    /// pre-ISSUE-10 behaviour; max 3).  Every stream is chain-written
    /// through this many endpoints in distinct failure domains; losing
    /// a whole machine loses no acked record.
    pub replication_factor: usize,
    /// Failure-domain labels cycled over the endpoint slots (empty =
    /// every endpoint is its own domain).  Chains never visit the same
    /// domain twice.
    pub replication_domains: Vec<String>,
    /// Ack durability: `tail` bounces a write (REPL error, writer
    /// retries) until the whole chain stored it; `head` acks after the
    /// local store and forwards best-effort.
    pub replication_ack: ReplAck,

    // --- adaptive reduction (ISSUE 8) ---
    /// Adaptation controller sweep cadence in ms (0 = controller
    /// disabled: every stream stays pinned to the configured `[stages]`
    /// pipeline, the pre-adaptive behaviour).
    pub adapt_sweep_ms: u64,
    /// Per-endpoint flush p95 (µs) the controller tries to stay under;
    /// a sweep above this walks streams down the reduction ladder.
    pub adapt_target_p95_us: u64,
    /// Writer-queue depth (peak per sweep) or per-stream backlog at/
    /// above which a stream is considered under WAN pressure.
    pub adapt_queue_hi: u64,
    /// Consecutive calm sweeps required before the controller walks a
    /// stream back up one rung (step-up hysteresis).
    pub adapt_hysteresis: u32,

    // --- observability (ISSUE 9) ---
    /// Stamp a flight-recorder trace into every Nth record per writer
    /// context (0 = tracing disabled; the unsampled hot path pays
    /// nothing beyond one counter compare).
    pub obs_trace_sample: u64,
    /// Metrics-snapshot cadence in ms: append a JSONL snapshot of the
    /// whole registry to `<obs_dir>/metrics.jsonl` every N ms
    /// (0 = no snapshot writer).
    pub obs_snapshot_ms: u64,
    /// Directory for observability output (metrics.jsonl + events.jsonl;
    /// "" = journal stays in-memory-only, no snapshot files).
    pub obs_dir: String,
    /// Control-plane event journal ring capacity (events kept in memory
    /// for INFO-style inspection; the JSONL sink, when `obs_dir` is set,
    /// is unbounded).
    pub obs_events_ring: usize,
}

impl Default for WorkflowConfig {
    fn default() -> Self {
        WorkflowConfig {
            ranks: 16,
            height: 256,
            width: 128,
            steps: 2000,
            write_interval: 5,
            io_mode: IoMode::Broker,
            out_dir: "sim_out".into(),
            use_pjrt: true,
            pfs_commit_ms: 25,
            group_size: 16,
            queue_cap: 64,
            drop_oldest: false,
            batch_max_records: 64,
            batch_max_bytes: 4 << 20,
            linger_ms: 0,
            stages: StagesConfig::default(),
            endpoints: None,
            store_shards: 8,
            executors: 16,
            trigger_ms: 3000,
            dmd_window: 8,
            dmd_rank: 6,
            dmd_use_pjrt: true,
            dmd_per_batch: false,
            dmd_gram_refresh: 64,
            dmd_shards: 8,
            analysis_csv: String::new(),
            consumer_group: String::new(),
            results_stream: false,
            wal_dir: String::new(),
            wal_fsync: FsyncPolicy::EveryMs(5),
            wal_segment_bytes: 64 << 20,
            retention: false,
            io_shards: 4,
            read_ring_bytes: 64 << 10,
            max_conns_per_shard: 4096,
            rebalance_ms: 0,
            qos_flush_p95_us: 250_000,
            qos_queue_depth: 48,
            qos_reconnects: 3,
            replication_factor: 1,
            replication_domains: Vec::new(),
            replication_ack: ReplAck::Tail,
            adapt_sweep_ms: 0,
            adapt_target_p95_us: 50_000,
            adapt_queue_hi: 16,
            adapt_hysteresis: 3,
            obs_trace_sample: 0,
            obs_snapshot_ms: 0,
            obs_dir: String::new(),
            obs_events_ring: 1024,
        }
    }
}

impl WorkflowConfig {
    /// Effective endpoint count (paper ratio ranks:endpoints = 16:1).
    pub fn endpoint_count(&self) -> usize {
        self.endpoints
            .unwrap_or_else(|| (self.ranks + self.group_size - 1) / self.group_size)
            .max(1)
    }

    /// Rows per rank (the Z-axis decomposition of §4.1).
    pub fn rows_per_rank(&self) -> Result<usize> {
        anyhow::ensure!(
            self.height % self.ranks == 0,
            "height {} not divisible by ranks {}",
            self.height,
            self.ranks
        );
        Ok(self.height / self.ranks)
    }

    /// Per-rank snapshot dimension d = rows × width × 2 components.
    pub fn snapshot_dim(&self) -> Result<usize> {
        Ok(self.rows_per_rank()? * self.width * 2)
    }

    /// Load from a TOML-subset file (missing keys keep defaults).
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text (missing keys keep defaults).
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse(text)?;
        let mut cfg = WorkflowConfig::default();
        if let Some(v) = map.get_usize("sim.ranks")? {
            cfg.ranks = v;
        }
        if let Some(v) = map.get_usize("sim.height")? {
            cfg.height = v;
        }
        if let Some(v) = map.get_usize("sim.width")? {
            cfg.width = v;
        }
        if let Some(v) = map.get_u64("sim.steps")? {
            cfg.steps = v;
        }
        if let Some(v) = map.get_u64("sim.write_interval")? {
            cfg.write_interval = v;
        }
        if let Some(v) = map.get_str("sim.io_mode")? {
            cfg.io_mode = IoMode::parse(&v)?;
        }
        if let Some(v) = map.get_str("sim.out_dir")? {
            cfg.out_dir = v;
        }
        if let Some(v) = map.get_bool("sim.use_pjrt")? {
            cfg.use_pjrt = v;
        }
        if let Some(v) = map.get_u64("sim.pfs_commit_ms")? {
            cfg.pfs_commit_ms = v;
        }
        if let Some(v) = map.get_usize("broker.group_size")? {
            cfg.group_size = v;
        }
        if let Some(v) = map.get_usize("broker.queue_cap")? {
            cfg.queue_cap = v;
        }
        if let Some(v) = map.get_bool("broker.drop_oldest")? {
            cfg.drop_oldest = v;
        }
        if let Some(v) = map.get_usize("broker.batch_max_records")? {
            cfg.batch_max_records = v;
        }
        if let Some(v) = map.get_usize("broker.batch_max_bytes")? {
            cfg.batch_max_bytes = v;
        }
        if let Some(v) = map.get_u64("broker.linger_ms")? {
            cfg.linger_ms = v;
        }
        if let Some(v) = map.get_u64("stages.decimate")? {
            cfg.stages.decimate = v;
        }
        if let Some(v) = map.get_u64("stages.rank_stride")? {
            cfg.stages.rank_stride = v as u32;
        }
        if let Some(v) = map.get_str("stages.roi")? {
            cfg.stages.roi = Some(StagesConfig::parse_roi(&v)?);
        }
        if let Some(v) = map.get_usize("stages.aggregate")? {
            cfg.stages.aggregate = v;
        }
        if let Some(v) = map.get_bool("stages.stats")? {
            cfg.stages.stats = v;
        }
        if let Some(v) = map.get_str("stages.convert")? {
            cfg.stages.convert = Encoding::parse(&v)?;
        }
        if let Some(v) = map.get_f64("stages.qdelta_step")? {
            cfg.stages.qdelta_step = v as f32;
        }
        if let Some(v) = map.get_str("stages.codec")? {
            cfg.stages.codec = CodecKind::parse(&v)?;
        }
        if let Some(v) = map.get_f64("stages.max_err")? {
            cfg.stages.max_err = v as f32;
        }
        if let Some(v) = map.get_usize("cloud.endpoints")? {
            cfg.endpoints = Some(v);
        }
        if let Some(v) = map.get_usize("cloud.store_shards")? {
            cfg.store_shards = v;
        }
        if let Some(v) = map.get_usize("cloud.executors")? {
            cfg.executors = v;
        }
        if let Some(v) = map.get_u64("cloud.trigger_ms")? {
            cfg.trigger_ms = v;
        }
        if let Some(v) = map.get_usize("cloud.dmd_window")? {
            cfg.dmd_window = v;
        }
        if let Some(v) = map.get_usize("cloud.dmd_rank")? {
            cfg.dmd_rank = v;
        }
        if let Some(v) = map.get_bool("cloud.dmd_use_pjrt")? {
            cfg.dmd_use_pjrt = v;
        }
        if let Some(v) = map.get_bool("cloud.dmd_per_batch")? {
            cfg.dmd_per_batch = v;
        }
        if let Some(v) = map.get_usize("cloud.dmd_gram_refresh")? {
            cfg.dmd_gram_refresh = v;
        }
        if let Some(v) = map.get_usize("cloud.dmd_shards")? {
            cfg.dmd_shards = v;
        }
        if let Some(v) = map.get_str("cloud.analysis_csv")? {
            cfg.analysis_csv = v;
        }
        if let Some(v) = map.get_str("cloud.consumer_group")? {
            cfg.consumer_group = v;
        }
        if let Some(v) = map.get_bool("cloud.results_stream")? {
            cfg.results_stream = v;
        }
        if let Some(v) = map.get_str("endpoint.wal_dir")? {
            cfg.wal_dir = v;
        }
        if let Some(v) = map.get_str("endpoint.fsync")? {
            cfg.wal_fsync = FsyncPolicy::parse(&v)?;
        }
        if let Some(v) = map.get_usize("endpoint.wal_segment_bytes")? {
            cfg.wal_segment_bytes = v;
        }
        if let Some(v) = map.get_bool("endpoint.retention")? {
            cfg.retention = v;
        }
        if let Some(v) = map.get_usize("endpoint.io_shards")? {
            cfg.io_shards = v;
        }
        if let Some(v) = map.get_usize("endpoint.read_ring_bytes")? {
            cfg.read_ring_bytes = v;
        }
        if let Some(v) = map.get_usize("endpoint.max_conns_per_shard")? {
            cfg.max_conns_per_shard = v;
        }
        if let Some(v) = map.get_u64("elastic.rebalance_ms")? {
            cfg.rebalance_ms = v;
        }
        if let Some(v) = map.get_u64("elastic.qos_flush_p95_us")? {
            cfg.qos_flush_p95_us = v;
        }
        if let Some(v) = map.get_u64("elastic.qos_queue_depth")? {
            cfg.qos_queue_depth = v;
        }
        if let Some(v) = map.get_u64("elastic.qos_reconnects")? {
            cfg.qos_reconnects = v;
        }
        if let Some(v) = map.get_usize("replication.factor")? {
            cfg.replication_factor = v;
        }
        if let Some(v) = map.get_str_list("replication.domains")? {
            cfg.replication_domains = v;
        }
        if let Some(v) = map.get_str("replication.ack")? {
            cfg.replication_ack = ReplAck::parse(&v)?;
        }
        if let Some(v) = map.get_u64("adapt.sweep_ms")? {
            cfg.adapt_sweep_ms = v;
        }
        if let Some(v) = map.get_u64("adapt.target_p95_us")? {
            cfg.adapt_target_p95_us = v;
        }
        if let Some(v) = map.get_u64("adapt.queue_hi")? {
            cfg.adapt_queue_hi = v;
        }
        if let Some(v) = map.get_u64("adapt.hysteresis")? {
            cfg.adapt_hysteresis = v as u32;
        }
        if let Some(v) = map.get_u64("obs.trace_sample")? {
            cfg.obs_trace_sample = v;
        }
        if let Some(v) = map.get_u64("obs.snapshot_ms")? {
            cfg.obs_snapshot_ms = v;
        }
        if let Some(v) = map.get_str("obs.dir")? {
            cfg.obs_dir = v;
        }
        if let Some(v) = map.get_usize("obs.events_ring")? {
            cfg.obs_events_ring = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check invariants the runtime relies on.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.ranks > 0, "ranks must be > 0");
        anyhow::ensure!(self.group_size > 0, "group_size must be > 0");
        anyhow::ensure!(self.executors > 0, "executors must be > 0");
        anyhow::ensure!(self.batch_max_records > 0, "batch_max_records must be > 0");
        anyhow::ensure!(self.store_shards > 0, "store_shards must be > 0");
        anyhow::ensure!(self.dmd_shards > 0, "dmd_shards must be > 0");
        anyhow::ensure!(
            self.dmd_rank <= self.dmd_window,
            "dmd_rank {} > dmd_window {}",
            self.dmd_rank,
            self.dmd_window
        );
        anyhow::ensure!(
            !(self.retention && self.wal_dir.is_empty()),
            "endpoint.retention requires endpoint.wal_dir (--persist-dir): \
             ack-based retention is log retention"
        );
        anyhow::ensure!(
            self.wal_dir.is_empty() || self.wal_segment_bytes > 0,
            "endpoint.wal_segment_bytes must be > 0"
        );
        anyhow::ensure!(self.io_shards > 0, "endpoint.io_shards must be > 0");
        anyhow::ensure!(
            self.read_ring_bytes >= 512,
            "endpoint.read_ring_bytes must be >= 512"
        );
        anyhow::ensure!(
            self.max_conns_per_shard > 0,
            "endpoint.max_conns_per_shard must be > 0"
        );
        anyhow::ensure!(
            self.obs_events_ring > 0,
            "obs.events_ring must be > 0"
        );
        anyhow::ensure!(
            self.obs_snapshot_ms == 0 || !self.obs_dir.is_empty(),
            "obs.snapshot_ms requires obs.dir (--obs-dir): snapshots need \
             somewhere to land"
        );
        anyhow::ensure!(
            (1..=3).contains(&self.replication_factor),
            "replication.factor {} out of range 1..=3",
            self.replication_factor
        );
        anyhow::ensure!(
            self.replication_factor <= self.endpoint_count(),
            "replication.factor {} exceeds the endpoint count {}: a chain \
             cannot visit the same endpoint twice",
            self.replication_factor,
            self.endpoint_count()
        );
        anyhow::ensure!(
            self.replication_factor == 1 || self.rebalance_ms > 0,
            "replication.factor > 1 requires elastic.rebalance_ms > 0: \
             failover is the rebalancer draining the dead head and \
             promoting its chain successor"
        );
        self.stages.validate()?;
        self.adapt().validate()?;
        self.rows_per_rank()?;
        Ok(())
    }

    /// The broker-side adaptation knobs as a typed [`AdaptConfig`].
    pub fn adapt(&self) -> crate::broker::AdaptConfig {
        crate::broker::AdaptConfig {
            sweep_ms: self.adapt_sweep_ms,
            target_p95_us: self.adapt_target_p95_us,
            queue_hi: self.adapt_queue_hi,
            hysteresis: self.adapt_hysteresis,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_experiment() {
        let c = WorkflowConfig::default();
        assert_eq!(c.ranks, 16);
        assert_eq!(c.steps, 2000);
        assert_eq!(c.trigger_ms, 3000);
        assert_eq!(c.endpoint_count(), 1); // 16 ranks : 1 endpoint
        assert_eq!(c.rows_per_rank().unwrap(), 16);
        assert_eq!(c.snapshot_dim().unwrap(), 16 * 128 * 2);
    }

    #[test]
    fn from_toml_overrides() {
        let c = WorkflowConfig::from_toml(
            r#"
            [sim]
            ranks = 32
            height = 256
            steps = 100
            io_mode = "file"
            use_pjrt = false

            [broker]
            queue_cap = 8
            drop_oldest = true
            batch_max_records = 128
            batch_max_bytes = 1048576
            linger_ms = 5

            [cloud]
            executors = 32
            trigger_ms = 500
            store_shards = 16
            "#,
        )
        .unwrap();
        assert_eq!(c.ranks, 32);
        assert_eq!(c.io_mode, IoMode::File);
        assert!(!c.use_pjrt);
        assert!(c.drop_oldest);
        assert_eq!(c.batch_max_records, 128);
        assert_eq!(c.batch_max_bytes, 1 << 20);
        assert_eq!(c.linger_ms, 5);
        assert_eq!(c.executors, 32);
        assert_eq!(c.store_shards, 16);
        assert_eq!(c.endpoint_count(), 2);
    }

    #[test]
    fn batching_defaults_and_validation() {
        let c = WorkflowConfig::default();
        assert_eq!(c.batch_max_records, 64);
        assert_eq!(c.batch_max_bytes, 4 << 20);
        assert_eq!(c.linger_ms, 0);
        assert_eq!(c.store_shards, 8);
        assert!(WorkflowConfig::from_toml("[broker]\nbatch_max_records = 0\n").is_err());
        assert!(WorkflowConfig::from_toml("[cloud]\nstore_shards = 0\n").is_err());
    }

    #[test]
    fn dmd_gram_knobs_parse_and_validate() {
        let c = WorkflowConfig::default();
        assert_eq!(c.dmd_gram_refresh, 64);
        assert_eq!(c.dmd_shards, 8);
        let c = WorkflowConfig::from_toml(
            "[cloud]\ndmd_gram_refresh = 16\ndmd_shards = 4\n",
        )
        .unwrap();
        assert_eq!(c.dmd_gram_refresh, 16);
        assert_eq!(c.dmd_shards, 4);
        // 0 refresh = never periodically rebuild (valid)
        assert_eq!(
            WorkflowConfig::from_toml("[cloud]\ndmd_gram_refresh = 0\n")
                .unwrap()
                .dmd_gram_refresh,
            0
        );
        assert!(WorkflowConfig::from_toml("[cloud]\ndmd_shards = 0\n").is_err());
    }

    #[test]
    fn stage_knobs_parse_and_validate() {
        let c = WorkflowConfig::default();
        assert!(c.stages.is_passthrough(), "stages off by default");
        let c = WorkflowConfig::from_toml(
            "[stages]\ndecimate = 2\nrank_stride = 2\nroi = \"8:120\"\n\
             aggregate = 4\nstats = true\nconvert = \"qdelta\"\n\
             qdelta_step = 0.0001\ncodec = \"shuffle-lz\"\n",
        )
        .unwrap();
        assert_eq!(c.stages.decimate, 2);
        assert_eq!(c.stages.rank_stride, 2);
        assert_eq!(c.stages.roi, Some((8, 120)));
        assert_eq!(c.stages.aggregate, 4);
        assert!(c.stages.stats);
        assert_eq!(c.stages.convert, Encoding::QDelta);
        assert!((c.stages.qdelta_step - 1e-4).abs() < 1e-10);
        assert_eq!(c.stages.codec, CodecKind::ShuffleLz);
        // invalid knobs are rejected through the shared validation
        assert!(WorkflowConfig::from_toml("[stages]\naggregate = 0\n").is_err());
        assert!(WorkflowConfig::from_toml("[stages]\nroi = \"9\"\n").is_err());
        assert!(WorkflowConfig::from_toml("[stages]\nconvert = \"f64\"\n").is_err());
        assert!(WorkflowConfig::from_toml("[stages]\ncodec = \"zstd\"\n").is_err());
        assert!(WorkflowConfig::from_toml(
            "[stages]\nconvert = \"qdelta\"\nqdelta_step = 0.0\n"
        )
        .is_err());
    }

    #[test]
    fn elastic_knobs_parse_with_defaults() {
        let c = WorkflowConfig::default();
        assert_eq!(c.rebalance_ms, 0, "elasticity off by default");
        assert_eq!(c.qos_flush_p95_us, 250_000);
        assert_eq!(c.qos_queue_depth, 48);
        assert_eq!(c.qos_reconnects, 3);
        let c = WorkflowConfig::from_toml(
            "[elastic]\nrebalance_ms = 200\nqos_flush_p95_us = 50000\n\
             qos_queue_depth = 16\nqos_reconnects = 5\n",
        )
        .unwrap();
        assert_eq!(c.rebalance_ms, 200);
        assert_eq!(c.qos_flush_p95_us, 50_000);
        assert_eq!(c.qos_queue_depth, 16);
        assert_eq!(c.qos_reconnects, 5);
    }

    #[test]
    fn adapt_knobs_parse_with_defaults() {
        let c = WorkflowConfig::default();
        assert_eq!(c.adapt_sweep_ms, 0, "adaptation off by default");
        assert_eq!(c.adapt_target_p95_us, 50_000);
        assert_eq!(c.adapt_queue_hi, 16);
        assert_eq!(c.adapt_hysteresis, 3);
        assert!(!c.adapt().enabled());
        let c = WorkflowConfig::from_toml(
            "[adapt]\nsweep_ms = 100\ntarget_p95_us = 20000\n\
             queue_hi = 8\nhysteresis = 2\n\n[stages]\nmax_err = 0.001\n",
        )
        .unwrap();
        assert_eq!(c.adapt_sweep_ms, 100);
        assert_eq!(c.adapt_target_p95_us, 20_000);
        assert_eq!(c.adapt_queue_hi, 8);
        assert_eq!(c.adapt_hysteresis, 2);
        assert!(c.adapt().enabled());
        assert!((c.stages.max_err - 1e-3).abs() < 1e-9);
        // an enabled controller needs a latency target and hysteresis
        assert!(WorkflowConfig::from_toml(
            "[adapt]\nsweep_ms = 100\ntarget_p95_us = 0\n"
        )
        .is_err());
        assert!(WorkflowConfig::from_toml(
            "[adapt]\nsweep_ms = 100\nhysteresis = 0\n"
        )
        .is_err());
        // a negative accuracy floor is rejected via stage validation
        assert!(WorkflowConfig::from_toml("[stages]\nmax_err = -0.5\n").is_err());
    }

    #[test]
    fn durability_knobs_parse_and_validate() {
        let c = WorkflowConfig::default();
        assert!(c.wal_dir.is_empty(), "persistence off by default");
        assert_eq!(c.wal_fsync, FsyncPolicy::EveryMs(5));
        assert_eq!(c.wal_segment_bytes, 64 << 20);
        assert!(!c.retention);
        let c = WorkflowConfig::from_toml(
            "[endpoint]\nwal_dir = \"/tmp/eb-wal\"\nfsync = \"always\"\n\
             wal_segment_bytes = 1048576\nretention = true\n",
        )
        .unwrap();
        assert_eq!(c.wal_dir, "/tmp/eb-wal");
        assert_eq!(c.wal_fsync, FsyncPolicy::Always);
        assert_eq!(c.wal_segment_bytes, 1 << 20);
        assert!(c.retention);
        // every_ms form
        let c = WorkflowConfig::from_toml(
            "[endpoint]\nwal_dir = \"w\"\nfsync = \"every_ms(25)\"\n",
        )
        .unwrap();
        assert_eq!(c.wal_fsync, FsyncPolicy::EveryMs(25));
        // retention without a wal_dir is rejected
        assert!(WorkflowConfig::from_toml("[endpoint]\nretention = true\n").is_err());
        // bad policy is rejected
        assert!(
            WorkflowConfig::from_toml("[endpoint]\nwal_dir = \"w\"\nfsync = \"meh\"\n")
                .is_err()
        );
    }

    #[test]
    fn io_core_knobs_parse_and_validate() {
        let c = WorkflowConfig::default();
        assert_eq!(c.io_shards, 4);
        assert_eq!(c.read_ring_bytes, 64 << 10);
        assert_eq!(c.max_conns_per_shard, 4096);
        let c = WorkflowConfig::from_toml(
            "[endpoint]\nio_shards = 2\nread_ring_bytes = 8192\n\
             max_conns_per_shard = 128\n",
        )
        .unwrap();
        assert_eq!(c.io_shards, 2);
        assert_eq!(c.read_ring_bytes, 8192);
        assert_eq!(c.max_conns_per_shard, 128);
        assert!(WorkflowConfig::from_toml("[endpoint]\nio_shards = 0\n").is_err());
        assert!(WorkflowConfig::from_toml("[endpoint]\nread_ring_bytes = 16\n").is_err());
        assert!(
            WorkflowConfig::from_toml("[endpoint]\nmax_conns_per_shard = 0\n").is_err()
        );
    }

    #[test]
    fn fanout_knobs_parse_with_defaults() {
        let c = WorkflowConfig::default();
        assert!(c.consumer_group.is_empty(), "default group by default");
        assert!(!c.results_stream, "results stream off by default");
        let c = WorkflowConfig::from_toml(
            "[cloud]\nconsumer_group = \"dashboard\"\nresults_stream = true\n",
        )
        .unwrap();
        assert_eq!(c.consumer_group, "dashboard");
        assert!(c.results_stream);
    }

    #[test]
    fn obs_knobs_parse_with_defaults() {
        let c = WorkflowConfig::default();
        assert_eq!(c.obs_trace_sample, 0, "tracing off by default");
        assert_eq!(c.obs_snapshot_ms, 0, "snapshot writer off by default");
        assert!(c.obs_dir.is_empty());
        assert_eq!(c.obs_events_ring, 1024);
        let c = WorkflowConfig::from_toml(
            "[obs]\ntrace_sample = 64\nsnapshot_ms = 500\n\
             dir = \"/tmp/eb-obs\"\nevents_ring = 256\n",
        )
        .unwrap();
        assert_eq!(c.obs_trace_sample, 64);
        assert_eq!(c.obs_snapshot_ms, 500);
        assert_eq!(c.obs_dir, "/tmp/eb-obs");
        assert_eq!(c.obs_events_ring, 256);
        // snapshots need a directory; an empty ring is meaningless
        assert!(WorkflowConfig::from_toml("[obs]\nsnapshot_ms = 100\n").is_err());
        assert!(WorkflowConfig::from_toml("[obs]\nevents_ring = 0\n").is_err());
    }

    #[test]
    fn replication_knobs_parse_and_validate() {
        let c = WorkflowConfig::default();
        assert_eq!(c.replication_factor, 1, "replication off by default");
        assert!(c.replication_domains.is_empty());
        assert_eq!(c.replication_ack, ReplAck::Tail);
        let c = WorkflowConfig::from_toml(
            "[sim]\nranks = 64\n[broker]\ngroup_size = 16\n\
             [elastic]\nrebalance_ms = 100\n\
             [replication]\nfactor = 2\ndomains = [\"a\", \"b\", \"c\"]\n\
             ack = \"head\"\n",
        )
        .unwrap();
        assert_eq!(c.replication_factor, 2);
        assert_eq!(c.replication_domains, vec!["a", "b", "c"]);
        assert_eq!(c.replication_ack, ReplAck::Head);
        // comma-separated string spelling (what the CLI forwards)
        let c = WorkflowConfig::from_toml(
            "[sim]\nranks = 32\n[elastic]\nrebalance_ms = 100\n\
             [replication]\nfactor = 2\ndomains = \"rack1, rack2\"\n",
        )
        .unwrap();
        assert_eq!(c.replication_domains, vec!["rack1", "rack2"]);
        // factor must fit 1..=3
        assert!(WorkflowConfig::from_toml("[replication]\nfactor = 0\n").is_err());
        assert!(WorkflowConfig::from_toml("[replication]\nfactor = 4\n").is_err());
        // a chain cannot be longer than the endpoint list (16 ranks →
        // one endpoint by default)
        assert!(WorkflowConfig::from_toml(
            "[elastic]\nrebalance_ms = 100\n[replication]\nfactor = 2\n"
        )
        .is_err());
        // replication without the rebalancer has no failover path
        assert!(WorkflowConfig::from_toml(
            "[sim]\nranks = 32\n[replication]\nfactor = 2\n"
        )
        .is_err());
        // unknown ack mode is rejected
        assert!(WorkflowConfig::from_toml(
            "[sim]\nranks = 32\n[elastic]\nrebalance_ms = 100\n\
             [replication]\nfactor = 2\nack = \"quorum\"\n"
        )
        .is_err());
    }

    #[test]
    fn invalid_decomposition_rejected() {
        let res = WorkflowConfig::from_toml("[sim]\nranks = 7\n");
        assert!(res.is_err());
    }

    #[test]
    fn invalid_rank_window_rejected() {
        let res = WorkflowConfig::from_toml("[cloud]\ndmd_rank = 12\ndmd_window = 4\n");
        assert!(res.is_err());
    }

    #[test]
    fn io_mode_names_roundtrip() {
        for m in [IoMode::File, IoMode::Broker, IoMode::None] {
            assert_eq!(IoMode::parse(m.name()).unwrap(), m);
        }
    }
}
