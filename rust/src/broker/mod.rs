//! The ElasticBroker HPC-side library (the paper's §3.1 contribution).
//!
//! Mirrors the paper's C/C++ API (Listing 1.1):
//!
//! ```text
//! broker_ctx* broker_init(char* field_name, int group_id);
//! broker_write(broker_ctx*, int step, void* data, size_t len);
//! broker_finalize(broker_ctx*);
//! ```
//!
//! as [`Broker::init`] → [`BrokerCtx::write`] → [`BrokerCtx::finalize`].
//!
//! Key properties reproduced from the paper:
//!
//! * **Process groups** ([`groups`]): ranks are divided into groups;
//!   every rank in a group registers with the group's designated Cloud
//!   endpoint (Fig 1), so endpoint fan-in is bounded and bandwidth can
//!   be provisioned per group.
//! * **Asynchronous writes** (the Fig 6 result): `write` transforms the
//!   field into a stream record and enqueues it on a bounded in-memory
//!   queue, returning to the simulation immediately; a background
//!   writer thread ships records to the endpoint.  Queue-full policy is
//!   configurable: `Block` (backpressure, no loss — default) or
//!   `DropOldest` (bounded staleness, lossy).
//! * **Batched pipelined shipping**: the writer drains the queue in
//!   coalesced batches ([`BoundedQueue::drain_batch`]) and ships each
//!   batch as one pipelined `XADD` frame
//!   ([`crate::transport::RespConn::pipeline`]) — one round trip per
//!   batch instead of per record.  Knobs: `batch_max_records`,
//!   `batch_max_bytes` and `linger_ms` on [`BrokerConfig`] (linger
//!   trades a bounded latency add for fuller batches; the 0 default
//!   ships whatever has queued the moment the writer is free, so an
//!   idle stream still sees per-record latency).
//! * **Filtering / aggregation / format conversion** ([`filter`]):
//!   optional per-context stages applied before serialization.

pub mod filter;
pub mod groups;
mod queue;

pub use filter::{Filter, FilterStage};
pub use groups::GroupMap;
pub use queue::{BoundedQueue, QueuePolicy};

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::metrics::WorkflowMetrics;
use crate::record::StreamRecord;
use crate::transport::{ConnConfig, Request, RespConn};
use crate::util;

/// Broker-wide configuration shared by all contexts of a process.
#[derive(Clone, Debug)]
pub struct BrokerConfig {
    /// Cloud endpoints, one per process group (paper Fig 1).
    pub endpoints: Vec<SocketAddr>,
    /// Ranks per group (paper default 16).
    pub group_size: usize,
    /// Bounded queue capacity per context (records).
    pub queue_cap: usize,
    /// Queue-full policy.
    pub policy: QueuePolicy,
    /// Transport settings (reconnect, optional WAN throttle).
    pub conn: ConnConfig,
    /// Optional data-reduction pipeline applied in `write`.
    pub filter: Filter,
    /// Max records coalesced into one pipelined `XADD` batch.
    pub batch_max_records: usize,
    /// Max payload bytes per batch (0 = unbounded; the first record of
    /// a batch always ships even when it alone exceeds this).
    pub batch_max_bytes: usize,
    /// How long the writer lingers for a batch to fill once it holds at
    /// least one record (ms; 0 = ship immediately).  Non-zero values
    /// trade up to that much added latency for fuller batches.
    pub linger_ms: u64,
}

impl BrokerConfig {
    pub fn new(endpoints: Vec<SocketAddr>) -> Self {
        BrokerConfig {
            endpoints,
            group_size: 16,
            queue_cap: 64,
            policy: QueuePolicy::Block,
            conn: ConnConfig::default(),
            filter: Filter::passthrough(),
            batch_max_records: 64,
            batch_max_bytes: 4 << 20, // 4 MiB
            linger_ms: 0,
        }
    }
}

/// Factory for per-(rank, field) contexts.
pub struct Broker {
    cfg: BrokerConfig,
    groups: GroupMap,
    metrics: WorkflowMetrics,
}

impl Broker {
    pub fn new(cfg: BrokerConfig, total_ranks: usize, metrics: WorkflowMetrics) -> Result<Self> {
        let groups = GroupMap::new(total_ranks, cfg.group_size, cfg.endpoints.len())?;
        Ok(Broker {
            cfg,
            groups,
            metrics,
        })
    }

    pub fn groups(&self) -> &GroupMap {
        &self.groups
    }

    /// `broker_init`: register `field` for `rank`, connect to the
    /// group's endpoint and start the background writer.
    pub fn init(&self, field: &str, rank: u32) -> Result<BrokerCtx> {
        self.init_filtered(field, rank, self.cfg.filter.clone())
    }

    /// `broker_init` with a per-field reduction pipeline (e.g. stream a
    /// strided or magnitude-aggregated view of one field while another
    /// ships raw).
    pub fn init_filtered(&self, field: &str, rank: u32, filter: Filter) -> Result<BrokerCtx> {
        let endpoint_idx = self.groups.endpoint_of_rank(rank as usize)?;
        let addr = self.cfg.endpoints[endpoint_idx];
        let queue = Arc::new(BoundedQueue::new(self.cfg.queue_cap, self.cfg.policy));
        let key = crate::record::stream_key(field, rank);
        let conn_cfg = self.cfg.conn.clone();
        let batching = BatchTuning {
            max_records: self.cfg.batch_max_records.max(1),
            max_bytes: self.cfg.batch_max_bytes,
            linger: Duration::from_millis(self.cfg.linger_ms),
        };
        let metrics = self.metrics.clone();
        let wq = queue.clone();
        let wkey = key.clone();
        let writer = std::thread::Builder::new()
            .name(format!("broker-writer-{key}"))
            .spawn(move || {
                let res = writer_loop(addr, conn_cfg, batching, &wq, wkey, metrics);
                if res.is_err() {
                    // A dead writer must never leave the producer blocked
                    // on a full queue: close it so pushes become drops.
                    wq.close();
                }
                res
            })?;
        log::debug!("broker: rank {rank} field '{field}' registered with endpoint {addr}");
        Ok(BrokerCtx {
            field: field.to_string(),
            rank,
            queue,
            writer: Some(writer),
            filter,
            metrics: self.metrics.clone(),
        })
    }
}

/// A registered (field, rank) write context — the paper's `broker_ctx`.
pub struct BrokerCtx {
    field: String,
    rank: u32,
    queue: Arc<BoundedQueue<StreamRecord>>,
    writer: Option<std::thread::JoinHandle<Result<()>>>,
    filter: Filter,
    metrics: WorkflowMetrics,
}

impl BrokerCtx {
    /// `broker_write`: transform the in-memory field into a stream
    /// record and enqueue it.  Returns as soon as the record is queued
    /// (the paper's asynchronous-write property); blocks only when the
    /// queue is full under `QueuePolicy::Block`.
    pub fn write(&self, step: u64, shape: &[u32], data: &[f32]) -> Result<()> {
        let t0 = Instant::now();
        let (shape, reduced) = self.filter.apply(shape, data)?;
        let record = StreamRecord::from_f32(
            &self.field,
            self.rank,
            step,
            util::epoch_micros(),
            &shape,
            &reduced,
        )?;
        let dropped = self.queue.push(record);
        if dropped > 0 {
            self.metrics.dropped.add(dropped as u64);
        }
        self.metrics
            .write_call_us
            .record(t0.elapsed().as_micros() as u64);
        Ok(())
    }

    /// `broker_finalize`: flush the queue, stop and join the writer.
    pub fn finalize(mut self) -> Result<()> {
        self.queue.close();
        if let Some(h) = self.writer.take() {
            match h.join() {
                Ok(res) => res.with_context(|| {
                    format!("broker writer for {}/{} failed", self.field, self.rank)
                })?,
                Err(_) => anyhow::bail!("broker writer panicked"),
            }
        }
        Ok(())
    }

    /// Records currently waiting in the queue (diagnostics).
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    pub fn stream_key(&self) -> String {
        crate::record::stream_key(&self.field, self.rank)
    }
}

impl Drop for BrokerCtx {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

/// Writer-side batching knobs (resolved from [`BrokerConfig`]).
#[derive(Clone, Copy, Debug)]
struct BatchTuning {
    max_records: usize,
    max_bytes: usize,
    linger: Duration,
}

/// Background writer: drain coalesced batches, serialize, ship each
/// batch as one pipelined `XADD` frame.
///
/// An `OOM` reply (endpoint over its memory budget) is retried with
/// backoff — that is exactly how backpressure propagates upstream: the
/// writer stalls, the bounded queue fills, and `broker_write` blocks
/// (Block) or sheds old snapshots (DropOldest).  Within a batch only
/// the records that actually got `OOM` are retried, preserving their
/// relative order and appending each record exactly once.  One caveat:
/// if endpoint memory frees *mid-frame* (a concurrent `DEL`/trim from
/// another connection), a later record of the same batch can succeed
/// while an earlier one OOMs, and the retried record then lands after
/// it — server-assigned ids cannot be backdated, so that inversion is
/// unrepairable client-side.  It is detected and logged; the analysis
/// layer's stale-step filter skips the late record (it stays readable
/// in the store via XRANGE).  Retrying is bounded so a permanently
/// wedged endpoint surfaces as an error, not a livelock.
fn writer_loop(
    addr: SocketAddr,
    conn_cfg: ConnConfig,
    batching: BatchTuning,
    queue: &BoundedQueue<StreamRecord>,
    key: String,
    metrics: WorkflowMetrics,
) -> Result<()> {
    const OOM_RETRY_EVERY: Duration = Duration::from_millis(25);
    const OOM_RETRY_LIMIT: u32 = 1200; // 30 s of patience

    let mut conn = RespConn::connect(addr, conn_cfg)?;
    while let Some(records) = queue.drain_batch(
        batching.max_records,
        batching.max_bytes,
        batching.linger,
        StreamRecord::encoded_len,
    ) {
        let mut reqs: Vec<Request> = Vec::with_capacity(records.len());
        let mut lens: Vec<usize> = Vec::with_capacity(records.len());
        for record in &records {
            let payload = record.encode();
            lens.push(payload.len());
            reqs.push(
                Request::new("XADD")
                    .arg(key.as_bytes())
                    .arg("*")
                    .arg("r")
                    .arg(payload),
            );
        }
        metrics.batch_records.record(reqs.len() as u64);
        let t0 = Instant::now();
        let mut oom_attempts = 0u32;
        while !reqs.is_empty() {
            // While backing off from OOM, probe with a single record
            // instead of re-pipelining the whole doomed batch: on a
            // wedged endpoint this costs one record per 25 ms tick
            // (the pre-batching behaviour) rather than burning the
            // possibly-throttled WAN link on megabytes of retries.
            // Once the probe lands, the remainder ships as a batch.
            let send = if oom_attempts == 0 { reqs.len() } else { 1 };
            let replies = conn.pipeline(&reqs[..send])?;
            let mut failed = vec![false; send];
            let mut n_failed = 0usize;
            let mut ok_after_failure = false;
            for (i, reply) in replies.iter().enumerate() {
                if reply.is_error() {
                    let msg = reply.as_str_lossy();
                    anyhow::ensure!(msg.starts_with("OOM"), "endpoint rejected XADD: {msg}");
                    failed[i] = true;
                    n_failed += 1;
                } else {
                    ok_after_failure |= n_failed > 0;
                    metrics.shipped.record(lens[i] as u64);
                }
            }
            if ok_after_failure {
                // Endpoint memory freed mid-frame: a later record landed
                // ahead of an OOM'd one.  The retry re-ships the OOM'd
                // records, but their ids will postdate it (see the
                // ordering caveat in the function docs).
                log::warn!(
                    "broker: stream {key}: record landed ahead of an OOM'd \
                     predecessor; retried records will arrive out of order"
                );
            }
            if n_failed > 0 {
                oom_attempts += 1;
                anyhow::ensure!(
                    oom_attempts <= OOM_RETRY_LIMIT,
                    "endpoint {addr} OOM for more than {:?} without progress",
                    OOM_RETRY_EVERY * OOM_RETRY_LIMIT
                );
                if oom_attempts == 1 {
                    log::warn!(
                        "broker: endpoint {addr} OOM on {n_failed}/{send} records; backing off"
                    );
                }
                std::thread::sleep(OOM_RETRY_EVERY);
            } else {
                oom_attempts = 0; // progress: next attempt batches again
            }
            // Keep this attempt's rejected records (in order) plus the
            // not-yet-attempted tail.
            let mut i = 0;
            reqs.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
            let mut i = 0;
            lens.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
        }
        metrics.flush_us.record(t0.elapsed().as_micros() as u64);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::endpoint::{EndpointServer, StoreConfig};

    fn setup() -> (EndpointServer, Broker) {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 4,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let broker = Broker::new(cfg, 4, WorkflowMetrics::new()).unwrap();
        (srv, broker)
    }

    #[test]
    fn write_lands_in_endpoint_stream() {
        let (srv, broker) = setup();
        let ctx = broker.init("velocity", 2).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for step in 0..5 {
            ctx.write(step, &[2, 32], &data).unwrap();
        }
        ctx.finalize().unwrap();
        // all records shipped and decodable
        let store = srv.store();
        assert_eq!(store.xlen("velocity/2"), 5);
        let entries = store.read_after("velocity/2", crate::endpoint::EntryId::ZERO, 0);
        let rec = StreamRecord::decode(&entries[0].fields[0].1).unwrap();
        assert_eq!(rec.field, "velocity");
        assert_eq!(rec.rank, 2);
        assert_eq!(rec.step, 0);
        assert_eq!(rec.payload_f32().unwrap(), data);
    }

    #[test]
    fn finalize_flushes_backlog() {
        let (srv, broker) = setup();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 256];
        for step in 0..50 {
            ctx.write(step, &[256], &data).unwrap();
        }
        ctx.finalize().unwrap(); // must not lose queued records
        assert_eq!(srv.store().xlen("u/0"), 50);
    }

    #[test]
    fn write_returns_before_ship_completes() {
        // The asynchronous-write property: with a slow (throttled) link,
        // write() must still return quickly.
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 128,
            // cap batches below the burst size so the throttle stall is
            // visible as backlog even if the writer wakes up late
            batch_max_records: 4,
            conn: ConnConfig {
                throttle_bytes_per_sec: Some(200_000.0),
                ..Default::default()
            },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 16 * 1024]; // 64 KiB per record
        let t0 = Instant::now();
        for step in 0..8 {
            ctx.write(step, &[16 * 1024], &data).unwrap();
        }
        let call_time = t0.elapsed();
        // 8 × 64 KiB at 200 KB/s would take ~2.5 s synchronously.
        assert!(
            call_time.as_millis() < 500,
            "writes not asynchronous: {call_time:?}"
        );
        assert!(ctx.backlog() > 0, "expected queued records");
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 8);
        assert!(metrics.write_call_us.count() == 8);
    }

    #[test]
    fn drop_oldest_policy_sheds_load() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 4,
            policy: QueuePolicy::DropOldest,
            conn: ConnConfig {
                throttle_bytes_per_sec: Some(50_000.0),
                ..Default::default()
            },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 8 * 1024];
        for step in 0..40 {
            ctx.write(step, &[8 * 1024], &data).unwrap();
        }
        ctx.finalize().unwrap();
        let landed = srv.store().xlen("u/0");
        let dropped = metrics.dropped.get() as usize;
        assert_eq!(landed + dropped, 40, "landed {landed} + dropped {dropped}");
        assert!(dropped > 0, "expected drops under a 4-deep queue");
    }

    #[test]
    fn linger_coalesces_writes_into_batches() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 64,
            linger_ms: 60, // let the writer absorb the whole burst
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 64];
        for step in 0..16 {
            ctx.write(step, &[64], &data).unwrap();
        }
        ctx.finalize().unwrap();
        // everything landed exactly once, in order
        assert_eq!(srv.store().xlen("u/0"), 16);
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let steps: Vec<u64> = entries
            .iter()
            .map(|e| StreamRecord::decode(&e.fields[0].1).unwrap().step)
            .collect();
        assert_eq!(steps, (0..16).collect::<Vec<_>>());
        // and it took fewer flushes than records: coalescing happened
        assert_eq!(metrics.shipped.records(), 16);
        let flushes = metrics.batch_records.count();
        assert!(flushes < 16, "no coalescing: {flushes} flushes for 16 records");
        assert!(metrics.batch_records.max() >= 2);
        assert_eq!(metrics.flush_us.count(), flushes);
    }

    #[test]
    fn batch_byte_budget_splits_batches() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 64,
            linger_ms: 60,
            // each record is ~4 KiB encoded; cap batches at ~2 records
            batch_max_bytes: 9 * 1024,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 1024];
        for step in 0..8 {
            ctx.write(step, &[1024], &data).unwrap();
        }
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 8);
        assert!(
            metrics.batch_records.max() <= 2,
            "byte budget ignored: max batch {}",
            metrics.batch_records.max()
        );
    }

    #[test]
    fn multiple_ranks_one_endpoint() {
        let (srv, broker) = setup();
        let ctxs: Vec<_> = (0..4).map(|r| broker.init("velocity", r).unwrap()).collect();
        let data = vec![1.0f32; 32];
        for ctx in &ctxs {
            ctx.write(7, &[32], &data).unwrap();
        }
        for ctx in ctxs {
            ctx.finalize().unwrap();
        }
        for r in 0..4 {
            assert_eq!(srv.store().xlen(&format!("velocity/{r}")), 1);
        }
    }

    #[test]
    fn rank_out_of_range_rejected() {
        let (_srv, broker) = setup();
        assert!(broker.init("u", 99).is_err());
    }

    #[test]
    fn filtered_write_reduces_payload() {
        let (srv, broker) = setup();
        let ctx_filtered = broker
            .init_filtered("u", 0, Filter::new(vec![FilterStage::Stride(4)]))
            .unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        ctx_filtered.write(0, &[64], &data).unwrap();
        ctx_filtered.finalize().unwrap();
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let rec = StreamRecord::decode(&entries[0].fields[0].1).unwrap();
        assert_eq!(rec.payload_f32().unwrap().len(), 16);
    }
}
