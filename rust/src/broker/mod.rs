//! The ElasticBroker HPC-side library (the paper's §3.1 contribution).
//!
//! Mirrors the paper's C/C++ API (Listing 1.1):
//!
//! ```text
//! broker_ctx* broker_init(char* field_name, int group_id);
//! broker_write(broker_ctx*, int step, void* data, size_t len);
//! broker_finalize(broker_ctx*);
//! ```
//!
//! as [`Broker::init`] → [`BrokerCtx::write`] → [`BrokerCtx::finalize`].
//!
//! Key properties reproduced from the paper:
//!
//! * **Process groups** ([`groups`]): ranks are divided into groups;
//!   every rank in a group registers with the group's designated Cloud
//!   endpoint (Fig 1), so endpoint fan-in is bounded and bandwidth can
//!   be provisioned per group.
//! * **Asynchronous writes** (the Fig 6 result): `write` transforms the
//!   field into a stream record and enqueues it on a bounded in-memory
//!   queue, returning to the simulation immediately; a background
//!   writer thread ships records to the endpoint.  Queue-full policy is
//!   configurable: `Block` (backpressure, no loss — default) or
//!   `DropOldest` (bounded staleness, lossy).
//! * **Batched pipelined shipping**: the writer drains the queue in
//!   coalesced batches ([`BoundedQueue::drain_batch`]) and ships each
//!   batch as one pipelined `XADD` frame
//!   ([`crate::transport::RespConn::pipeline`]) — one round trip per
//!   batch instead of per record.  Knobs: `batch_max_records`,
//!   `batch_max_bytes` and `linger_ms` on [`BrokerConfig`] (linger
//!   trades a bounded latency add for fuller batches; the 0 default
//!   ships whatever has queued the moment the writer is free, so an
//!   idle stream still sees per-record latency).
//! * **Filtering / aggregation / format conversion** ([`filter`],
//!   [`stages`]): [`filter`] declares per-context value transforms
//!   (stride / magnitude / clamp / threshold) which the broker folds
//!   into the head of the stage pipeline's filter stage (ISSUE 6, so
//!   one reduction mechanism exists and every reduced byte is
//!   accounted); [`stages`] (ISSUE 5) is the full data-reduction stage
//!   pipeline — filter (transforms / decimation / rank subset / ROI) → aggregate
//!   (block-mean + sidecar stats) → convert (f16 / quantized delta
//!   with stated error bound) → compress (byte-shuffle + LZ behind the
//!   [`crate::record::Codec`] trait) — producing self-describing
//!   `EBR2` frames the Cloud side decodes transparently.  See
//!   ROADMAP.md §"Reduction model".
//! * **Elasticity** (ISSUE 3, the paper's namesake behaviour): the
//!   group→endpoint assignment is a versioned [`Topology`] rather than
//!   a constant.  Writers ship through the epoch-fenced [`Shipper`]
//!   protocol (`HELLO` registration, `XADDF` fenced writes, `XHANDOFF`
//!   tombstones), migrate between endpoints at batch boundaries with
//!   no record loss or duplication, and a QoS-driven [`Rebalancer`]
//!   moves groups off dead or saturated endpoints at runtime.  See
//!   ROADMAP.md §"Elasticity model".

pub mod adapt;
pub mod filter;
pub mod groups;
mod queue;
pub mod rebalancer;
pub mod shipper;
pub mod stages;
pub mod topology;

pub use adapt::{AdaptConfig, AdaptController, AdaptRegistry, Ladder, StreamAdapt};
pub use filter::{Filter, FilterStage};
pub use groups::GroupMap;
pub use queue::{BoundedQueue, QueuePolicy};
pub use rebalancer::{EndpointSample, MigrationPlan, QosThresholds, Rebalancer};
pub use shipper::Shipper;
pub use stages::{StagePipeline, StagesConfig};
pub use topology::{EndpointSlot, Topology, TopologyHandle};

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::metrics::WorkflowMetrics;
use crate::record::{CodecKind, Encoding, FrameMeta, StreamRecord, Trace};
use crate::transport::{ConnConfig, Dialer, TcpDialer};
use crate::util;

/// Broker-wide configuration shared by all contexts of a process.
#[derive(Clone, Debug)]
pub struct BrokerConfig {
    /// Cloud endpoints, one per process group (paper Fig 1).
    pub endpoints: Vec<SocketAddr>,
    /// Ranks per group (paper default 16).
    pub group_size: usize,
    /// Bounded queue capacity per context (records).
    pub queue_cap: usize,
    /// Queue-full policy.
    pub policy: QueuePolicy,
    /// Transport settings (reconnect, optional WAN throttle).
    pub conn: ConnConfig,
    /// Optional data-reduction pipeline applied in `write`.
    pub filter: Filter,
    /// Stage-pipeline knobs (filter → aggregate → convert → compress,
    /// ISSUE 5); the default is a passthrough that ships classic raw
    /// `EBR1` frames.
    pub stages: StagesConfig,
    /// Closed-loop adaptive reduction (ISSUE 8): when enabled
    /// (`adapt.sweep_ms > 0`), each context walks a reduction ladder
    /// built from `stages` under QoS pressure instead of using the
    /// static config directly.  The [`AdaptController`] must be
    /// started (e.g. by the workflow) for levels to actually move.
    pub adapt: AdaptConfig,
    /// Max records coalesced into one pipelined `XADD` batch.
    pub batch_max_records: usize,
    /// Max payload bytes per batch (0 = unbounded; the first record of
    /// a batch always ships even when it alone exceeds this).
    pub batch_max_bytes: usize,
    /// How long the writer lingers for a batch to fill once it holds at
    /// least one record (ms; 0 = ship immediately).  Non-zero values
    /// trade up to that much added latency for fuller batches.
    pub linger_ms: u64,
    /// Staleness-trace sampling (ISSUE 9): stamp every Nth write per
    /// context with a [`Trace`] carried in the frame header; 0 (the
    /// default) disables tracing entirely — the unsampled hot path
    /// does no extra work and frames do not grow.
    pub trace_sample: u64,
}

impl BrokerConfig {
    pub fn new(endpoints: Vec<SocketAddr>) -> Self {
        BrokerConfig {
            endpoints,
            group_size: 16,
            queue_cap: 64,
            policy: QueuePolicy::Block,
            conn: ConnConfig::default(),
            filter: Filter::passthrough(),
            stages: StagesConfig::default(),
            adapt: AdaptConfig::default(),
            batch_max_records: 64,
            batch_max_bytes: 4 << 20, // 4 MiB
            linger_ms: 0,
            trace_sample: 0,
        }
    }
}

/// Factory for per-(rank, field) contexts.
///
/// [`Broker::new`] builds the classic static topology (group `g` →
/// endpoint `g % n`, fixed addresses) — every pre-elastic caller keeps
/// working unchanged.  [`Broker::with_topology`] attaches the broker
/// to a shared, mutable [`TopologyHandle`] instead: writers then
/// follow epoch bumps (scale-out, scale-in, rebalancing) at batch
/// boundaries via the [`Shipper`] migration protocol.
pub struct Broker {
    cfg: BrokerConfig,
    topology: TopologyHandle,
    dialer: Arc<dyn Dialer>,
    metrics: WorkflowMetrics,
    /// Shared data-reduction pipeline every context writes through.
    stages: Arc<StagePipeline>,
    /// Prebuilt reduction ladder + stream directory when adaptive
    /// reduction is enabled (ISSUE 8).
    ladder: Option<Arc<adapt::Ladder>>,
    registry: AdaptRegistry,
}

impl Broker {
    pub fn new(cfg: BrokerConfig, total_ranks: usize, metrics: WorkflowMetrics) -> Result<Self> {
        let groups = GroupMap::new(total_ranks, cfg.group_size, cfg.endpoints.len())?;
        let topology = TopologyHandle::new_static(groups, cfg.endpoints.clone())?;
        let resolver = topology.clone();
        let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
            move |e| resolver.endpoint_addr(e),
            cfg.conn.clone(),
        ));
        let stages = Arc::new(StagePipeline::new(
            cfg.stages.clone(),
            metrics.stages.clone(),
        )?);
        let ladder = Self::build_ladder(&cfg, &metrics)?;
        Ok(Broker {
            cfg,
            topology,
            dialer,
            metrics,
            stages,
            ladder,
            registry: AdaptRegistry::new(),
        })
    }

    /// Elastic constructor: writers ship per `topology` (shared with
    /// the rebalancer and the Cloud-side [`crate::streamproc::ElasticReader`])
    /// through `dialer`.  `cfg.endpoints` is ignored — the topology
    /// owns endpoint addressing.  Fails only on an invalid
    /// [`BrokerConfig::stages`] config.
    pub fn with_topology(
        cfg: BrokerConfig,
        topology: TopologyHandle,
        dialer: Arc<dyn Dialer>,
        metrics: WorkflowMetrics,
    ) -> Result<Broker> {
        let stages = Arc::new(StagePipeline::new(
            cfg.stages.clone(),
            metrics.stages.clone(),
        )?);
        let ladder = Self::build_ladder(&cfg, &metrics)?;
        Ok(Broker {
            cfg,
            topology,
            dialer,
            metrics,
            stages,
            ladder,
            registry: AdaptRegistry::new(),
        })
    }

    fn build_ladder(
        cfg: &BrokerConfig,
        metrics: &WorkflowMetrics,
    ) -> Result<Option<Arc<adapt::Ladder>>> {
        if !cfg.adapt.enabled() {
            return Ok(None);
        }
        cfg.adapt.validate()?;
        Ok(Some(adapt::Ladder::build(
            &cfg.stages,
            metrics.stages.clone(),
        )?))
    }

    /// The rank→group partition (a small copy; the assignment half of
    /// the topology is versioned and lives behind [`Broker::topology`]).
    pub fn groups(&self) -> GroupMap {
        self.topology.snapshot().groups
    }

    /// The shared versioned topology this broker ships by.
    pub fn topology(&self) -> &TopologyHandle {
        &self.topology
    }

    /// The shared stream directory the [`AdaptController`] sweeps
    /// (empty and inert unless `cfg.adapt` is enabled).
    pub fn adapt_registry(&self) -> AdaptRegistry {
        self.registry.clone()
    }

    /// Whether contexts from this broker take the adaptive write path.
    pub fn adapt_enabled(&self) -> bool {
        self.ladder.is_some()
    }

    /// `broker_init`: register `field` for `rank`, connect to the
    /// group's endpoint and start the background writer.
    pub fn init(&self, field: &str, rank: u32) -> Result<BrokerCtx> {
        self.init_filtered(field, rank, self.cfg.filter.clone())
    }

    /// `broker_init` with a per-field reduction pipeline (e.g. stream a
    /// strided or magnitude-aggregated view of one field while another
    /// ships raw).  The transforms are folded into the context's stage
    /// pipeline (ISSUE 6): they run at the head of the filter stage and
    /// their reductions are part of the shared [`StageMetrics`] byte
    /// accounting.
    ///
    /// [`StageMetrics`]: crate::metrics::StageMetrics
    pub fn init_filtered(&self, field: &str, rank: u32, filter: Filter) -> Result<BrokerCtx> {
        // Validate the rank synchronously (the paper API returns the
        // error from broker_init, not from a later write).
        let group = self.topology.snapshot().groups.group_of_rank(rank as usize)?;
        // Per-context transforms prepend to the broker-wide stage
        // config; the pipeline shares the broker's StageMetrics so all
        // reduction accounting lands in one place.
        let ctx_cfg = if filter.is_passthrough() {
            None
        } else {
            let mut scfg = self.cfg.stages.clone();
            let mut transforms = filter.into_stages();
            transforms.extend(scfg.transforms);
            scfg.transforms = transforms;
            Some(scfg)
        };
        let stages = match &ctx_cfg {
            None => self.stages.clone(),
            Some(scfg) => Arc::new(StagePipeline::new(
                scfg.clone(),
                self.metrics.stages.clone(),
            )?),
        };
        let queue = Arc::new(BoundedQueue::new(self.cfg.queue_cap, self.cfg.policy));
        let key = crate::record::stream_key(field, rank);
        // Adaptive path (ISSUE 8): contexts with their own transforms
        // get their own ladder (transforms fold into every rung);
        // plain contexts share the broker's.
        let adapt_state = match &self.ladder {
            None => None,
            Some(ladder) => {
                let ladder = match &ctx_cfg {
                    None => ladder.clone(),
                    Some(scfg) => {
                        adapt::Ladder::build(scfg, self.metrics.stages.clone())?
                    }
                };
                let state =
                    StreamAdapt::new(key.clone(), group, ladder, queue.clone());
                self.registry.register(state.clone());
                Some(state)
            }
        };
        let batching = BatchTuning {
            max_records: self.cfg.batch_max_records.max(1),
            max_bytes: self.cfg.batch_max_bytes,
            linger: Duration::from_millis(self.cfg.linger_ms),
        };
        let metrics = self.metrics.clone();
        let topology = self.topology.clone();
        let dialer = self.dialer.clone();
        let max_recover = self.cfg.conn.max_retries.max(1);
        let wq = queue.clone();
        let wkey = key.clone();
        let writer = std::thread::Builder::new()
            .name(format!("broker-writer-{key}"))
            .spawn(move || {
                let res = Shipper::register(
                    wkey, group, topology, dialer, metrics.clone(), max_recover,
                )
                .and_then(|mut shipper| writer_loop(&mut shipper, batching, &wq, metrics));
                if res.is_err() {
                    // A dead writer must never leave the producer blocked
                    // on a full queue: close it so pushes become drops.
                    wq.close();
                }
                res
            })?;
        log::debug!("broker: rank {rank} field '{field}' registered (group {group})");
        Ok(BrokerCtx {
            field: field.to_string(),
            rank,
            queue,
            writer: Some(writer),
            stages,
            adapt: adapt_state,
            write_seq: AtomicU64::new(0),
            trace_sample: self.cfg.trace_sample,
            metrics: self.metrics.clone(),
        })
    }
}

/// A registered (field, rank) write context — the paper's `broker_ctx`.
pub struct BrokerCtx {
    field: String,
    rank: u32,
    queue: Arc<BoundedQueue<StreamRecord>>,
    writer: Option<std::thread::JoinHandle<Result<()>>>,
    /// Shared data-reduction stage pipeline (ISSUE 5); contexts with
    /// per-field transforms ([`Broker::init_filtered`]) hold their own
    /// pipeline sharing the broker's metrics (ISSUE 6).
    stages: Arc<StagePipeline>,
    /// Adaptive-reduction state when the broker runs with
    /// `adapt.sweep_ms > 0` (ISSUE 8): writes then encode at the
    /// stream's current ladder level instead of through `stages`.
    adapt: Option<Arc<StreamAdapt>>,
    /// Writes issued through this context — the sequence the decimation
    /// filter counts (independent of the simulation step numbering).
    write_seq: AtomicU64,
    /// Stamp a staleness [`Trace`] on every Nth write (0 = off).
    trace_sample: u64,
    metrics: WorkflowMetrics,
}

impl BrokerCtx {
    /// `broker_write`: transform the in-memory field into a stream
    /// record and enqueue it.  Returns as soon as the record is queued
    /// (the paper's asynchronous-write property); blocks only when the
    /// queue is full under `QueuePolicy::Block`.
    ///
    /// The record runs the [`StagePipeline`] (filter — including any
    /// per-context [`Filter`] transforms — → aggregate → convert →
    /// compress).  A record the stage filter decides never ships
    /// (decimation, rank subsetting) returns `Ok` without enqueueing —
    /// intentional reduction, not loss.
    pub fn write(&self, step: u64, shape: &[u32], data: &[f32]) -> Result<()> {
        let t0 = Instant::now();
        let seq = self.write_seq.fetch_add(1, Ordering::Relaxed);
        let staged = match &self.adapt {
            // Adaptive path (ISSUE 8): encode at the stream's current
            // ladder level, per-frame accuracy admission included.
            Some(ad) => ad.encode(
                &self.field,
                self.rank,
                step,
                seq,
                util::epoch_micros(),
                shape,
                data,
                &self.metrics.adapt,
            )?,
            None => self.stages.apply(
                &self.field,
                self.rank,
                step,
                seq,
                util::epoch_micros(),
                shape,
                data,
            )?,
        };
        let mut record = match staged {
            Some(rec) => rec,
            None => {
                self.metrics
                    .write_call_us
                    .record(t0.elapsed().as_micros() as u64);
                return Ok(());
            }
        };
        // Staleness tracing (ISSUE 9): stamp the 1-in-N sample with its
        // origin (the gen timestamp the stage pipeline recorded at call
        // entry) and the enqueue time.  The shipper and the reader fill
        // in the later hops.
        if self.trace_sample != 0 && seq % self.trace_sample == 0 {
            let enqueue_us = util::epoch_micros();
            let trace = Trace {
                origin_us: record.gen_micros,
                enqueue_us,
                flush_us: 0,
                deliver_us: 0,
            };
            match &mut record.meta {
                Some(m) => m.trace = Some(trace),
                // Raw passthrough frames get promoted to a minimal
                // lossless EBR2 header so the stamp can ride the wire.
                None => {
                    record.meta = Some(FrameMeta {
                        encoding: Encoding::F32,
                        codec: CodecKind::None,
                        enc_param: 0.0,
                        err_bound: 0.0,
                        raw_len: record.payload.len() as u32,
                        stats: None,
                        trace: Some(trace),
                        provenance: String::new(),
                    });
                }
            }
            self.metrics.trace.sampled.inc();
            self.metrics
                .trace
                .hop_enqueue_us
                .record(enqueue_us.saturating_sub(record.gen_micros));
        }
        let dropped = self.queue.push(record);
        if dropped > 0 {
            self.metrics.dropped.add(dropped as u64);
        }
        self.metrics
            .write_call_us
            .record(t0.elapsed().as_micros() as u64);
        Ok(())
    }

    /// `broker_finalize`: flush the queue, stop and join the writer.
    pub fn finalize(mut self) -> Result<()> {
        self.queue.close();
        if let Some(h) = self.writer.take() {
            match h.join() {
                Ok(res) => res.with_context(|| {
                    format!("broker writer for {}/{} failed", self.field, self.rank)
                })?,
                Err(_) => anyhow::bail!("broker writer panicked"),
            }
        }
        Ok(())
    }

    /// Records currently waiting in the queue (diagnostics).
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    pub fn stream_key(&self) -> String {
        crate::record::stream_key(&self.field, self.rank)
    }
}

impl Drop for BrokerCtx {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

/// Writer-side batching knobs (resolved from [`BrokerConfig`]).
#[derive(Clone, Copy, Debug)]
struct BatchTuning {
    max_records: usize,
    max_bytes: usize,
    linger: Duration,
}

/// Background writer: drain coalesced batches and hand each one to the
/// epoch-fenced [`Shipper`] (one pipelined `XADDF` frame per batch,
/// plus the whole elastic protocol — migration at batch boundaries,
/// `HELLO` re-registration after transport failures, `STALE` fencing,
/// partial `OOM` retry that preserves stream order; see
/// [`shipper`]'s module docs).  Per-endpoint QoS (flush latency, peak
/// queue depth) is recorded against the endpoint each batch actually
/// shipped to, which is what feeds the [`Rebalancer`].
fn writer_loop(
    shipper: &mut Shipper,
    batching: BatchTuning,
    queue: &BoundedQueue<StreamRecord>,
    metrics: WorkflowMetrics,
) -> Result<()> {
    while let Some(records) = queue.drain_batch(
        batching.max_records,
        batching.max_bytes,
        batching.linger,
        StreamRecord::encoded_len,
    ) {
        metrics.batch_records.record(records.len() as u64);
        shipper.qos().queue_depth.set_max(queue.len() as u64);
        let t0 = Instant::now();
        shipper.ship(&records)?;
        let us = t0.elapsed().as_micros() as u64;
        metrics.flush_us.record(us);
        shipper.qos().flush_us.record(us);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::endpoint::{EndpointServer, StoreConfig};

    fn setup() -> (EndpointServer, Broker) {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 4,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let broker = Broker::new(cfg, 4, WorkflowMetrics::new()).unwrap();
        (srv, broker)
    }

    #[test]
    fn write_lands_in_endpoint_stream() {
        let (srv, broker) = setup();
        let ctx = broker.init("velocity", 2).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for step in 0..5 {
            ctx.write(step, &[2, 32], &data).unwrap();
        }
        ctx.finalize().unwrap();
        // all records shipped and decodable
        let store = srv.store();
        assert_eq!(store.xlen("velocity/2"), 5);
        let entries = store.read_after("velocity/2", crate::endpoint::EntryId::ZERO, 0);
        let rec = StreamRecord::decode(&entries[0].fields[0].1).unwrap();
        assert_eq!(rec.field, "velocity");
        assert_eq!(rec.rank, 2);
        assert_eq!(rec.step, 0);
        assert_eq!(rec.payload_f32().unwrap(), data);
    }

    #[test]
    fn finalize_flushes_backlog() {
        let (srv, broker) = setup();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 256];
        for step in 0..50 {
            ctx.write(step, &[256], &data).unwrap();
        }
        ctx.finalize().unwrap(); // must not lose queued records
        assert_eq!(srv.store().xlen("u/0"), 50);
    }

    #[test]
    fn write_returns_before_ship_completes() {
        // The asynchronous-write property: with a slow (throttled) link,
        // write() must still return quickly.
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 128,
            // cap batches below the burst size so the throttle stall is
            // visible as backlog even if the writer wakes up late
            batch_max_records: 4,
            conn: ConnConfig {
                throttle_bytes_per_sec: Some(200_000.0),
                ..Default::default()
            },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 16 * 1024]; // 64 KiB per record
        let t0 = Instant::now();
        for step in 0..8 {
            ctx.write(step, &[16 * 1024], &data).unwrap();
        }
        let call_time = t0.elapsed();
        // 8 × 64 KiB at 200 KB/s would take ~2.5 s synchronously.
        assert!(
            call_time.as_millis() < 500,
            "writes not asynchronous: {call_time:?}"
        );
        assert!(ctx.backlog() > 0, "expected queued records");
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 8);
        assert!(metrics.write_call_us.count() == 8);
    }

    #[test]
    fn drop_oldest_policy_sheds_load() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 4,
            policy: QueuePolicy::DropOldest,
            conn: ConnConfig {
                throttle_bytes_per_sec: Some(50_000.0),
                ..Default::default()
            },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 8 * 1024];
        for step in 0..40 {
            ctx.write(step, &[8 * 1024], &data).unwrap();
        }
        ctx.finalize().unwrap();
        let landed = srv.store().xlen("u/0");
        let dropped = metrics.dropped.get() as usize;
        assert_eq!(landed + dropped, 40, "landed {landed} + dropped {dropped}");
        assert!(dropped > 0, "expected drops under a 4-deep queue");
    }

    #[test]
    fn linger_coalesces_writes_into_batches() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 64,
            linger_ms: 60, // let the writer absorb the whole burst
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 64];
        for step in 0..16 {
            ctx.write(step, &[64], &data).unwrap();
        }
        ctx.finalize().unwrap();
        // everything landed exactly once, in order
        assert_eq!(srv.store().xlen("u/0"), 16);
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let steps: Vec<u64> = entries
            .iter()
            .map(|e| StreamRecord::decode(&e.fields[0].1).unwrap().step)
            .collect();
        assert_eq!(steps, (0..16).collect::<Vec<_>>());
        // and it took fewer flushes than records: coalescing happened
        assert_eq!(metrics.shipped.records(), 16);
        let flushes = metrics.batch_records.count();
        assert!(flushes < 16, "no coalescing: {flushes} flushes for 16 records");
        assert!(metrics.batch_records.max() >= 2);
        assert_eq!(metrics.flush_us.count(), flushes);
    }

    #[test]
    fn batch_byte_budget_splits_batches() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            queue_cap: 64,
            linger_ms: 60,
            // each record is ~4 KiB encoded; cap batches at ~2 records
            batch_max_bytes: 9 * 1024,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![0.5f32; 1024];
        for step in 0..8 {
            ctx.write(step, &[1024], &data).unwrap();
        }
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 8);
        assert!(
            metrics.batch_records.max() <= 2,
            "byte budget ignored: max batch {}",
            metrics.batch_records.max()
        );
    }

    #[test]
    fn multiple_ranks_one_endpoint() {
        let (srv, broker) = setup();
        let ctxs: Vec<_> = (0..4).map(|r| broker.init("velocity", r).unwrap()).collect();
        let data = vec![1.0f32; 32];
        for ctx in &ctxs {
            ctx.write(7, &[32], &data).unwrap();
        }
        for ctx in ctxs {
            ctx.finalize().unwrap();
        }
        for r in 0..4 {
            assert_eq!(srv.store().xlen(&format!("velocity/{r}")), 1);
        }
    }

    #[test]
    fn rank_out_of_range_rejected() {
        let (_srv, broker) = setup();
        assert!(broker.init("u", 99).is_err());
    }

    // --- ISSUE 3 fault-injection regressions: deterministic, no
    // --- sleeps, no real sockets (everything runs on SimConn).

    fn sim_records(rank: u32, steps: std::ops::Range<u64>) -> Vec<StreamRecord> {
        steps
            .map(|s| {
                StreamRecord::from_f32("u", rank, s, 0, &[2], &[s as f32, 1.0]).unwrap()
            })
            .collect()
    }

    fn sim_steps(store: &crate::endpoint::Store, key: &str) -> Vec<u64> {
        store
            .read_after(key, crate::endpoint::EntryId::ZERO, 0)
            .iter()
            .filter(|e| e.fields[0].0 != b"h")
            .map(|e| StreamRecord::decode(&e.fields[0].1).unwrap().step)
            .collect()
    }

    fn dummy_addrs(n: usize) -> Vec<SocketAddr> {
        (0..n).map(|_| "127.0.0.1:1".parse().unwrap()).collect()
    }

    /// The writer survives endpoint death mid-batch: the frame is cut
    /// after a prefix landed (no replies seen), reconnects are refused
    /// twice, and the re-shipped frame is deduplicated server-side —
    /// stream order preserved, every record exactly once.
    #[test]
    fn shipper_survives_endpoint_death_mid_batch() {
        use crate::transport::sim::{FaultSchedule, SimDialer, SimNet};

        let net = SimNet::new();
        let e0 = net.add_endpoint(StoreConfig::default());
        let topology = TopologyHandle::new_static(
            GroupMap::new(1, 1, 1).unwrap(),
            dummy_addrs(1),
        )
        .unwrap();
        let metrics = WorkflowMetrics::new();
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let mut shipper = Shipper::register(
            "u/0".into(),
            0,
            topology,
            dialer,
            metrics.clone(),
            8,
        )
        .unwrap();
        net.inject(
            e0,
            FaultSchedule {
                drop_after_frames: Some(0), // the batch frame dies
                partial_commands: 2,        // ...with 2 of 5 records landed
                refuse_connects: 2,         // ...and the endpoint down a while
                ..Default::default()
            },
        );
        shipper.ship(&sim_records(0, 0..5)).unwrap();
        let store = net.store(e0);
        assert_eq!(sim_steps(&store, "u/0"), vec![0, 1, 2, 3, 4]);
        assert_eq!(store.xlen("u/0"), 5, "no duplicates stored");
        assert!(metrics.reconnects.get() >= 3, "2 refused + 1 success");
        assert_eq!(metrics.migrations.get(), 0);
        assert_eq!(metrics.stale_rejections.get(), 0);
        assert_eq!(metrics.shipped.records(), 5);
    }

    /// A writer that raced a migration writes at its old epoch, is
    /// rejected `STALE`, re-registers on the new endpoint at the new
    /// epoch and re-ships — no loss, no duplication, and the old
    /// endpoint's segment ends with handoff tombstones.
    #[test]
    fn stale_writer_after_migration_re_registers_without_loss() {
        use crate::transport::sim::{FaultSchedule, SimDialer, SimNet};

        let net = SimNet::new();
        let e0 = net.add_endpoint(StoreConfig::default());
        let e1 = net.add_endpoint(StoreConfig::default());
        let topology = TopologyHandle::new_static(
            GroupMap::new(1, 1, 2).unwrap(),
            dummy_addrs(2),
        )
        .unwrap();
        let metrics = WorkflowMetrics::new();
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let mut shipper = Shipper::register(
            "u/0".into(),
            0,
            topology.clone(),
            dialer,
            metrics.clone(),
            8,
        )
        .unwrap();
        shipper.ship(&sim_records(0, 0..3)).unwrap();
        assert_eq!(shipper.endpoint(), 0);

        // Script the takeover to happen exactly while the next frame is
        // in flight (after the shipper's topology check, before the
        // endpoint applies the frame): an external controller fences
        // the e0 stream and reassigns the group to e1.
        let store0 = net.store(e0);
        let topo = topology.clone();
        net.inject(
            e0,
            FaultSchedule {
                before_frame: Some(Box::new(move || {
                    let next = topo.epoch() + 1;
                    store0.xhandoff("u/0", next, Some(1)).unwrap();
                    topo.assign(&[(0, 1)]).unwrap();
                })),
                ..Default::default()
            },
        );
        shipper.ship(&sim_records(0, 3..8)).unwrap();

        // every stale write was rejected, then re-shipped to e1
        assert!(metrics.stale_rejections.get() >= 1);
        assert_eq!(metrics.migrations.get(), 1);
        assert_eq!(shipper.endpoint(), 1);
        assert_eq!(shipper.epoch(), topology.epoch());
        assert_eq!(sim_steps(&net.store(e0), "u/0"), vec![0, 1, 2]);
        assert_eq!(sim_steps(&net.store(e1), "u/0"), vec![3, 4, 5, 6, 7]);
        // the old segment is fenced and tombstoned for readers
        assert!(net.store(e0).stream_epoch("u/0") >= 2);
        let entries = net
            .store(e0)
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        assert_eq!(entries.last().unwrap().fields[0].0, b"h");
    }

    /// A zombie writer (stream fenced above it, topology with nothing
    /// newer to offer) must fail hard instead of fighting the fence.
    #[test]
    fn zombie_writer_with_no_newer_topology_fails_hard() {
        use crate::transport::sim::{SimDialer, SimNet};

        let net = SimNet::new();
        let e0 = net.add_endpoint(StoreConfig::default());
        let topology = TopologyHandle::new_static(
            GroupMap::new(1, 1, 1).unwrap(),
            dummy_addrs(1),
        )
        .unwrap();
        let metrics = WorkflowMetrics::new();
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let mut shipper = Shipper::register(
            "u/0".into(),
            0,
            topology,
            dialer,
            metrics.clone(),
            4,
        )
        .unwrap();
        shipper.ship(&sim_records(0, 0..2)).unwrap();
        // a successor fences the stream far above anything we know
        net.store(e0).xhandoff("u/0", 99, None).unwrap();
        let err = shipper.ship(&sim_records(0, 2..4)).unwrap_err();
        assert!(err.to_string().contains("fenced above"), "{err}");
        // nothing stale landed
        assert_eq!(sim_steps(&net.store(e0), "u/0"), vec![0, 1]);
    }

    /// Batch-boundary migration (the graceful path): after a scale-out
    /// reassigns the group, the next batch ships to the new endpoint,
    /// with a tombstone closing the old segment.
    #[test]
    fn graceful_migration_at_batch_boundary() {
        use crate::transport::sim::{SimDialer, SimNet};

        let net = SimNet::new();
        let e0 = net.add_endpoint(StoreConfig::default());
        let topology = TopologyHandle::new_static(
            GroupMap::new(2, 1, 1).unwrap(), // 2 groups on one endpoint
            dummy_addrs(1),
        )
        .unwrap();
        let metrics = WorkflowMetrics::new();
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let mut s0 = Shipper::register(
            "u/0".into(), 0, topology.clone(), dialer.clone(), metrics.clone(), 4,
        )
        .unwrap();
        let mut s1 = Shipper::register(
            "u/1".into(), 1, topology.clone(), dialer, metrics.clone(), 4,
        )
        .unwrap();
        s0.ship(&sim_records(0, 0..4)).unwrap();
        s1.ship(&sim_records(1, 0..4)).unwrap();

        // scale out: one group moves to the new endpoint
        let e1 = net.add_endpoint(StoreConfig::default());
        let (slot, _) = topology.scale_out("127.0.0.1:1".parse().unwrap()).unwrap();
        assert_eq!(slot, e1);
        s0.ship(&sim_records(0, 4..8)).unwrap();
        s1.ship(&sim_records(1, 4..8)).unwrap();

        assert_eq!(metrics.migrations.get(), 1, "exactly one group moved");
        assert_eq!(metrics.handoffs.get(), 1);
        // the moved stream: old segment 0..4 + tombstone, new segment 4..8
        let moved = topology.snapshot().groups_of_endpoint(e1);
        assert_eq!(moved.len(), 1);
        let key = format!("u/{}", moved[0]);
        assert_eq!(
            sim_steps(&net.store(e0), &key),
            vec![0, 1, 2, 3],
            "{key} old segment"
        );
        assert_eq!(
            sim_steps(&net.store(e1), &key),
            vec![4, 5, 6, 7],
            "{key} new segment"
        );
        // the unmoved stream never left e0
        let stayed = if moved[0] == 0 { "u/1" } else { "u/0" };
        assert_eq!(sim_steps(&net.store(e0), stayed).len(), 8);
    }

    /// ISSUE 5: staged writes ship opaque `EBR2` frames that the
    /// endpoint stores unchanged, cost fewer wire bytes than raw, and
    /// decode back to the aggregated f32 data on the Cloud side.
    #[test]
    fn staged_write_reduces_wire_bytes_and_decodes() {
        use crate::record::CodecKind;

        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            stages: StagesConfig {
                aggregate: 2,
                codec: CodecKind::ShuffleLz,
                ..Default::default()
            },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        // smooth field: the codec must win
        let data: Vec<f32> = (0..256).map(|i| (i as f32 * 0.05).sin()).collect();
        for step in 0..4 {
            ctx.write(step, &[256], &data).unwrap();
        }
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 4);
        let stage = &metrics.stages;
        assert_eq!(stage.records_in.get(), 4);
        assert!(
            stage.bytes_out.get() < stage.bytes_in.get() / 2,
            "aggregate 2 + codec must at least halve: {} vs {}",
            stage.bytes_out.get(),
            stage.bytes_in.get()
        );
        // the stored frame is EBR2 and decodes to the block-mean oracle
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let rec = StreamRecord::decode(&entries[0].fields[0].1).unwrap();
        let meta = rec.meta.as_ref().expect("staged frame header");
        // ISSUE 8 bugfix: aggregation is lossy at element granularity
        // even though the block means themselves ship bit-exactly —
        // the header must carry the measured block-mean residual.
        assert!(
            meta.err_bound > 0.0,
            "aggregate=2 on a varying field must report its residual"
        );
        assert!(meta.stats.is_some());
        assert_eq!(rec.shape, vec![128]);
        let (oracle_shape, oracle) =
            stages::block_mean_last_axis(&[256], &data, 2).unwrap();
        assert_eq!(rec.shape, oracle_shape);
        let got = rec.payload_f32().unwrap();
        assert_eq!(got.len(), oracle.len());
        for (a, b) in got.iter().zip(&oracle) {
            assert_eq!(a.to_bits(), b.to_bits(), "lossless staged bits changed");
        }
    }

    /// ISSUE 5: decimation thins the stream without counting as drops.
    #[test]
    fn decimated_write_ships_every_nth() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            stages: StagesConfig { decimate: 3, ..Default::default() },
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 16];
        for step in 0..9 {
            ctx.write(step, &[16], &data).unwrap();
        }
        ctx.finalize().unwrap();
        assert_eq!(srv.store().xlen("u/0"), 3);
        assert_eq!(metrics.dropped.get(), 0, "decimation is not queue loss");
        assert_eq!(metrics.stages.records_filtered.get(), 6);
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let steps: Vec<u64> = entries
            .iter()
            .map(|e| StreamRecord::decode(&e.fields[0].1).unwrap().step)
            .collect();
        assert_eq!(steps, vec![0, 3, 6]);
    }

    /// ISSUE 9: a 1-in-N trace sample rides the wire with origin and
    /// enqueue stamped by the write path and flush stamped by the
    /// shipper; the unsampled majority stays raw `EBR1` and untraced.
    #[test]
    fn trace_sampling_stamps_every_nth_write() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 1,
            trace_sample: 2,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 1, metrics.clone()).unwrap();
        let ctx = broker.init("u", 0).unwrap();
        let data = vec![1.0f32; 16];
        for step in 0..4 {
            ctx.write(step, &[16], &data).unwrap();
        }
        ctx.finalize().unwrap();
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        assert_eq!(entries.len(), 4);
        let mut traced = 0;
        for (i, e) in entries.iter().enumerate() {
            let rec = StreamRecord::decode(&e.fields[0].1).unwrap();
            let trace = rec.meta.as_ref().and_then(|m| m.trace);
            if i % 2 == 0 {
                let t = trace.expect("even writes are sampled");
                assert!(t.origin_us > 0);
                assert!(t.enqueue_us >= t.origin_us, "enqueue after origin");
                assert!(t.flush_us >= t.enqueue_us, "shipper stamps flush");
                assert_eq!(t.deliver_us, 0, "producers never stamp deliver");
                traced += 1;
            } else {
                assert!(trace.is_none(), "odd writes stay untraced");
            }
        }
        assert_eq!(traced, 2);
        assert_eq!(metrics.trace.sampled.get(), 2);
        assert_eq!(metrics.trace.hop_enqueue_us.count(), 2);
        assert_eq!(metrics.trace.hop_queue_us.count(), 2);
        assert_eq!(metrics.trace.hop_ack_us.count(), 2);
    }

    #[test]
    fn filtered_write_reduces_payload() {
        let srv = EndpointServer::start("127.0.0.1:0", StoreConfig::default()).unwrap();
        let cfg = BrokerConfig {
            group_size: 4,
            ..BrokerConfig::new(vec![srv.addr()])
        };
        let metrics = WorkflowMetrics::new();
        let broker = Broker::new(cfg, 4, metrics.clone()).unwrap();
        let ctx_filtered = broker
            .init_filtered("u", 0, Filter::new(vec![FilterStage::Stride(4)]))
            .unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        ctx_filtered.write(0, &[64], &data).unwrap();
        ctx_filtered.finalize().unwrap();
        let entries = srv
            .store()
            .read_after("u/0", crate::endpoint::EntryId::ZERO, 0);
        let rec = StreamRecord::decode(&entries[0].fields[0].1).unwrap();
        assert_eq!(rec.payload_f32().unwrap().len(), 16);
        // ISSUE 6 satellite: the per-context transform is part of the
        // stage byte accounting — 64 raw f32 in, 16 shipped f32 out.
        assert_eq!(metrics.stages.bytes_in.get(), 64 * 4);
        assert_eq!(metrics.stages.bytes_out.get(), 16 * 4);
        assert!((metrics.stages.reduction_factor() - 4.0).abs() < 1e-9);
    }
}
