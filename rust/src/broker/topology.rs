//! Versioned group→endpoint topology — the substrate of the paper's
//! *elastic* claim (ISSUE 3 tentpole).
//!
//! [`GroupMap`] stays what it always was: the immutable partition of
//! ranks into process groups.  What used to be hard-wired on top of it
//! (group *g* → endpoint *g mod n*, fixed at `Broker::init`) is now a
//! [`Topology`]: an **epoch-numbered** assignment of groups to endpoint
//! slots, where slots can be added (scale-out), drained (scale-in) or
//! marked dead (failure), and every assignment change bumps the epoch.
//!
//! The epoch is the fencing token of the whole migration protocol:
//! writers register streams with `HELLO <key> <epoch>`, endpoints
//! reject writes below the stream's fence (`STALE`), and handoff
//! tombstones carry the epoch the stream moved at — so two writers
//! racing a migration can never interleave appends, and a reader can
//! follow a stream across endpoints without loss or duplication.
//!
//! [`TopologyHandle`] is the shared, cheaply-pollable view: writers
//! check `epoch()` (one atomic load) at every batch boundary and only
//! take the read lock when it moved.
//!
//! **Replication (ISSUE 10).**  On top of the head assignment, every
//! group carries a *replica chain* (`replicas[g]`, head first): the
//! ordered endpoint slots its streams are chain-replicated across.
//! Slots carry a failure-domain label, and the chain invariant — kept
//! by [`Topology::validate`] like every other invariant here — is that
//! a chain never visits the same endpoint or the same failure domain
//! twice.  Failover is nothing new: [`TopologyHandle::drain_endpoint`]
//! of a chain head promotes a surviving member (preferring one that,
//! thanks to tail-acks, holds every acknowledged record — repair
//! recruits are tracked as *catching up* and only promoted as a last
//! resort) and bumps the epoch, so the existing fencing machinery
//! turns the old head into a zombie.  Control planes that must react
//! to an epoch bump in the same call stack (e.g. rewiring replication
//! maps onto a just-promoted head) install a
//! [`TopologyHandle::set_on_change`] observer instead of polling.

use std::collections::BTreeSet;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use super::groups::GroupMap;

/// One endpoint slot.  Slot indices are stable for the topology's
/// lifetime (a removed endpoint keeps its index, marked not-live), so
/// writers, dialers and QoS boards can key everything by slot.
#[derive(Clone, Debug)]
pub struct EndpointSlot {
    pub addr: SocketAddr,
    pub live: bool,
    /// Failure-domain label (rack, AZ, machine).  Replica chains never
    /// place two members in the same domain, so one domain loss costs
    /// at most one chain position per group.
    pub domain: String,
}

/// An epoch-numbered group→endpoint assignment.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Monotonic version; bumped by every assignment change.  Starts
    /// at 1 (0 means "never registered" on the endpoint side).
    pub epoch: u64,
    /// The immutable rank→group partition.
    pub groups: GroupMap,
    /// `assignment[g]` = endpoint slot group `g` writes to.
    pub assignment: Vec<usize>,
    /// Endpoint slots (stable indices; `live` toggles).
    pub endpoints: Vec<EndpointSlot>,
    /// `replicas[g]` = the chain of endpoint slots group `g`'s streams
    /// are replicated across, head first (`replicas[g][0] ==
    /// assignment[g]`).  A single-element chain is an unreplicated
    /// group (the pre-ISSUE-10 behaviour).
    pub replicas: Vec<Vec<usize>>,
    /// Target chain length for placement and repair (1 = replication
    /// off).
    pub replication_factor: usize,
    /// `catching_up[g]` = chain members of group `g` added by
    /// [`TopologyHandle::repair_chains`] after writes began.  Until a
    /// backfill mechanism exists they hold only the suffix of the
    /// group's history since they joined, so failover promotion must
    /// never *prefer* them over a fully-replicated member — promoting
    /// one would serve a truncated history and lose acked records.
    /// Cleared by [`TopologyHandle::mark_replica_synced`] (the future
    /// backfill completion hook), by promotion-of-last-resort, or when
    /// the member leaves the chain.
    pub catching_up: Vec<BTreeSet<usize>>,
}

impl Topology {
    /// The static topology every pre-elastic run used: group `g` on
    /// endpoint `g % n`, all endpoints live, epoch 1.
    pub fn new_static(groups: GroupMap, addrs: Vec<SocketAddr>) -> Result<Topology> {
        Topology::new_replicated(groups, addrs, &[], 1)
    }

    /// A replicated static topology: group `g`'s chain starts at
    /// endpoint `g % n` and extends to the next `factor - 1` endpoints
    /// in distinct failure domains.  `domains` labels the endpoints
    /// (cycled when shorter than the endpoint list; empty = every
    /// endpoint is its own domain `d<i>`).
    pub fn new_replicated(
        groups: GroupMap,
        addrs: Vec<SocketAddr>,
        domains: &[String],
        factor: usize,
    ) -> Result<Topology> {
        ensure!(!addrs.is_empty(), "need at least one endpoint");
        ensure!(
            (1..=3).contains(&factor),
            "replication factor {factor} out of range 1..=3"
        );
        let n = addrs.len();
        let endpoints: Vec<EndpointSlot> = addrs
            .into_iter()
            .enumerate()
            .map(|(i, addr)| EndpointSlot {
                addr,
                live: true,
                domain: if domains.is_empty() {
                    format!("d{i}")
                } else {
                    domains[i % domains.len()].clone()
                },
            })
            .collect();
        let assignment: Vec<usize> = (0..groups.n_groups()).map(|g| g % n).collect();
        let replicas: Vec<Vec<usize>> = assignment
            .iter()
            .map(|&head| {
                let mut chain = vec![head];
                // walk the ring from the head; only distinct failure
                // domains extend the chain
                for off in 1..n {
                    if chain.len() >= factor {
                        break;
                    }
                    let e = (head + off) % n;
                    if chain.iter().any(|&c| endpoints[c].domain == endpoints[e].domain) {
                        continue;
                    }
                    chain.push(e);
                }
                chain
            })
            .collect();
        let n_groups = replicas.len();
        let topo = Topology {
            epoch: 1,
            groups,
            assignment,
            endpoints,
            replicas,
            replication_factor: factor,
            catching_up: vec![BTreeSet::new(); n_groups],
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Endpoint slot a group currently writes to.
    pub fn endpoint_of_group(&self, group: usize) -> Result<usize> {
        ensure!(
            group < self.assignment.len(),
            "group {group} out of range 0..{}",
            self.assignment.len()
        );
        Ok(self.assignment[group])
    }

    /// Endpoint slot a rank currently writes to.
    pub fn endpoint_of_rank(&self, rank: usize) -> Result<usize> {
        self.endpoint_of_group(self.groups.group_of_rank(rank)?)
    }

    /// Groups currently assigned to endpoint slot `e`.
    pub fn groups_of_endpoint(&self, e: usize) -> Vec<usize> {
        (0..self.assignment.len())
            .filter(|&g| self.assignment[g] == e)
            .collect()
    }

    /// Live endpoint slot indices.
    pub fn live_endpoints(&self) -> Vec<usize> {
        (0..self.endpoints.len())
            .filter(|&e| self.endpoints[e].live)
            .collect()
    }

    /// Stream keys endpoint `e` currently receives for `field`.
    pub fn streams_of_endpoint(&self, e: usize, field: &str) -> Vec<String> {
        (0..self.groups.total_ranks())
            .filter(|&r| self.endpoint_of_rank(r).unwrap() == e)
            .map(|r| crate::record::stream_key(field, r as u32))
            .collect()
    }

    /// The replica chain of a group, head first.
    pub fn replica_chain(&self, group: usize) -> Result<&[usize]> {
        ensure!(
            group < self.replicas.len(),
            "group {group} out of range 0..{}",
            self.replicas.len()
        );
        Ok(&self.replicas[group])
    }

    /// The chain successor of endpoint `e` for `group` (`None` when `e`
    /// is the tail or not in the chain).
    pub fn successor_in_chain(&self, group: usize, e: usize) -> Option<usize> {
        let chain = self.replicas.get(group)?;
        let pos = chain.iter().position(|&m| m == e)?;
        chain.get(pos + 1).copied()
    }

    /// Whether chain member `e` of `group` joined via repair and has
    /// not been backfilled — i.e. it holds only the suffix of the
    /// group's history and must not be preferred for promotion.
    pub fn is_catching_up(&self, group: usize, e: usize) -> bool {
        self.catching_up
            .get(group)
            .map(|s| s.contains(&e))
            .unwrap_or(false)
    }

    /// The core invariant: every group is assigned to exactly one
    /// endpoint slot that exists and is live, and its replica chain is
    /// headed by that slot, visits only live endpoints, and never
    /// repeats an endpoint or a failure domain.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.assignment.len() == self.groups.n_groups(),
            "assignment covers {} groups, topology has {}",
            self.assignment.len(),
            self.groups.n_groups()
        );
        for (g, &e) in self.assignment.iter().enumerate() {
            ensure!(
                e < self.endpoints.len(),
                "group {g} assigned to missing endpoint {e}"
            );
            ensure!(
                self.endpoints[e].live,
                "group {g} assigned to dead endpoint {e}"
            );
        }
        ensure!(
            self.replicas.len() == self.assignment.len(),
            "replica chains cover {} groups, topology has {}",
            self.replicas.len(),
            self.assignment.len()
        );
        for (g, chain) in self.replicas.iter().enumerate() {
            ensure!(!chain.is_empty(), "group {g} has an empty replica chain");
            ensure!(
                chain[0] == self.assignment[g],
                "group {g}: chain head {} != assigned endpoint {}",
                chain[0],
                self.assignment[g]
            );
            ensure!(
                chain.len() <= 3,
                "group {g}: replica chain longer than 3"
            );
            for (i, &e) in chain.iter().enumerate() {
                ensure!(
                    e < self.endpoints.len(),
                    "group {g}: missing endpoint {e} in chain"
                );
                ensure!(
                    self.endpoints[e].live,
                    "group {g}: dead endpoint {e} in chain"
                );
                for &f in &chain[..i] {
                    ensure!(f != e, "group {g}: endpoint {e} twice in chain");
                    ensure!(
                        self.endpoints[f].domain != self.endpoints[e].domain,
                        "group {g}: chain co-located in failure domain '{}'",
                        self.endpoints[e].domain
                    );
                }
            }
        }
        ensure!(
            self.catching_up.len() == self.replicas.len(),
            "catching-up marks cover {} groups, topology has {}",
            self.catching_up.len(),
            self.replicas.len()
        );
        for (g, marks) in self.catching_up.iter().enumerate() {
            for &e in marks {
                ensure!(
                    self.replicas[g][1..].contains(&e),
                    "group {g}: catching-up mark on {e}, which is not a \
                     follower in its chain"
                );
            }
        }
        ensure!(
            !self.live_endpoints().is_empty(),
            "no live endpoints left"
        );
        Ok(())
    }

    /// Live endpoint with the fewest assigned groups, excluding `not`
    /// (ties broken by lowest index — deterministic).
    fn least_loaded_live(&self, not: Option<usize>) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (load, idx)
        for e in 0..self.endpoints.len() {
            if !self.endpoints[e].live || Some(e) == not {
                continue;
            }
            let load = self.groups_of_endpoint(e).len();
            let better = match best {
                None => true,
                Some((bl, bi)) => load < bl || (load == bl && e < bi),
            };
            if better {
                best = Some((load, e));
            }
        }
        best.map(|(_, e)| e)
    }
}

/// Observer invoked (outside the topology lock) after every successful
/// epoch bump, with a consistent snapshot of the new state.
type ChangeCallback = Arc<dyn Fn(&Topology) + Send + Sync>;

/// Shared, versioned view of the topology.
///
/// Cloning the handle shares the topology.  `epoch()` is one atomic
/// load, so writers can poll for changes at every batch boundary for
/// free; all mutating operations bump the epoch exactly once and keep
/// the [`Topology::validate`] invariant.
#[derive(Clone)]
pub struct TopologyHandle {
    inner: Arc<RwLock<Topology>>,
    epoch: Arc<AtomicU64>,
    on_change: Arc<RwLock<Option<ChangeCallback>>>,
}

impl TopologyHandle {
    pub fn new(topology: Topology) -> TopologyHandle {
        let epoch = Arc::new(AtomicU64::new(topology.epoch));
        TopologyHandle {
            inner: Arc::new(RwLock::new(topology)),
            epoch,
            on_change: Arc::new(RwLock::new(None)),
        }
    }

    /// Install the change observer.  It runs synchronously on the
    /// mutating thread, *after* the topology lock is released, so a
    /// failover promotion and the rewiring it requires (replication
    /// maps on the new head) land in the same call stack — no polling
    /// window in which tail-acks run against a stale map.  The callback
    /// must not mutate the topology (that would recurse).  Replaces any
    /// previous observer.
    pub fn set_on_change(&self, cb: impl Fn(&Topology) + Send + Sync + 'static) {
        *self.on_change.write().unwrap() = Some(Arc::new(cb));
    }

    /// Drop the change observer (releases whatever the closure owns).
    pub fn clear_on_change(&self) {
        *self.on_change.write().unwrap() = None;
    }

    /// Snapshot for the observer, taken while the topology lock is
    /// still held — but only when an observer is installed.
    fn change_snapshot(&self, t: &Topology) -> Option<Topology> {
        if self.on_change.read().unwrap().is_some() {
            Some(t.clone())
        } else {
            None
        }
    }

    /// Deliver a post-mutation snapshot; call with the topology lock
    /// released.
    fn notify_change(&self, snap: Option<Topology>) {
        if let Some(t) = snap {
            let cb = self.on_change.read().unwrap().clone();
            if let Some(cb) = cb {
                cb(&t);
            }
        }
    }

    /// Convenience: a static topology from a rank partition + addresses.
    pub fn new_static(groups: GroupMap, addrs: Vec<SocketAddr>) -> Result<TopologyHandle> {
        Ok(TopologyHandle::new(Topology::new_static(groups, addrs)?))
    }

    /// Convenience: a chain-replicated topology (see
    /// [`Topology::new_replicated`]).
    pub fn new_replicated(
        groups: GroupMap,
        addrs: Vec<SocketAddr>,
        domains: &[String],
        factor: usize,
    ) -> Result<TopologyHandle> {
        Ok(TopologyHandle::new(Topology::new_replicated(
            groups, addrs, domains, factor,
        )?))
    }

    /// Current epoch (one atomic load; no lock).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// A consistent copy of the whole topology.
    pub fn snapshot(&self) -> Topology {
        self.inner.read().unwrap().clone()
    }

    /// Where a group writes right now: `(endpoint slot, epoch)`.  With
    /// replication this is the chain head — the only member a writer
    /// ever talks to.
    pub fn route(&self, group: usize) -> Result<(usize, u64)> {
        let t = self.inner.read().unwrap();
        Ok((t.endpoint_of_group(group)?, t.epoch))
    }

    /// A group's replica chain right now: `(chain, epoch)`.
    pub fn chain(&self, group: usize) -> Result<(Vec<usize>, u64)> {
        let t = self.inner.read().unwrap();
        Ok((t.replica_chain(group)?.to_vec(), t.epoch))
    }

    /// Address of an endpoint slot (the TCP dialer's resolver).
    pub fn endpoint_addr(&self, e: usize) -> Result<SocketAddr> {
        let t = self.inner.read().unwrap();
        ensure!(e < t.endpoints.len(), "no endpoint slot {e}");
        Ok(t.endpoints[e].addr)
    }

    fn mutate<R>(&self, f: impl FnOnce(&mut Topology) -> Result<R>) -> Result<R> {
        let (r, snap) = {
            let mut t = self.inner.write().unwrap();
            let before = t.clone();
            match f(&mut t).and_then(|r| t.validate().map(|_| r)) {
                Ok(r) => {
                    t.epoch += 1;
                    self.epoch.store(t.epoch, Ordering::Release);
                    (r, self.change_snapshot(&t))
                }
                Err(e) => {
                    *t = before; // roll back a rejected mutation wholesale
                    return Err(e);
                }
            }
        };
        self.notify_change(snap);
        Ok(r)
    }

    /// Add an endpoint slot without moving any group onto it yet.
    /// Bumps the epoch (the slot becomes routable for future moves).
    /// The slot gets its own fresh failure domain `d<index>`; use
    /// [`TopologyHandle::add_endpoint_in_domain`] to co-locate.
    pub fn add_endpoint(&self, addr: SocketAddr) -> Result<usize> {
        self.mutate(|t| {
            let domain = format!("d{}", t.endpoints.len());
            t.endpoints.push(EndpointSlot { addr, live: true, domain });
            Ok(t.endpoints.len() - 1)
        })
    }

    /// [`TopologyHandle::add_endpoint`] with an explicit failure-domain
    /// label (chains will refuse to visit the domain twice).
    pub fn add_endpoint_in_domain(
        &self,
        addr: SocketAddr,
        domain: impl Into<String>,
    ) -> Result<usize> {
        let domain = domain.into();
        self.mutate(|t| {
            t.endpoints.push(EndpointSlot { addr, live: true, domain });
            Ok(t.endpoints.len() - 1)
        })
    }

    /// Move specific groups: `moves` = `(group, target endpoint)`.
    /// Returns the new epoch.
    pub fn assign(&self, moves: &[(usize, usize)]) -> Result<u64> {
        self.mutate(|t| {
            for &(g, e) in moves {
                ensure!(g < t.assignment.len(), "no group {g}");
                set_head_in_place(t, g, e);
            }
            Ok(())
        })?;
        Ok(self.epoch())
    }

    /// Scale-out: add an endpoint and rebalance groups onto it so live
    /// loads differ by at most one group (fewest moves, deterministic).
    /// Returns `(new slot index, new epoch)`.
    pub fn scale_out(&self, addr: SocketAddr) -> Result<(usize, u64)> {
        let slot = self.mutate(|t| {
            let domain = format!("d{}", t.endpoints.len());
            t.endpoints.push(EndpointSlot { addr, live: true, domain });
            let slot = t.endpoints.len() - 1;
            rebalance_in_place(t);
            Ok(slot)
        })?;
        Ok((slot, self.epoch()))
    }

    /// Scale-in / failure: mark a slot not-live, strip it from every
    /// replica chain, and re-route its groups.  A group whose chain
    /// survives the loss is **promoted onto a fully-replicated
    /// successor** — thanks to tail-acks such a member holds every
    /// acknowledged record, so this epoch bump *is* chain-replication
    /// failover.  Members still catching up after a chain repair (no
    /// backfill yet — they only hold the suffix since they joined) are
    /// passed over, and promoted only as a last resort when no
    /// full-history member survives: a truncated suffix still beats
    /// the empty store a fresh reassignment would serve.  A group
    /// whose chain is wiped out falls back to the least-loaded
    /// survivor (the pre-replication drain behaviour).  The slot keeps
    /// its index; its server (if still up) stays drainable by readers.
    /// Returns the new epoch.
    pub fn drain_endpoint(&self, e: usize) -> Result<u64> {
        self.mutate(|t| {
            ensure!(e < t.endpoints.len(), "no endpoint slot {e}");
            ensure!(t.endpoints[e].live, "endpoint {e} already drained");
            t.endpoints[e].live = false;
            for g in 0..t.assignment.len() {
                t.replicas[g].retain(|&m| m != e);
                t.catching_up[g].remove(&e);
                if t.assignment[g] == e {
                    let full = t.replicas[g]
                        .iter()
                        .copied()
                        .find(|m| !t.catching_up[g].contains(m));
                    match full.or_else(|| t.replicas[g].first().copied()) {
                        Some(successor) => {
                            if t.catching_up[g].remove(&successor) {
                                log::warn!(
                                    "topology: group {g} promotes catching-up \
                                     endpoint {successor} — no fully-replicated \
                                     member left; history before its join is \
                                     unrecoverable"
                                );
                            }
                            t.replicas[g].retain(|&m| m != successor);
                            t.replicas[g].insert(0, successor);
                            t.assignment[g] = successor;
                        }
                        None => {
                            let target = t.least_loaded_live(None).ok_or_else(|| {
                                anyhow::anyhow!("no live endpoint to drain {e} into")
                            })?;
                            t.assignment[g] = target;
                            t.replicas[g] = vec![target];
                            t.catching_up[g].clear();
                        }
                    }
                }
            }
            Ok(())
        })?;
        Ok(self.epoch())
    }

    /// Top every short replica chain back up to the topology's
    /// replication factor with live endpoints from unused failure
    /// domains (least loaded first, lowest index on ties).  Recruits
    /// are marked catching-up — they hold none of the group's history
    /// and [`TopologyHandle::drain_endpoint`] must not prefer them for
    /// promotion until [`TopologyHandle::mark_replica_synced`] clears
    /// the mark.  Returns the new epoch if anything changed; a no-op
    /// (chains full, or no compatible endpoint) leaves the epoch
    /// untouched.
    pub fn repair_chains(&self) -> Result<Option<u64>> {
        let mut t = self.inner.write().unwrap();
        let before = t.clone();
        let factor = t.replication_factor.max(1);
        let mut changed = false;
        for g in 0..t.replicas.len() {
            while t.replicas[g].len() < factor {
                let mut best: Option<(usize, usize)> = None; // (load, idx)
                for e in 0..t.endpoints.len() {
                    if !t.endpoints[e].live || t.replicas[g].contains(&e) {
                        continue;
                    }
                    if t.replicas[g]
                        .iter()
                        .any(|&c| t.endpoints[c].domain == t.endpoints[e].domain)
                    {
                        continue;
                    }
                    let load = t.groups_of_endpoint(e).len();
                    let better = match best {
                        None => true,
                        Some((bl, bi)) => load < bl || (load == bl && e < bi),
                    };
                    if better {
                        best = Some((load, e));
                    }
                }
                match best {
                    Some((_, e)) => {
                        t.replicas[g].push(e);
                        // No backfill yet: the recruit holds none of the
                        // group's history, so failover must not prefer it
                        // (see [`Topology::catching_up`]).
                        t.catching_up[g].insert(e);
                        changed = true;
                    }
                    None => break, // no compatible endpoint: stay short
                }
            }
        }
        if !changed {
            return Ok(None);
        }
        if let Err(e) = t.validate() {
            *t = before;
            return Err(e);
        }
        t.epoch += 1;
        self.epoch.store(t.epoch, Ordering::Release);
        let epoch = t.epoch;
        let snap = self.change_snapshot(&t);
        drop(t);
        self.notify_change(snap);
        Ok(Some(epoch))
    }

    /// Declare that a catching-up chain member now holds the group's
    /// full history (a backfill finished, or an operator verified the
    /// copies match) and may be preferred for failover promotion again.
    /// No-op if the member carries no mark.  Returns the new epoch.
    pub fn mark_replica_synced(&self, group: usize, e: usize) -> Result<u64> {
        self.mutate(|t| {
            ensure!(group < t.replicas.len(), "no group {group}");
            t.catching_up[group].remove(&e);
            Ok(())
        })?;
        Ok(self.epoch())
    }

    /// Even out group load across live endpoints (at most one group of
    /// spread).  Returns the new epoch if anything moved; a no-op
    /// leaves the epoch untouched.
    pub fn rebalance(&self) -> Result<Option<u64>> {
        let mut t = self.inner.write().unwrap();
        let before = t.clone();
        if !rebalance_in_place(&mut t) {
            return Ok(None);
        }
        if let Err(e) = t.validate() {
            *t = before;
            return Err(e);
        }
        t.epoch += 1;
        self.epoch.store(t.epoch, Ordering::Release);
        let epoch = t.epoch;
        let snap = self.change_snapshot(&t);
        drop(t);
        self.notify_change(snap);
        Ok(Some(epoch))
    }
}

/// Move groups from the most- to the least-loaded live endpoint until
/// the spread is ≤ 1.  Deterministic (lowest indices win ties); returns
/// whether anything moved.
fn rebalance_in_place(t: &mut Topology) -> bool {
    let mut moved = false;
    loop {
        let live = t.live_endpoints();
        if live.len() < 2 {
            return moved;
        }
        let loads: Vec<(usize, usize)> = live
            .iter()
            .map(|&e| (e, t.groups_of_endpoint(e).len()))
            .collect();
        let &(min_e, min_l) = loads.iter().min_by_key(|&&(e, l)| (l, e)).unwrap();
        let &(max_e, max_l) = loads.iter().max_by_key(|&&(e, l)| (l, usize::MAX - e)).unwrap();
        if max_l - min_l <= 1 {
            return moved;
        }
        // move the lowest-numbered group off the most-loaded endpoint
        let g = t.groups_of_endpoint(max_e)[0];
        set_head_in_place(t, g, min_e);
        moved = true;
    }
}

/// Re-head group `g`'s chain at endpoint `e`: the chain becomes `[e]`
/// followed by as many previous members as stay live, distinct and
/// domain-compatible, capped at the replication factor.  The previous
/// head is eligible to stay on as a follower — it already holds the
/// group's data, which is exactly what a replica is for.  Chains
/// shortened by a domain conflict are topped back up by
/// [`TopologyHandle::repair_chains`].
fn set_head_in_place(t: &mut Topology, g: usize, e: usize) {
    let old = std::mem::take(&mut t.replicas[g]);
    let cap = t.replication_factor.max(1);
    let mut chain = vec![e];
    for &m in &old {
        if chain.len() >= cap {
            break;
        }
        if m == e || !t.endpoints.get(m).map(|s| s.live).unwrap_or(false) {
            continue;
        }
        if e < t.endpoints.len()
            && chain
                .iter()
                .any(|&c| t.endpoints[c].domain == t.endpoints[m].domain)
        {
            continue;
        }
        chain.push(m);
    }
    t.replicas[g] = chain;
    t.assignment[g] = e;
    // Members dropped from the chain shed their catching-up mark, and a
    // catching-up member re-headed by an explicit migration is trusted
    // by construction (readers follow the handoff, writers start fresh)
    // — marks only ever apply to followers.
    t.catching_up[g].retain(|&m| t.replicas[g][1..].contains(&m));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn topo(ranks: usize, gsize: usize, n_eps: usize) -> TopologyHandle {
        let groups = GroupMap::new(ranks, gsize, n_eps).unwrap();
        let addrs = (0..n_eps).map(|i| addr(7000 + i as u16)).collect();
        TopologyHandle::new_static(groups, addrs).unwrap()
    }

    #[test]
    fn static_topology_matches_legacy_modulo_mapping() {
        let h = topo(64, 16, 2);
        let t = h.snapshot();
        assert_eq!(t.epoch, 1);
        for r in 0..64 {
            let legacy = t.groups.endpoint_of_rank(r).unwrap();
            assert_eq!(t.endpoint_of_rank(r).unwrap(), legacy);
        }
        assert_eq!(t.streams_of_endpoint(0, "u").len(), 32);
        assert_eq!(t.streams_of_endpoint(1, "u").len(), 32);
    }

    #[test]
    fn scale_out_rebalances_and_bumps_epoch_once() {
        let h = topo(64, 16, 1); // 4 groups on 1 endpoint
        let (slot, epoch) = h.scale_out(addr(7100)).unwrap();
        assert_eq!(slot, 1);
        assert_eq!(epoch, 2);
        assert_eq!(h.epoch(), 2);
        let t = h.snapshot();
        assert_eq!(t.groups_of_endpoint(0).len(), 2);
        assert_eq!(t.groups_of_endpoint(1).len(), 2);
        t.validate().unwrap();
    }

    #[test]
    fn drain_moves_groups_to_survivors() {
        let h = topo(64, 16, 2); // groups 0,2 → e0; 1,3 → e1
        let epoch = h.drain_endpoint(1).unwrap();
        assert_eq!(epoch, 2);
        let t = h.snapshot();
        assert!(!t.endpoints[1].live);
        assert_eq!(t.groups_of_endpoint(0).len(), 4);
        t.validate().unwrap();
        // slot index stayed stable
        assert_eq!(t.endpoints.len(), 2);
    }

    #[test]
    fn draining_last_endpoint_rejected_and_rolled_back() {
        let h = topo(16, 16, 1);
        assert!(h.drain_endpoint(0).is_err());
        // rolled back wholesale: still live, epoch unchanged
        let t = h.snapshot();
        assert!(t.endpoints[0].live);
        assert_eq!(t.epoch, 1);
        assert_eq!(h.epoch(), 1);
    }

    #[test]
    fn assign_validates_target_liveness() {
        let h = topo(32, 16, 2);
        h.drain_endpoint(1).unwrap();
        let err = h.assign(&[(0, 1)]).unwrap_err();
        assert!(err.to_string().contains("dead endpoint"), "{err}");
        // failed assign must not bump the epoch
        assert_eq!(h.epoch(), 2);
    }

    #[test]
    fn route_reports_current_slot_and_epoch() {
        let h = topo(32, 16, 2);
        assert_eq!(h.route(0).unwrap(), (0, 1));
        assert_eq!(h.route(1).unwrap(), (1, 1));
        let e = h.assign(&[(1, 0)]).unwrap();
        assert_eq!(h.route(1).unwrap(), (0, e));
        assert!(h.route(5).is_err());
    }

    fn rtopo(ranks: usize, gsize: usize, n_eps: usize, factor: usize) -> TopologyHandle {
        let groups = GroupMap::new(ranks, gsize, n_eps).unwrap();
        let addrs = (0..n_eps).map(|i| addr(7200 + i as u16)).collect();
        TopologyHandle::new_replicated(groups, addrs, &[], factor).unwrap()
    }

    #[test]
    fn replicated_chains_are_headed_distinct_and_domain_spread() {
        let h = rtopo(64, 16, 3, 2); // 4 groups, 3 endpoints, factor 2
        let t = h.snapshot();
        t.validate().unwrap();
        for g in 0..4 {
            let chain = t.replica_chain(g).unwrap();
            assert_eq!(chain.len(), 2, "group {g}");
            assert_eq!(chain[0], t.assignment[g]);
            assert_eq!(chain[1], (chain[0] + 1) % 3);
            assert_eq!(t.successor_in_chain(g, chain[0]), Some(chain[1]));
            assert_eq!(t.successor_in_chain(g, chain[1]), None);
        }
    }

    #[test]
    fn colocated_domains_shorten_chains_instead_of_violating() {
        // two endpoints share domain "a": a factor-3 chain can only
        // ever reach length 2
        let groups = GroupMap::new(16, 16, 3).unwrap();
        let addrs = (0..3).map(|i| addr(7300 + i as u16)).collect();
        let domains = vec!["a".to_string(), "a".to_string(), "b".to_string()];
        let h = TopologyHandle::new_replicated(groups, addrs, &domains, 3).unwrap();
        let t = h.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[0, 2], "e1 shares e0's domain");
        t.validate().unwrap();
    }

    #[test]
    fn drain_of_chain_head_promotes_the_successor() {
        let h = rtopo(32, 16, 3, 2); // group 0 chain [0,1], group 1 chain [1,2]
        let epoch = h.drain_endpoint(0).unwrap();
        assert_eq!(epoch, 2);
        let t = h.snapshot();
        // group 0: head 0 died → successor 1 promoted, chain shrank
        assert_eq!(t.assignment[0], 1);
        assert_eq!(t.replica_chain(0).unwrap(), &[1]);
        // group 1: 0 was not in its chain → untouched
        assert_eq!(t.replica_chain(1).unwrap(), &[1, 2]);
        t.validate().unwrap();
    }

    #[test]
    fn repair_tops_chains_back_up_in_fresh_domains() {
        let h = rtopo(32, 16, 3, 2);
        h.drain_endpoint(0).unwrap();
        let epoch = h.repair_chains().unwrap().unwrap();
        assert_eq!(epoch, 3);
        let t = h.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[1, 2]);
        t.validate().unwrap();
        // idempotent: full chains → no-op, epoch untouched
        assert!(h.repair_chains().unwrap().is_none());
        assert_eq!(h.epoch(), 3);
    }

    #[test]
    fn repair_marks_recruits_catching_up() {
        let h = rtopo(32, 16, 3, 2); // group 0 chain [0,1], group 1 chain [1,2]
        h.drain_endpoint(0).unwrap();
        h.repair_chains().unwrap().unwrap();
        let t = h.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[1, 2]);
        // the recruit holds none of group 0's history…
        assert!(t.is_catching_up(0, 2));
        // …but it has always been a full member of group 1's chain
        assert!(!t.is_catching_up(1, 2));
        t.validate().unwrap();
    }

    #[test]
    fn failover_prefers_full_history_member_over_recruit() {
        let h = rtopo(16, 16, 4, 3); // one group, chain [0,1,2]
        h.drain_endpoint(2).unwrap();
        h.repair_chains().unwrap().unwrap(); // chain [0,1,3], 3 catching up
        assert!(h.snapshot().is_catching_up(0, 3));
        h.drain_endpoint(0).unwrap();
        let t = h.snapshot();
        // 1 held every tail-acked record; 3 only holds the suffix
        assert_eq!(t.assignment[0], 1);
        assert_eq!(t.replica_chain(0).unwrap(), &[1, 3]);
        assert!(t.is_catching_up(0, 3));
        t.validate().unwrap();
    }

    #[test]
    fn last_resort_promotion_clears_catching_up_mark() {
        let h = rtopo(32, 16, 3, 2); // group 0 chain [0,1], group 1 chain [1,2]
        h.drain_endpoint(0).unwrap();
        h.repair_chains().unwrap().unwrap(); // group 0 chain [1,2], 2 catching up
        h.drain_endpoint(1).unwrap();
        let t = h.snapshot();
        // no full-history member left: the truncated recruit is still
        // better than an empty reassignment, and it is head now
        assert_eq!(t.assignment[0], 2);
        assert_eq!(t.replica_chain(0).unwrap(), &[2]);
        assert!(!t.is_catching_up(0, 2));
        t.validate().unwrap();
    }

    #[test]
    fn mark_replica_synced_restores_promotion_preference() {
        let h = rtopo(32, 16, 3, 2);
        h.drain_endpoint(0).unwrap();
        h.repair_chains().unwrap().unwrap(); // group 0 chain [1,2], 2 catching up
        h.mark_replica_synced(0, 2).unwrap();
        assert!(!h.snapshot().is_catching_up(0, 2));
        // synced → promotion is the normal preferred path again
        h.drain_endpoint(1).unwrap();
        let t = h.snapshot();
        assert_eq!(t.assignment[0], 2);
        t.validate().unwrap();
    }

    #[test]
    fn migrating_onto_a_recruit_clears_its_mark() {
        let h = rtopo(32, 16, 3, 2);
        h.drain_endpoint(0).unwrap();
        h.repair_chains().unwrap().unwrap(); // group 0 chain [1,2], 2 catching up
        // an explicit migration re-heads at 2: readers follow the
        // handoff and writers start fresh there, so the mark is moot
        h.assign(&[(0, 2)]).unwrap();
        let t = h.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[2, 1]);
        assert!(!t.is_catching_up(0, 2));
        t.validate().unwrap();
    }

    #[test]
    fn on_change_fires_after_bumps_with_lock_released() {
        use std::sync::Mutex;
        let h = rtopo(32, 16, 3, 2);
        let seen: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let h2 = h.clone();
        let log = seen.clone();
        h.set_on_change(move |t| {
            // re-entering the handle proves the write lock is released
            assert_eq!(h2.epoch(), t.epoch);
            h2.snapshot();
            log.lock().unwrap().push(t.epoch);
        });
        h.drain_endpoint(0).unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![2]);
        h.repair_chains().unwrap().unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![2, 3]);
        // a no-op keeps the epoch — and stays silent
        assert!(h.repair_chains().unwrap().is_none());
        // a rejected mutation rolls back — and stays silent
        assert!(h.assign(&[(99, 0)]).is_err());
        assert_eq!(*seen.lock().unwrap(), vec![2, 3]);
        h.clear_on_change();
        h.assign(&[(0, 1)]).unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![2, 3]);
    }

    #[test]
    fn migrating_a_head_keeps_the_old_head_as_follower() {
        let h = rtopo(16, 16, 2, 2); // one group, chain [0,1]
        h.assign(&[(0, 1)]).unwrap();
        let t = h.snapshot();
        // the old head already holds the data — it stays as replica
        assert_eq!(t.replica_chain(0).unwrap(), &[1, 0]);
        t.validate().unwrap();
    }

    #[test]
    fn rebalance_is_idempotent_at_spread_one() {
        let h = topo(48, 16, 3); // 3 groups, 3 endpoints, load 1 each
        assert!(h.rebalance().unwrap().is_none());
        // skew it: everything on endpoint 0
        h.assign(&[(1, 0), (2, 0)]).unwrap();
        let epoch = h.rebalance().unwrap().unwrap();
        assert!(epoch > 1);
        let t = h.snapshot();
        for e in 0..3 {
            assert_eq!(t.groups_of_endpoint(e).len(), 1, "endpoint {e}");
        }
        assert!(h.rebalance().unwrap().is_none());
    }
}
