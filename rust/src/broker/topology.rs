//! Versioned group→endpoint topology — the substrate of the paper's
//! *elastic* claim (ISSUE 3 tentpole).
//!
//! [`GroupMap`] stays what it always was: the immutable partition of
//! ranks into process groups.  What used to be hard-wired on top of it
//! (group *g* → endpoint *g mod n*, fixed at `Broker::init`) is now a
//! [`Topology`]: an **epoch-numbered** assignment of groups to endpoint
//! slots, where slots can be added (scale-out), drained (scale-in) or
//! marked dead (failure), and every assignment change bumps the epoch.
//!
//! The epoch is the fencing token of the whole migration protocol:
//! writers register streams with `HELLO <key> <epoch>`, endpoints
//! reject writes below the stream's fence (`STALE`), and handoff
//! tombstones carry the epoch the stream moved at — so two writers
//! racing a migration can never interleave appends, and a reader can
//! follow a stream across endpoints without loss or duplication.
//!
//! [`TopologyHandle`] is the shared, cheaply-pollable view: writers
//! check `epoch()` (one atomic load) at every batch boundary and only
//! take the read lock when it moved.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use super::groups::GroupMap;

/// One endpoint slot.  Slot indices are stable for the topology's
/// lifetime (a removed endpoint keeps its index, marked not-live), so
/// writers, dialers and QoS boards can key everything by slot.
#[derive(Clone, Debug)]
pub struct EndpointSlot {
    pub addr: SocketAddr,
    pub live: bool,
}

/// An epoch-numbered group→endpoint assignment.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Monotonic version; bumped by every assignment change.  Starts
    /// at 1 (0 means "never registered" on the endpoint side).
    pub epoch: u64,
    /// The immutable rank→group partition.
    pub groups: GroupMap,
    /// `assignment[g]` = endpoint slot group `g` writes to.
    pub assignment: Vec<usize>,
    /// Endpoint slots (stable indices; `live` toggles).
    pub endpoints: Vec<EndpointSlot>,
}

impl Topology {
    /// The static topology every pre-elastic run used: group `g` on
    /// endpoint `g % n`, all endpoints live, epoch 1.
    pub fn new_static(groups: GroupMap, addrs: Vec<SocketAddr>) -> Result<Topology> {
        ensure!(!addrs.is_empty(), "need at least one endpoint");
        let n = addrs.len();
        let assignment = (0..groups.n_groups()).map(|g| g % n).collect();
        let topo = Topology {
            epoch: 1,
            groups,
            assignment,
            endpoints: addrs
                .into_iter()
                .map(|addr| EndpointSlot { addr, live: true })
                .collect(),
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Endpoint slot a group currently writes to.
    pub fn endpoint_of_group(&self, group: usize) -> Result<usize> {
        ensure!(
            group < self.assignment.len(),
            "group {group} out of range 0..{}",
            self.assignment.len()
        );
        Ok(self.assignment[group])
    }

    /// Endpoint slot a rank currently writes to.
    pub fn endpoint_of_rank(&self, rank: usize) -> Result<usize> {
        self.endpoint_of_group(self.groups.group_of_rank(rank)?)
    }

    /// Groups currently assigned to endpoint slot `e`.
    pub fn groups_of_endpoint(&self, e: usize) -> Vec<usize> {
        (0..self.assignment.len())
            .filter(|&g| self.assignment[g] == e)
            .collect()
    }

    /// Live endpoint slot indices.
    pub fn live_endpoints(&self) -> Vec<usize> {
        (0..self.endpoints.len())
            .filter(|&e| self.endpoints[e].live)
            .collect()
    }

    /// Stream keys endpoint `e` currently receives for `field`.
    pub fn streams_of_endpoint(&self, e: usize, field: &str) -> Vec<String> {
        (0..self.groups.total_ranks())
            .filter(|&r| self.endpoint_of_rank(r).unwrap() == e)
            .map(|r| crate::record::stream_key(field, r as u32))
            .collect()
    }

    /// The core invariant: every group is assigned to exactly one
    /// endpoint slot that exists and is live.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.assignment.len() == self.groups.n_groups(),
            "assignment covers {} groups, topology has {}",
            self.assignment.len(),
            self.groups.n_groups()
        );
        for (g, &e) in self.assignment.iter().enumerate() {
            ensure!(
                e < self.endpoints.len(),
                "group {g} assigned to missing endpoint {e}"
            );
            ensure!(
                self.endpoints[e].live,
                "group {g} assigned to dead endpoint {e}"
            );
        }
        ensure!(
            !self.live_endpoints().is_empty(),
            "no live endpoints left"
        );
        Ok(())
    }

    /// Live endpoint with the fewest assigned groups, excluding `not`
    /// (ties broken by lowest index — deterministic).
    fn least_loaded_live(&self, not: Option<usize>) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (load, idx)
        for e in 0..self.endpoints.len() {
            if !self.endpoints[e].live || Some(e) == not {
                continue;
            }
            let load = self.groups_of_endpoint(e).len();
            let better = match best {
                None => true,
                Some((bl, bi)) => load < bl || (load == bl && e < bi),
            };
            if better {
                best = Some((load, e));
            }
        }
        best.map(|(_, e)| e)
    }
}

/// Shared, versioned view of the topology.
///
/// Cloning the handle shares the topology.  `epoch()` is one atomic
/// load, so writers can poll for changes at every batch boundary for
/// free; all mutating operations bump the epoch exactly once and keep
/// the [`Topology::validate`] invariant.
#[derive(Clone)]
pub struct TopologyHandle {
    inner: Arc<RwLock<Topology>>,
    epoch: Arc<AtomicU64>,
}

impl TopologyHandle {
    pub fn new(topology: Topology) -> TopologyHandle {
        let epoch = Arc::new(AtomicU64::new(topology.epoch));
        TopologyHandle {
            inner: Arc::new(RwLock::new(topology)),
            epoch,
        }
    }

    /// Convenience: a static topology from a rank partition + addresses.
    pub fn new_static(groups: GroupMap, addrs: Vec<SocketAddr>) -> Result<TopologyHandle> {
        Ok(TopologyHandle::new(Topology::new_static(groups, addrs)?))
    }

    /// Current epoch (one atomic load; no lock).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// A consistent copy of the whole topology.
    pub fn snapshot(&self) -> Topology {
        self.inner.read().unwrap().clone()
    }

    /// Where a group writes right now: `(endpoint slot, epoch)`.
    pub fn route(&self, group: usize) -> Result<(usize, u64)> {
        let t = self.inner.read().unwrap();
        Ok((t.endpoint_of_group(group)?, t.epoch))
    }

    /// Address of an endpoint slot (the TCP dialer's resolver).
    pub fn endpoint_addr(&self, e: usize) -> Result<SocketAddr> {
        let t = self.inner.read().unwrap();
        ensure!(e < t.endpoints.len(), "no endpoint slot {e}");
        Ok(t.endpoints[e].addr)
    }

    fn mutate<R>(&self, f: impl FnOnce(&mut Topology) -> Result<R>) -> Result<R> {
        let mut t = self.inner.write().unwrap();
        let before = t.clone();
        match f(&mut t).and_then(|r| t.validate().map(|_| r)) {
            Ok(r) => {
                t.epoch += 1;
                self.epoch.store(t.epoch, Ordering::Release);
                Ok(r)
            }
            Err(e) => {
                *t = before; // roll back a rejected mutation wholesale
                Err(e)
            }
        }
    }

    /// Add an endpoint slot without moving any group onto it yet.
    /// Bumps the epoch (the slot becomes routable for future moves).
    pub fn add_endpoint(&self, addr: SocketAddr) -> Result<usize> {
        self.mutate(|t| {
            t.endpoints.push(EndpointSlot { addr, live: true });
            Ok(t.endpoints.len() - 1)
        })
    }

    /// Move specific groups: `moves` = `(group, target endpoint)`.
    /// Returns the new epoch.
    pub fn assign(&self, moves: &[(usize, usize)]) -> Result<u64> {
        self.mutate(|t| {
            for &(g, e) in moves {
                ensure!(g < t.assignment.len(), "no group {g}");
                t.assignment[g] = e;
            }
            Ok(())
        })?;
        Ok(self.epoch())
    }

    /// Scale-out: add an endpoint and rebalance groups onto it so live
    /// loads differ by at most one group (fewest moves, deterministic).
    /// Returns `(new slot index, new epoch)`.
    pub fn scale_out(&self, addr: SocketAddr) -> Result<(usize, u64)> {
        let slot = self.mutate(|t| {
            t.endpoints.push(EndpointSlot { addr, live: true });
            let slot = t.endpoints.len() - 1;
            rebalance_in_place(t);
            Ok(slot)
        })?;
        Ok((slot, self.epoch()))
    }

    /// Scale-in / failure: mark a slot not-live and move its groups to
    /// the least-loaded surviving endpoints.  The slot keeps its index;
    /// its server (if still up) stays drainable by readers.  Returns
    /// the new epoch.
    pub fn drain_endpoint(&self, e: usize) -> Result<u64> {
        self.mutate(|t| {
            ensure!(e < t.endpoints.len(), "no endpoint slot {e}");
            ensure!(t.endpoints[e].live, "endpoint {e} already drained");
            t.endpoints[e].live = false;
            for g in 0..t.assignment.len() {
                if t.assignment[g] == e {
                    let target = t
                        .least_loaded_live(None)
                        .ok_or_else(|| anyhow::anyhow!("no live endpoint to drain {e} into"))?;
                    t.assignment[g] = target;
                }
            }
            Ok(())
        })?;
        Ok(self.epoch())
    }

    /// Even out group load across live endpoints (at most one group of
    /// spread).  Returns the new epoch if anything moved; a no-op
    /// leaves the epoch untouched.
    pub fn rebalance(&self) -> Result<Option<u64>> {
        let mut t = self.inner.write().unwrap();
        let before = t.clone();
        if !rebalance_in_place(&mut t) {
            return Ok(None);
        }
        if let Err(e) = t.validate() {
            *t = before;
            return Err(e);
        }
        t.epoch += 1;
        self.epoch.store(t.epoch, Ordering::Release);
        Ok(Some(t.epoch))
    }
}

/// Move groups from the most- to the least-loaded live endpoint until
/// the spread is ≤ 1.  Deterministic (lowest indices win ties); returns
/// whether anything moved.
fn rebalance_in_place(t: &mut Topology) -> bool {
    let mut moved = false;
    loop {
        let live = t.live_endpoints();
        if live.len() < 2 {
            return moved;
        }
        let loads: Vec<(usize, usize)> = live
            .iter()
            .map(|&e| (e, t.groups_of_endpoint(e).len()))
            .collect();
        let &(min_e, min_l) = loads.iter().min_by_key(|&&(e, l)| (l, e)).unwrap();
        let &(max_e, max_l) = loads.iter().max_by_key(|&&(e, l)| (l, usize::MAX - e)).unwrap();
        if max_l - min_l <= 1 {
            return moved;
        }
        // move the lowest-numbered group off the most-loaded endpoint
        let g = t.groups_of_endpoint(max_e)[0];
        t.assignment[g] = min_e;
        moved = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn topo(ranks: usize, gsize: usize, n_eps: usize) -> TopologyHandle {
        let groups = GroupMap::new(ranks, gsize, n_eps).unwrap();
        let addrs = (0..n_eps).map(|i| addr(7000 + i as u16)).collect();
        TopologyHandle::new_static(groups, addrs).unwrap()
    }

    #[test]
    fn static_topology_matches_legacy_modulo_mapping() {
        let h = topo(64, 16, 2);
        let t = h.snapshot();
        assert_eq!(t.epoch, 1);
        for r in 0..64 {
            let legacy = t.groups.endpoint_of_rank(r).unwrap();
            assert_eq!(t.endpoint_of_rank(r).unwrap(), legacy);
        }
        assert_eq!(t.streams_of_endpoint(0, "u").len(), 32);
        assert_eq!(t.streams_of_endpoint(1, "u").len(), 32);
    }

    #[test]
    fn scale_out_rebalances_and_bumps_epoch_once() {
        let h = topo(64, 16, 1); // 4 groups on 1 endpoint
        let (slot, epoch) = h.scale_out(addr(7100)).unwrap();
        assert_eq!(slot, 1);
        assert_eq!(epoch, 2);
        assert_eq!(h.epoch(), 2);
        let t = h.snapshot();
        assert_eq!(t.groups_of_endpoint(0).len(), 2);
        assert_eq!(t.groups_of_endpoint(1).len(), 2);
        t.validate().unwrap();
    }

    #[test]
    fn drain_moves_groups_to_survivors() {
        let h = topo(64, 16, 2); // groups 0,2 → e0; 1,3 → e1
        let epoch = h.drain_endpoint(1).unwrap();
        assert_eq!(epoch, 2);
        let t = h.snapshot();
        assert!(!t.endpoints[1].live);
        assert_eq!(t.groups_of_endpoint(0).len(), 4);
        t.validate().unwrap();
        // slot index stayed stable
        assert_eq!(t.endpoints.len(), 2);
    }

    #[test]
    fn draining_last_endpoint_rejected_and_rolled_back() {
        let h = topo(16, 16, 1);
        assert!(h.drain_endpoint(0).is_err());
        // rolled back wholesale: still live, epoch unchanged
        let t = h.snapshot();
        assert!(t.endpoints[0].live);
        assert_eq!(t.epoch, 1);
        assert_eq!(h.epoch(), 1);
    }

    #[test]
    fn assign_validates_target_liveness() {
        let h = topo(32, 16, 2);
        h.drain_endpoint(1).unwrap();
        let err = h.assign(&[(0, 1)]).unwrap_err();
        assert!(err.to_string().contains("dead endpoint"), "{err}");
        // failed assign must not bump the epoch
        assert_eq!(h.epoch(), 2);
    }

    #[test]
    fn route_reports_current_slot_and_epoch() {
        let h = topo(32, 16, 2);
        assert_eq!(h.route(0).unwrap(), (0, 1));
        assert_eq!(h.route(1).unwrap(), (1, 1));
        let e = h.assign(&[(1, 0)]).unwrap();
        assert_eq!(h.route(1).unwrap(), (0, e));
        assert!(h.route(5).is_err());
    }

    #[test]
    fn rebalance_is_idempotent_at_spread_one() {
        let h = topo(48, 16, 3); // 3 groups, 3 endpoints, load 1 each
        assert!(h.rebalance().unwrap().is_none());
        // skew it: everything on endpoint 0
        h.assign(&[(1, 0), (2, 0)]).unwrap();
        let epoch = h.rebalance().unwrap().unwrap();
        assert!(epoch > 1);
        let t = h.snapshot();
        for e in 0..3 {
            assert_eq!(t.groups_of_endpoint(e).len(), 1, "endpoint {e}");
        }
        assert!(h.rebalance().unwrap().is_none());
    }
}
