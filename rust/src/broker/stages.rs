//! The broker-side data-reduction stage pipeline (ISSUE 5 tentpole) —
//! the paper's §1 promise made concrete: "ElasticBroker performs data
//! filtering, aggregation, and format conversions to close the gap
//! between an HPC ecosystem and a distinct Cloud ecosystem".
//!
//! Every record a [`crate::broker::BrokerCtx`] writes passes through
//! four composable stages between the simulation and the batch queue:
//!
//! ```text
//!          ┌────────┐   ┌───────────┐   ┌─────────┐   ┌──────────┐
//!  write → │ filter │ → │ aggregate │ → │ convert │ → │ compress │ → queue
//!          └────────┘   └───────────┘   └─────────┘   └──────────┘
//!   drop records      block-mean        f32→f16 /      byte-shuffle
//!   (decimation,      downsample +      quantized      + LZ behind
//!   rank subset)      min/max/mean      delta, with    the Codec
//!   or crop (ROI)     sidecar stats     stated bound   trait
//! ```
//!
//! 1. **filter** — every-Nth-step decimation, rank subsetting (only
//!    every `rank_stride`-th rank ships at all), per-element value
//!    transforms ([`FilterStage`]: stride / magnitude / clamp /
//!    threshold — the formerly separate `broker::Filter`, folded in
//!    here by ISSUE 6 so its reductions are part of the stage byte
//!    accounting) and region-of-interest cropping along the last
//!    (fastest-varying, spatial) axis.
//! 2. **aggregate** — block-mean spatial downsampling by a configured
//!    factor along the last axis, with per-field min/max/mean sidecar
//!    stats carried in the frame header.
//! 3. **convert** — element format conversion
//!    ([`crate::record::Encoding`]): raw f32, IEEE binary16, or
//!    quantized-delta, the lossy ones carrying their *measured* max
//!    absolute error in the header so downstream consumers know the
//!    bound.
//! 4. **compress** — lossless payload compression behind the
//!    [`crate::record::Codec`] trait (byte-shuffle + LZ by default),
//!    with a per-frame fallback to uncompressed when a frame does not
//!    actually shrink.
//!
//! The output is a staged [`StreamRecord`] whose `EBR2` frame the
//! endpoints and the WAL store opaquely — the reduction carries
//! through wire *and* disk multiplicatively — and which
//! [`StreamRecord::decode`] reverses transparently on the Cloud side,
//! so the DMD analysis sees plain f32 snapshots (bit-exact for
//! lossless stages, within the stated bound for lossy ones).  Peers
//! that never enable stages keep exchanging byte-identical `EBR1`
//! frames: interop is unchanged.
//!
//! Costs and achieved reduction are recorded in
//! [`crate::metrics::StageMetrics`]; benchmark with
//! `cargo bench --bench micro_stages` (emits `BENCH_stages.json`).

use std::borrow::Cow;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::filter::{Filter, FilterStage};
use crate::metrics::StageMetrics;
use crate::record::{codec_for, convert, CodecKind, Encoding, FieldStats, FrameMeta, StreamRecord};

/// Stage-pipeline knobs (config `[stages]`, CLI `--stage-*`).
#[derive(Clone, Debug, PartialEq)]
pub struct StagesConfig {
    /// Per-element value transforms ([`FilterStage`]: stride /
    /// magnitude / clamp / threshold) run at the head of the filter
    /// stage (ISSUE 6: the formerly separate `broker::Filter` now
    /// lives here, so transformed bytes are part of the stage byte
    /// accounting instead of silently evading it).
    pub transforms: Vec<FilterStage>,
    /// Keep every `decimate`-th written record per context (1 = all).
    pub decimate: u64,
    /// Ship only ranks with `rank % rank_stride == 0` (1 = all ranks).
    pub rank_stride: u32,
    /// Region of interest: keep elements `[lo, hi)` of the last axis.
    pub roi: Option<(u32, u32)>,
    /// Block-mean downsampling factor along the last axis (1 = off).
    pub aggregate: usize,
    /// Compute min/max/mean sidecar stats even when `aggregate` is off
    /// (aggregated frames always carry them).
    pub stats: bool,
    /// Element encoding of the shipped payload.
    pub convert: Encoding,
    /// Quantization step for [`Encoding::QDelta`] (absolute error is
    /// at most half of this).
    pub qdelta_step: f32,
    /// Lossless payload codec.
    pub codec: CodecKind,
    /// Per-stream accuracy target (max tolerated `err_bound`, absolute;
    /// 0 = unconstrained).  The adapt controller (`broker::adapt`,
    /// ISSUE 8) never walks a stream onto a ladder level whose measured
    /// error bound exceeds this.
    pub max_err: f32,
}

impl Default for StagesConfig {
    fn default() -> Self {
        StagesConfig {
            transforms: Vec::new(),
            decimate: 1,
            rank_stride: 1,
            roi: None,
            aggregate: 1,
            stats: false,
            convert: Encoding::F32,
            qdelta_step: 1e-3,
            codec: CodecKind::None,
            max_err: 0.0,
        }
    }
}

impl StagesConfig {
    /// Whether the pipeline changes nothing (records then ship as
    /// classic raw `EBR1` frames).
    pub fn is_passthrough(&self) -> bool {
        self.transforms.is_empty()
            && self.decimate <= 1
            && self.rank_stride <= 1
            && self.roi.is_none()
            && self.aggregate <= 1
            && !self.stats
            && self.convert == Encoding::F32
            && self.codec == CodecKind::None
    }

    /// Parse a `lo:hi` ROI spec (elements of the last axis, hi
    /// exclusive).
    pub fn parse_roi(s: &str) -> Result<(u32, u32)> {
        let (lo, hi) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("roi '{s}' is not lo:hi"))?;
        let lo: u32 = lo.trim().parse().map_err(|e| anyhow::anyhow!("roi lo: {e}"))?;
        let hi: u32 = hi.trim().parse().map_err(|e| anyhow::anyhow!("roi hi: {e}"))?;
        ensure!(lo < hi, "roi {lo}:{hi} is empty");
        Ok((lo, hi))
    }

    /// Sanity-check invariants the pipeline relies on.
    pub fn validate(&self) -> Result<()> {
        for t in &self.transforms {
            match *t {
                FilterStage::Stride(k) => {
                    ensure!(k >= 1, "stages.transforms: stride must be >= 1")
                }
                FilterStage::Clamp(lo, hi) => {
                    ensure!(lo <= hi, "stages.transforms: clamp lo > hi")
                }
                FilterStage::Magnitude | FilterStage::Threshold(_) => {}
            }
        }
        ensure!(self.decimate >= 1, "stages.decimate must be >= 1");
        ensure!(self.rank_stride >= 1, "stages.rank_stride must be >= 1");
        ensure!(self.aggregate >= 1, "stages.aggregate must be >= 1");
        if let Some((lo, hi)) = self.roi {
            ensure!(lo < hi, "stages.roi {lo}:{hi} is empty");
        }
        if self.convert == Encoding::QDelta {
            ensure!(
                self.qdelta_step > 0.0 && self.qdelta_step.is_finite(),
                "stages.qdelta_step must be a positive finite number"
            );
        }
        ensure!(
            self.max_err >= 0.0 && self.max_err.is_finite(),
            "stages.max_err must be a non-negative finite number"
        );
        Ok(())
    }

    /// The provenance tag carried in every staged frame header, with
    /// the codec that actually applied to this frame.
    fn provenance(&self, applied_codec: CodecKind) -> String {
        let mut parts: Vec<String> = Vec::new();
        for t in &self.transforms {
            parts.push(match *t {
                FilterStage::Stride(k) => format!("xstride:{k}"),
                FilterStage::Magnitude => "mag".to_string(),
                FilterStage::Clamp(lo, hi) => format!("clamp:{lo}:{hi}"),
                FilterStage::Threshold(thr) => format!("thr:{thr}"),
            });
        }
        if self.rank_stride > 1 {
            parts.push(format!("ranks%{}", self.rank_stride));
        }
        if self.decimate > 1 {
            parts.push(format!("decim:{}", self.decimate));
        }
        if let Some((lo, hi)) = self.roi {
            parts.push(format!("roi:{lo}:{hi}"));
        }
        if self.aggregate > 1 {
            parts.push(format!("agg:{}", self.aggregate));
        }
        if self.convert != Encoding::F32 {
            parts.push(self.convert.name().to_string());
        }
        if applied_codec != CodecKind::None {
            parts.push(applied_codec.name().to_string());
        }
        parts.join("|")
    }
}

/// The runnable pipeline: validated config + metrics.  One shared
/// instance serves every context of a broker (it is stateless per
/// record; the decimation counter lives in the context).
pub struct StagePipeline {
    cfg: StagesConfig,
    /// The value-transform head of the filter stage, prebuilt from
    /// `cfg.transforms`.
    xform: Filter,
    metrics: Arc<StageMetrics>,
}

impl StagePipeline {
    pub fn new(cfg: StagesConfig, metrics: Arc<StageMetrics>) -> Result<StagePipeline> {
        cfg.validate()?;
        let xform = Filter::new(cfg.transforms.clone());
        Ok(StagePipeline { cfg, xform, metrics })
    }

    /// A do-nothing pipeline (records ship as raw `EBR1` frames).
    pub fn passthrough() -> StagePipeline {
        StagePipeline {
            cfg: StagesConfig::default(),
            xform: Filter::passthrough(),
            metrics: Arc::new(StageMetrics::new()),
        }
    }

    pub fn config(&self) -> &StagesConfig {
        &self.cfg
    }

    pub fn is_passthrough(&self) -> bool {
        self.cfg.is_passthrough()
    }

    /// Whether the filter stage ships this rank at all.
    pub fn admits_rank(&self, rank: u32) -> bool {
        rank % self.cfg.rank_stride.max(1) == 0
    }

    /// Run one snapshot through filter → aggregate → convert →
    /// compress.  `seq` is the per-context write sequence number the
    /// decimation filter counts (the first write is kept).  Returns
    /// `None` when the filter stage drops the record — an intentional
    /// reduction, not an error.
    #[allow(clippy::too_many_arguments)]
    pub fn apply(
        &self,
        field: &str,
        rank: u32,
        step: u64,
        seq: u64,
        gen_micros: u64,
        shape: &[u32],
        data: &[f32],
    ) -> Result<Option<StreamRecord>> {
        self.apply_tagged(field, rank, step, seq, gen_micros, shape, data, None)
    }

    /// [`apply`](StagePipeline::apply) with an optional provenance tag
    /// appended to the frame header — the adapt controller stamps each
    /// frame with its ladder level + epoch (`lvl:N@E`) so readers, the
    /// WAL and replay stay self-describing across mid-run level
    /// changes.  A tagged frame is always a staged `EBR2` frame, even
    /// for a passthrough config: the tag must survive the wire.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_tagged(
        &self,
        field: &str,
        rank: u32,
        step: u64,
        seq: u64,
        gen_micros: u64,
        shape: &[u32],
        data: &[f32],
        tag: Option<&str>,
    ) -> Result<Option<StreamRecord>> {
        if self.is_passthrough() && tag.is_none() {
            return Ok(Some(StreamRecord::from_f32(
                field, rank, step, gen_micros, shape, data,
            )?));
        }
        let n: usize = shape.iter().map(|&d| d as usize).product();
        ensure!(
            n == data.len(),
            "stages: shape {shape:?} does not match data len {}",
            data.len()
        );
        self.metrics.records_in.inc();
        self.metrics.bytes_in.add((data.len() * 4) as u64);

        // --- 1. filter ------------------------------------------------
        let t = Instant::now();
        if !self.admits_rank(rank) || (self.cfg.decimate > 1 && seq % self.cfg.decimate != 0) {
            self.metrics.records_filtered.inc();
            self.metrics.filter_us.record(t.elapsed().as_micros() as u64);
            return Ok(None);
        }
        // Borrow until a stage actually reshapes the data — a codec- or
        // convert-only config never copies the snapshot here.
        let (mut shape, mut data): (Cow<'_, [u32]>, Cow<'_, [f32]>) =
            if self.xform.is_passthrough() {
                (Cow::Borrowed(shape), Cow::Borrowed(data))
            } else {
                let (s, d) = self.xform.apply(shape, data)?;
                (Cow::Owned(s), Cow::Owned(d))
            };
        if let Some((lo, hi)) = self.cfg.roi {
            let (s, d) = crop_last_axis(&shape, &data, lo, hi)?;
            shape = Cow::Owned(s);
            data = Cow::Owned(d);
        }
        self.metrics.filter_us.record(t.elapsed().as_micros() as u64);

        // --- 2. aggregate ---------------------------------------------
        let t = Instant::now();
        // Measured max-abs block-mean residual: what a consumer that
        // expands the aggregated frame back to element granularity is
        // actually off by.  Folded into `err_bound` below (ISSUE 8
        // bugfix: it used to be silently excluded, so an
        // `aggregate=4, convert=f32` frame shipped `err_bound=0.0`).
        let mut agg_err = 0.0f32;
        if self.cfg.aggregate > 1 {
            let (s, d, e) =
                block_mean_last_axis_with_residual(&shape, &data, self.cfg.aggregate)?;
            shape = Cow::Owned(s);
            data = Cow::Owned(d);
            agg_err = e;
        }
        let stats = if self.cfg.aggregate > 1 || self.cfg.stats {
            Some(field_stats(&data))
        } else {
            None
        };
        self.metrics.aggregate_us.record(t.elapsed().as_micros() as u64);

        // --- 3. convert -----------------------------------------------
        let t = Instant::now();
        let (encoded, convert_err, enc_param) = match self.cfg.convert {
            Encoding::F32 => {
                let mut b = Vec::with_capacity(data.len() * 4);
                for v in data.iter() {
                    b.extend_from_slice(&v.to_le_bytes());
                }
                (b, 0.0, 0.0)
            }
            Encoding::F16 => {
                let (b, e) = convert::encode_f16(&data)?;
                (b, e, 0.0)
            }
            Encoding::QDelta => {
                let (b, e) = convert::encode_qdelta(&data, self.cfg.qdelta_step)?;
                (b, e, self.cfg.qdelta_step)
            }
        };
        self.metrics.convert_us.record(t.elapsed().as_micros() as u64);

        // --- 4. compress ----------------------------------------------
        let t = Instant::now();
        let raw_len = encoded.len() as u32;
        let (applied_codec, payload) = match self.cfg.codec {
            CodecKind::None => (CodecKind::None, encoded),
            kind => {
                let comp = codec_for(kind).compress(&encoded, self.cfg.convert.elem_size());
                // Per-frame fallback: never ship a frame the codec grew.
                if comp.len() < encoded.len() {
                    (kind, comp)
                } else {
                    (CodecKind::None, encoded)
                }
            }
        };
        self.metrics.compress_us.record(t.elapsed().as_micros() as u64);
        self.metrics.bytes_out.add(payload.len() as u64);

        // Honest end-to-end bound vs the data that *entered* the
        // aggregate stage (filter stages subset, they do not
        // approximate): |decoded − original| ≤ agg residual + convert
        // error, since the convert error is measured against the
        // post-aggregate values (triangle inequality).
        let err_bound = agg_err + convert_err;
        let provenance = match tag {
            None => self.cfg.provenance(applied_codec),
            Some(tag) => {
                let base = self.cfg.provenance(applied_codec);
                if base.is_empty() {
                    tag.to_string()
                } else {
                    format!("{base}|{tag}")
                }
            }
        };
        let meta = FrameMeta {
            encoding: self.cfg.convert,
            codec: applied_codec,
            enc_param,
            err_bound,
            raw_len,
            stats,
            trace: None,
            provenance,
        };
        Ok(Some(StreamRecord::from_staged(
            field, rank, step, gen_micros, &shape, payload, meta,
        )))
    }
}

/// Crop the last axis of a row-major array to `[lo, hi)`.
pub fn crop_last_axis(
    shape: &[u32],
    data: &[f32],
    lo: u32,
    hi: u32,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let Some(&w) = shape.last() else {
        bail!("roi: record has no shape");
    };
    ensure!(
        lo < hi && hi <= w,
        "roi {lo}:{hi} out of bounds for last axis {w}"
    );
    let (lo, hi, w) = (lo as usize, hi as usize, w as usize);
    let rows = data.len() / w;
    let mut out = Vec::with_capacity(rows * (hi - lo));
    for r in 0..rows {
        out.extend_from_slice(&data[r * w + lo..r * w + hi]);
    }
    let mut new_shape = shape.to_vec();
    *new_shape.last_mut().unwrap() = (hi - lo) as u32;
    Ok((new_shape, out))
}

/// Block-mean downsample along the last axis by factor `k`; a trailing
/// partial block averages the elements it has.
pub fn block_mean_last_axis(
    shape: &[u32],
    data: &[f32],
    k: usize,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let (shape, data, _) = block_mean_last_axis_with_residual(shape, data, k)?;
    Ok((shape, data))
}

/// [`block_mean_last_axis`], also returning the measured max-abs
/// residual `max |v − mean(block of v)|` over every element — the true
/// error a consumer reading the block mean in place of the original
/// values pays.  Exact: the residual is measured against the f32 block
/// mean the decoder will actually see, not the f64 accumulator.
pub fn block_mean_last_axis_with_residual(
    shape: &[u32],
    data: &[f32],
    k: usize,
) -> Result<(Vec<u32>, Vec<f32>, f32)> {
    ensure!(k >= 1, "aggregate factor must be >= 1");
    let Some(&w) = shape.last() else {
        bail!("aggregate: record has no shape");
    };
    let w = w as usize;
    ensure!(w > 0, "aggregate: empty last axis");
    let out_w = w.div_ceil(k);
    let rows = data.len() / w;
    let mut out = Vec::with_capacity(rows * out_w);
    let mut residual = 0.0f32;
    for r in 0..rows {
        let row = &data[r * w..(r + 1) * w];
        for b in 0..out_w {
            let start = b * k;
            let end = (start + k).min(w);
            let mut sum = 0f64;
            for &v in &row[start..end] {
                sum += v as f64;
            }
            let mean = (sum / (end - start) as f64) as f32;
            for &v in &row[start..end] {
                let e = (v - mean).abs();
                if e > residual {
                    residual = e;
                }
            }
            out.push(mean);
        }
    }
    let mut new_shape = shape.to_vec();
    *new_shape.last_mut().unwrap() = out_w as u32;
    Ok((new_shape, out, residual))
}

/// Min / max / mean of a field (the sidecar stats).
pub fn field_stats(data: &[f32]) -> FieldStats {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    let mut sum = 0f64;
    for &v in data {
        if v < min {
            min = v;
        }
        if v > max {
            max = v;
        }
        sum += v as f64;
    }
    if data.is_empty() {
        return FieldStats { min: 0.0, max: 0.0, mean: 0.0 };
    }
    FieldStats {
        min,
        max,
        mean: (sum / data.len() as f64) as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline(cfg: StagesConfig) -> StagePipeline {
        StagePipeline::new(cfg, Arc::new(StageMetrics::new())).unwrap()
    }

    fn smooth(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.05).sin()).collect()
    }

    #[test]
    fn passthrough_emits_v1_records() {
        let p = StagePipeline::passthrough();
        assert!(p.is_passthrough());
        let rec = p
            .apply("u", 0, 7, 0, 0, &[4], &[1.0, 2.0, 3.0, 4.0])
            .unwrap()
            .unwrap();
        assert!(rec.meta.is_none());
        assert_eq!(rec.payload_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn decimation_keeps_every_nth() {
        let m = Arc::new(StageMetrics::new());
        let p = StagePipeline::new(
            StagesConfig { decimate: 3, ..Default::default() },
            m.clone(),
        )
        .unwrap();
        let data = smooth(8);
        let kept: Vec<u64> = (0..9u64)
            .filter(|&seq| {
                p.apply("u", 0, seq, seq, 0, &[8], &data).unwrap().is_some()
            })
            .collect();
        assert_eq!(kept, vec![0, 3, 6]);
        assert_eq!(m.records_in.get(), 9);
        assert_eq!(m.records_filtered.get(), 6);
    }

    #[test]
    fn rank_subsetting_drops_odd_ranks() {
        let p = pipeline(StagesConfig { rank_stride: 2, ..Default::default() });
        assert!(p.admits_rank(0) && !p.admits_rank(1) && p.admits_rank(2));
        let data = smooth(4);
        assert!(p.apply("u", 1, 0, 0, 0, &[4], &data).unwrap().is_none());
        assert!(p.apply("u", 2, 0, 0, 0, &[4], &data).unwrap().is_some());
    }

    #[test]
    fn roi_crops_last_axis() {
        let p = pipeline(StagesConfig { roi: Some((2, 6)), ..Default::default() });
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let rec = p.apply("u", 0, 0, 0, 0, &[2, 8], &data).unwrap().unwrap();
        assert_eq!(rec.shape, vec![2, 4]);
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        assert_eq!(
            back.payload_f32().unwrap(),
            vec![2., 3., 4., 5., 10., 11., 12., 13.]
        );
        // out-of-bounds roi is an error
        let bad = pipeline(StagesConfig { roi: Some((2, 9)), ..Default::default() });
        assert!(bad.apply("u", 0, 0, 0, 0, &[2, 8], &data).is_err());
    }

    #[test]
    fn aggregate_block_means_and_carries_stats() {
        let p = pipeline(StagesConfig { aggregate: 2, ..Default::default() });
        let data = vec![1.0f32, 3.0, 5.0, 7.0, 2.0, 4.0, 6.0, 8.0];
        let rec = p.apply("u", 0, 0, 0, 0, &[2, 4], &data).unwrap().unwrap();
        assert_eq!(rec.shape, vec![2, 2]);
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back.payload_f32().unwrap(), vec![2.0, 6.0, 3.0, 7.0]);
        let stats = back.meta.unwrap().stats.unwrap();
        assert_eq!(stats.min, 2.0);
        assert_eq!(stats.max, 7.0);
        assert!((stats.mean - 4.5).abs() < 1e-6);
    }

    /// ISSUE 8 bugfix regression: an aggregated frame is *lossy* at
    /// element granularity even with `convert=f32`, and its header must
    /// say so — `err_bound > 0`, and the actual per-element error of
    /// the decoded (block-mean) values vs the original field stays
    /// within the stated bound.
    #[test]
    fn aggregate_residual_is_folded_into_err_bound() {
        for convert in [Encoding::F32, Encoding::F16, Encoding::QDelta] {
            let p = pipeline(StagesConfig {
                aggregate: 4,
                convert,
                qdelta_step: 1e-3,
                ..Default::default()
            });
            let data = smooth(256);
            let rec = p.apply("u", 0, 0, 0, 0, &[256], &data).unwrap().unwrap();
            let bound = rec.meta.as_ref().unwrap().err_bound;
            assert!(
                bound > 0.0,
                "{convert:?}: aggregate=4 frame shipped err_bound=0 (the bug)"
            );
            // decoded block means, expanded back to element granularity
            let back = StreamRecord::decode(&rec.encode()).unwrap();
            let means = back.payload_f32().unwrap();
            for (i, &v) in data.iter().enumerate() {
                let m = means[i / 4];
                assert!(
                    (v - m).abs() <= bound + 1e-6,
                    "{convert:?}: element {i}: |{v} - {m}| over bound {bound}"
                );
            }
        }
    }

    /// A constant field block-means losslessly: the measured residual —
    /// and so the bound — must stay 0 instead of some worst-case guess.
    #[test]
    fn aggregate_of_constant_field_keeps_zero_bound() {
        let p = pipeline(StagesConfig { aggregate: 4, ..Default::default() });
        let data = vec![2.5f32; 64];
        let rec = p.apply("u", 0, 0, 0, 0, &[64], &data).unwrap().unwrap();
        assert_eq!(rec.meta.unwrap().err_bound, 0.0);
    }

    /// ISSUE 8: the adapt controller's level/epoch tag rides the frame
    /// provenance — appended after the config provenance, and forcing a
    /// staged `EBR2` frame even for passthrough configs so the tag
    /// survives the wire, the WAL and replay.
    #[test]
    fn provenance_tag_is_appended_and_survives_decode() {
        let p = pipeline(StagesConfig { convert: Encoding::F16, ..Default::default() });
        let data = smooth(32);
        let rec = p
            .apply_tagged("u", 0, 0, 0, 0, &[32], &data, Some("lvl:1@3"))
            .unwrap()
            .unwrap();
        let prov = StreamRecord::decode(&rec.encode())
            .unwrap()
            .meta
            .unwrap()
            .provenance;
        assert_eq!(prov, "f16|lvl:1@3");

        // passthrough + tag: still an EBR2 frame, provenance = tag alone
        let p = StagePipeline::passthrough();
        let rec = p
            .apply_tagged("u", 0, 0, 0, 0, &[32], &data, Some("lvl:0@0"))
            .unwrap()
            .unwrap();
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back.meta.unwrap().provenance, "lvl:0@0");
        assert_eq!(back.payload_f32().unwrap(), data, "payload bit-exact");
        // untagged passthrough keeps shipping classic EBR1
        let rec = p.apply("u", 0, 0, 0, 0, &[32], &data).unwrap().unwrap();
        assert!(rec.meta.is_none());
    }

    #[test]
    fn aggregate_partial_tail_block() {
        let (shape, data) =
            block_mean_last_axis(&[5], &[1.0, 2.0, 3.0, 4.0, 10.0], 2).unwrap();
        assert_eq!(shape, vec![3]);
        assert_eq!(data, vec![1.5, 3.5, 10.0]);
    }

    #[test]
    fn lossless_codec_roundtrips_bit_exact() {
        let m = Arc::new(StageMetrics::new());
        let p = StagePipeline::new(
            StagesConfig { codec: CodecKind::ShuffleLz, ..Default::default() },
            m.clone(),
        )
        .unwrap();
        let data = smooth(512);
        let rec = p.apply("u", 0, 3, 0, 0, &[512], &data).unwrap().unwrap();
        let meta = rec.meta.as_ref().unwrap();
        assert_eq!(meta.err_bound, 0.0);
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        let got = back.payload_f32().unwrap();
        assert_eq!(got.len(), data.len());
        for (a, b) in got.iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits(), "lossless bits changed");
        }
        assert!(m.bytes_out.get() < m.bytes_in.get(), "smooth field must shrink");
        assert!(m.reduction_factor() > 1.0);
    }

    #[test]
    fn lossy_roundtrip_within_stated_bound() {
        for convert in [Encoding::F16, Encoding::QDelta] {
            let p = pipeline(StagesConfig {
                convert,
                qdelta_step: 1e-3,
                codec: CodecKind::ShuffleLz,
                ..Default::default()
            });
            let data = smooth(256);
            let rec = p.apply("u", 0, 0, 0, 0, &[256], &data).unwrap().unwrap();
            let bound = rec.meta.as_ref().unwrap().err_bound;
            let back = StreamRecord::decode(&rec.encode()).unwrap();
            for (a, b) in back.payload_f32().unwrap().iter().zip(&data) {
                assert!(
                    (a - b).abs() <= bound + 1e-12,
                    "{convert:?}: {b} → {a} over bound {bound}"
                );
            }
        }
    }

    #[test]
    fn incompressible_frame_falls_back_to_uncompressed() {
        let p = pipeline(StagesConfig { codec: CodecKind::ShuffleLz, ..Default::default() });
        // white noise: the LZ pass cannot win; the frame must ship
        // uncompressed rather than grown
        let mut rng = crate::util::rng::Rng::new(3);
        let data: Vec<f32> =
            (0..256).map(|_| f32::from_bits(rng.next_below(u32::MAX as u64) as u32)).collect();
        let data: Vec<f32> = data
            .into_iter()
            .map(|v| if v.is_finite() { v } else { 0.0 })
            .collect();
        let rec = p.apply("u", 0, 0, 0, 0, &[256], &data).unwrap().unwrap();
        let meta = rec.meta.as_ref().unwrap();
        assert_eq!(meta.codec, CodecKind::None, "fallback should disable the codec");
        assert_eq!(rec.payload.len(), meta.raw_len as usize);
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        for (a, b) in back.payload_f32().unwrap().iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn stages_compose_and_provenance_records_them() {
        let p = pipeline(StagesConfig {
            decimate: 2,
            roi: Some((0, 8)),
            aggregate: 2,
            convert: Encoding::F16,
            codec: CodecKind::ShuffleLz,
            ..Default::default()
        });
        let data = smooth(32);
        let rec = p.apply("u", 0, 0, 0, 0, &[2, 16], &data).unwrap().unwrap();
        assert_eq!(rec.shape, vec![2, 4]); // 16 → roi 8 → agg 4
        let prov = rec.meta.as_ref().unwrap().provenance.clone();
        assert!(prov.contains("decim:2"), "{prov}");
        assert!(prov.contains("roi:0:8"), "{prov}");
        assert!(prov.contains("agg:2"), "{prov}");
        assert!(prov.contains("f16"), "{prov}");
        // odd write sequence numbers are decimated away
        assert!(p.apply("u", 0, 1, 1, 0, &[2, 16], &data).unwrap().is_none());
    }

    /// ISSUE 6 satellite: the folded-in value transforms are part of
    /// the stage byte accounting — a stride-16 reduction shows up in
    /// `bytes_in`/`bytes_out` instead of silently evading it.
    #[test]
    fn transforms_count_in_byte_accounting() {
        let m = Arc::new(StageMetrics::new());
        let p = StagePipeline::new(
            StagesConfig {
                transforms: vec![FilterStage::Stride(16)],
                ..Default::default()
            },
            m.clone(),
        )
        .unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let rec = p.apply("u", 0, 0, 0, 0, &[64], &data).unwrap().unwrap();
        assert_eq!(
            rec.payload_f32().unwrap(),
            vec![0.0, 16.0, 32.0, 48.0],
            "stride-16 keeps every 16th element"
        );
        assert_eq!(m.bytes_in.get(), 64 * 4, "pre-transform bytes counted");
        assert_eq!(m.bytes_out.get(), 4 * 4, "post-transform bytes counted");
        assert!((m.reduction_factor() - 16.0).abs() < 1e-9);
        let prov = rec.meta.unwrap().provenance;
        assert!(prov.contains("xstride:16"), "{prov}");
    }

    /// Transforms compose with the downstream stages in order
    /// (transform → ROI → aggregate), matching the legacy
    /// filter-then-stages pipeline.
    #[test]
    fn transforms_compose_with_roi_and_aggregate() {
        let p = pipeline(StagesConfig {
            transforms: vec![FilterStage::Magnitude, FilterStage::Clamp(0.0, 10.0)],
            roi: Some((0, 4)),
            aggregate: 2,
            ..Default::default()
        });
        // ux = 3,0,8,0,0,0,0,0 ; uy = 4,1,6,0,0,0,0,0 → magnitude
        // [5,1,10,0,0,0,0,0] (clamp is a no-op here) → roi [5,1,10,0]
        // → agg2 [3,5]
        let mut data = vec![0.0f32; 16];
        (data[0], data[1], data[2]) = (3.0, 0.0, 8.0);
        (data[8], data[9], data[10]) = (4.0, 1.0, 6.0);
        let rec = p.apply("u", 0, 0, 0, 0, &[2, 8], &data).unwrap().unwrap();
        assert_eq!(rec.shape, vec![2]);
        let got = StreamRecord::decode(&rec.encode())
            .unwrap()
            .payload_f32()
            .unwrap();
        assert_eq!(got, vec![3.0, 5.0]);
        assert!(StagesConfig {
            transforms: vec![FilterStage::Stride(0)],
            ..Default::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(StagesConfig { decimate: 0, ..Default::default() }.validate().is_err());
        assert!(StagesConfig { rank_stride: 0, ..Default::default() }.validate().is_err());
        assert!(StagesConfig { aggregate: 0, ..Default::default() }.validate().is_err());
        assert!(StagesConfig { roi: Some((4, 4)), ..Default::default() }.validate().is_err());
        assert!(StagesConfig {
            convert: Encoding::QDelta,
            qdelta_step: 0.0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert_eq!(StagesConfig::parse_roi("8:120").unwrap(), (8, 120));
        assert!(StagesConfig::parse_roi("120").is_err());
        assert!(StagesConfig::parse_roi("9:3").is_err());
    }
}
