//! The epoch-fenced shipping protocol — what a broker writer thread
//! runs between "batch drained from the queue" and "every record
//! acknowledged by the right endpoint" (ISSUE 3 tentpole).
//!
//! A [`Shipper`] owns one stream's relationship with the elastic
//! topology:
//!
//! * **Registration.**  Before shipping anything it sends
//!   `HELLO <key> <epoch>` to the endpoint its group is currently
//!   assigned to.  The endpoint fences the stream at that epoch and
//!   reports the resume point.
//! * **Migration (batch-boundary).**  At every [`ship`] it compares the
//!   topology epoch (one atomic load) with its own; if the topology
//!   moved its group, it writes an `XHANDOFF` tombstone to the old
//!   endpoint (best effort — the old endpoint may be dead; readers
//!   fall back to the topology), dials the new endpoint and re-HELLOs
//!   at the new epoch.  Migration happens *between* batches, so there
//!   is never an in-flight frame to lose.
//! * **Recovery (mid-batch).**  A transport failure mid-frame leaves
//!   records landed-but-unacked.  The shipper reconnects (or follows
//!   the topology if it moved meanwhile), re-registers with `HELLO`,
//!   and re-ships the *whole* pending frame: the endpoint's step
//!   dedupe answers `DUP` for records that already landed, so nothing
//!   is stored twice and nothing is dropped — exactly-once, with
//!   stream order preserved.
//! * **Fencing.**  A `STALE` reply means a successor registered at a
//!   higher epoch (this writer was migrated away and didn't notice, or
//!   is a zombie after a takeover).  The shipper re-reads the topology
//!   and re-registers at the current epoch; if the topology itself has
//!   no newer epoch to offer, it surfaces a hard error instead of
//!   fighting the fence.
//! * **Backpressure.**  `OOM` replies keep the existing partial-retry
//!   behaviour: only the rejected records are retried, in order, with
//!   a single-record probe while backing off, so a wedged endpoint
//!   costs one record per tick, not the whole batch.
//! * **Replication stalls (ISSUE 10).**  A `REPL` reply means the
//!   chain head stored the record but could not reach its successor
//!   under tail-ack — the record is *not yet durable chain-wide*, so
//!   the shipper retries the rejected records on a short tick (the
//!   head answers `DUP` and re-forwards) and follows any topology
//!   epoch bump, which is how a failover promotion reroutes it to the
//!   surviving replica.
//! * **Restarted endpoints (ISSUE 4).**  Reconnecting to an endpoint
//!   that crashed and recovered from its WAL is just the recovery path:
//!   `HELLO` reports the replayed high-water mark and the re-shipped
//!   frame dedupes against it.  The shipper additionally compares that
//!   mark with the highest step it was ever *acked* for on this
//!   endpoint — if the recovered mark is lower, the endpoint restarted
//!   from a stale log (fsync policy looser than `always`) and acked
//!   records are gone for good; the loss is counted in the
//!   `replay_gaps` metric and logged, since no re-ship can mend it
//!   (the records were dropped from the queue at ack time).
//!
//! [`ship`]: Shipper::ship

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::topology::TopologyHandle;
use crate::metrics::obs::json_escape;
use crate::metrics::{EndpointStats, WorkflowMetrics};
use crate::record::{StreamRecord, Trace};
use crate::transport::{Conn, Dialer, Request};
use crate::util;
use crate::wire::Value;

/// One stream's epoch-fenced connection to the elastic topology.
pub struct Shipper {
    key: String,
    group: usize,
    topology: TopologyHandle,
    dialer: Arc<dyn Dialer>,
    conn: Option<Box<dyn Conn>>,
    /// Endpoint slot the connection points at.
    endpoint: usize,
    /// Epoch we last registered at (HELLO'd).
    epoch: u64,
    /// Whether we ever completed a registration (migrations are only
    /// counted after the first one).
    registered: bool,
    /// Highest step the *current endpoint* acknowledged (stored or
    /// deduped) for this stream's current segment — the bar a restarted
    /// endpoint's recovered high-water mark is measured against.
    /// Reset on migration (a fresh endpoint starts a fresh segment).
    acked_step: Option<u64>,
    metrics: WorkflowMetrics,
    stats: Arc<EndpointStats>,
    /// Recovery attempts per failure before giving up.
    max_recover: u32,
}

impl Shipper {
    /// Resolve the group's current endpoint, dial it and register the
    /// stream (`HELLO`).  Fails if the endpoint is unreachable after
    /// the recovery budget.
    pub fn register(
        key: String,
        group: usize,
        topology: TopologyHandle,
        dialer: Arc<dyn Dialer>,
        metrics: WorkflowMetrics,
        max_recover: u32,
    ) -> Result<Shipper> {
        // Resolve the route up front: validates the group and pins the
        // QoS slot to the endpoint we are actually about to dial, so
        // initial-connect failures charge the right endpoint.
        let (ep0, _) = topology.route(group)?;
        let stats = metrics.qos.slot(ep0);
        let mut shipper = Shipper {
            key,
            group,
            topology,
            dialer,
            conn: None,
            endpoint: usize::MAX, // forces the first sync to dial
            epoch: 0,
            registered: false,
            acked_step: None,
            metrics,
            stats,
            max_recover,
        };
        if shipper.ensure_registered(false).is_err() {
            shipper.recover()?;
        }
        Ok(shipper)
    }

    /// Endpoint slot currently shipped to.
    pub fn endpoint(&self) -> usize {
        self.endpoint
    }

    /// Epoch currently registered at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// QoS stats slot of the current endpoint (writer loops record
    /// per-endpoint flush latency / queue depth here).
    pub fn qos(&self) -> &Arc<EndpointStats> {
        &self.stats
    }

    /// Bring connection + registration in line with the current
    /// topology.  `reconnect` forces a transport-level reconnect when
    /// the endpoint did not change (the recovery path).
    fn ensure_registered(&mut self, reconnect: bool) -> Result<()> {
        let (ep, epoch) = self.topology.route(self.group)?;
        let moving = ep != self.endpoint;
        // Gap detection only makes sense when re-registering with the
        // SAME endpoint (recovery): after a migration the new endpoint
        // legitimately starts a fresh segment with no high-water mark.
        let check_gap = self.registered && !moving;
        if moving || self.conn.is_none() {
            if moving && self.conn.is_some() {
                // Graceful handoff: tombstone the old endpoint's segment
                // (naming the destination slot) so readers follow the
                // hop chain without consulting the topology.  Best
                // effort — a dead endpoint just loses the hint.
                let req = Request::new("XHANDOFF")
                    .arg(self.key.as_bytes())
                    .arg(epoch.to_string())
                    .arg(ep.to_string());
                match self.conn.as_mut().unwrap().exchange(std::slice::from_ref(&req)) {
                    Ok(replies) if matches!(replies.first(), Some(r) if !r.is_error()) => {
                        self.metrics.handoffs.inc();
                    }
                    _ => log::debug!(
                        "shipper {}: old endpoint {} unreachable for handoff tombstone",
                        self.key,
                        self.endpoint
                    ),
                }
            }
            self.conn = Some(self.dialer.dial(ep)?);
            if self.registered && moving {
                self.metrics.migrations.inc();
                self.metrics.events.emit(
                    "broker.migrate",
                    format!(
                        "{{\"stream\":\"{}\",\"from\":{},\"to\":{ep},\"epoch\":{epoch}}}",
                        json_escape(&self.key),
                        self.endpoint
                    ),
                );
                log::debug!(
                    "shipper {}: migrated endpoint {} -> {ep} (epoch {epoch})",
                    self.key,
                    self.endpoint
                );
            }
            self.endpoint = ep;
            self.stats = self.metrics.qos.slot(ep);
            // Fresh endpoint = fresh segment: the old endpoint's acked
            // bar does not apply here.
            self.acked_step = None;
        } else if reconnect {
            self.conn.as_mut().unwrap().reconnect()?;
        }
        self.epoch = epoch;
        self.hello(check_gap)
    }

    /// `HELLO <key> <epoch>` on the current connection.  With
    /// `check_replay_gap`, compare the endpoint's reported high-water
    /// mark against the highest step it ever acked us for — a lower
    /// mark means the endpoint restarted from a stale WAL and acked
    /// records are unrecoverable (counted in `replay_gaps`).
    fn hello(&mut self, check_replay_gap: bool) -> Result<()> {
        let req = Request::new("HELLO")
            .arg(self.key.as_bytes())
            .arg(self.epoch.to_string());
        let replies = self
            .conn
            .as_mut()
            .unwrap()
            .exchange(std::slice::from_ref(&req))?;
        let reply = replies.first().context("empty HELLO reply")?;
        if reply.is_error() {
            let msg = reply.as_str_lossy();
            if msg.starts_with("STALE") {
                self.metrics.stale_rejections.inc();
                self.metrics.events.emit(
                    "fence.stale",
                    format!(
                        "{{\"stream\":\"{}\",\"epoch\":{},\"at\":\"hello\",\
                         \"endpoint\":{}}}",
                        json_escape(&self.key),
                        self.epoch,
                        self.endpoint
                    ),
                );
            }
            bail!("HELLO {} epoch {} rejected: {msg}", self.key, self.epoch);
        }
        if check_replay_gap {
            if let (Some(mine), Some(parts)) = (self.acked_step, reply.as_array()) {
                let endpoint_step = match parts.get(1) {
                    Some(Value::Int(s)) => Some(*s as u64),
                    _ => None,
                };
                if endpoint_step.map_or(true, |s| s < mine) {
                    self.metrics.replay_gaps.inc();
                    log::warn!(
                        "shipper {}: endpoint {} recovered with step {:?} below \
                         our acked step {mine} — it restarted from a stale WAL; \
                         the acked records in between are unrecoverable",
                        self.key,
                        self.endpoint,
                        endpoint_step
                    );
                }
            }
        }
        self.registered = true;
        Ok(())
    }

    /// Recover after a failure: follow the topology (it may have moved
    /// us off a dead endpoint), reconnect, re-register.  Bounded; never
    /// sleeps itself (TCP reconnects back off inside the transport).
    fn recover(&mut self) -> Result<()> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..self.max_recover.max(1) {
            self.metrics.reconnects.inc();
            // Charge reconnect pressure to the endpoint this attempt
            // actually targets (the current route), not a stale slot.
            let target = match self.topology.route(self.group) {
                Ok((ep, _)) => ep,
                Err(_) if self.endpoint != usize::MAX => self.endpoint,
                Err(_) => 0,
            };
            self.metrics.qos.slot(target).reconnects.inc();
            self.metrics.events.emit(
                "conn.reconnect",
                format!(
                    "{{\"stream\":\"{}\",\"endpoint\":{target},\"attempt\":{}}}",
                    json_escape(&self.key),
                    attempt + 1
                ),
            );
            match self.ensure_registered(true) {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap()).with_context(|| {
            format!(
                "shipper {}: gave up after {} recovery attempts",
                self.key,
                self.max_recover.max(1)
            )
        })
    }

    /// Ship one drained batch, surviving migration, transport failure
    /// and endpoint backpressure.  Returns only when every record has
    /// been acknowledged (stored or deduplicated) by the endpoint the
    /// topology currently assigns — or with an error once the recovery
    /// / backoff budgets are spent.
    pub fn ship(&mut self, records: &[StreamRecord]) -> Result<()> {
        const OOM_RETRY_EVERY: Duration = Duration::from_millis(25);
        const OOM_RETRY_LIMIT: u32 = 1200; // 30 s of patience
        const REPL_RETRY_EVERY: Duration = Duration::from_millis(5);
        const REPL_RETRY_LIMIT: u32 = 2000; // 10 s for the chain to heal

        if records.is_empty() {
            return Ok(());
        }
        // Batch-boundary migration check: one atomic load when nothing
        // changed.
        if self.topology.epoch() != self.epoch && self.ensure_registered(false).is_err() {
            self.recover()?;
        }
        // Requests are built exactly once — each encoded payload moves
        // straight into its frame, no per-attempt clone.  Re-registration
        // only rewrites the small epoch argument (part index 2) in
        // place; an OOM-inversion retry inserts a FORCE flag.
        let mut built_epoch = self.epoch;
        let mut reqs: Vec<Request> = Vec::with_capacity(records.len());
        let mut lens: Vec<usize> = Vec::with_capacity(records.len());
        let mut steps: Vec<u64> = Vec::with_capacity(records.len());
        let mut forced: Vec<bool> = vec![false; records.len()];
        // Trace stamps of sampled records, parallel to `reqs` (ISSUE 9);
        // `None` for the unsampled majority.
        let mut traces: Vec<Option<Trace>> = Vec::with_capacity(records.len());
        for r in records {
            // Sampled records get their flush hop stamped at encode time
            // — the stamp must ride the frame, so re-encode a (cheap,
            // payload-shared) clone with the updated trace.
            let trace = r.meta.as_ref().and_then(|m| m.trace).map(|mut t| {
                t.flush_us = util::epoch_micros();
                self.metrics
                    .trace
                    .hop_queue_us
                    .record(t.flush_us.saturating_sub(t.enqueue_us));
                t
            });
            let payload = match trace {
                None => r.encode(),
                Some(t) => {
                    let mut rec = r.clone();
                    rec.meta.as_mut().unwrap().trace = Some(t);
                    rec.encode()
                }
            };
            traces.push(trace);
            lens.push(payload.len());
            steps.push(r.step);
            reqs.push(
                Request::new("XADDF")
                    .arg(self.key.as_bytes())
                    .arg(self.epoch.to_string())
                    .arg(r.step.to_string())
                    .arg("r")
                    .arg(payload),
            );
        }
        let mut oom_attempts = 0u32;
        let mut repl_attempts = 0u32;
        while !reqs.is_empty() {
            if built_epoch != self.epoch {
                for req in reqs.iter_mut() {
                    req.set_arg(2, self.epoch.to_string());
                }
                built_epoch = self.epoch;
            }
            // While backing off from OOM, probe with a single record
            // instead of re-pipelining the whole doomed batch.
            let send = if oom_attempts == 0 { reqs.len() } else { 1 };
            let replies = match self.conn.as_mut().unwrap().exchange(&reqs[..send]) {
                Ok(r) => r,
                Err(e) => {
                    log::debug!("shipper {}: frame failed ({e:#}); recovering", self.key);
                    self.recover()?;
                    // Re-ship the whole pending frame: the endpoint's
                    // step dedupe answers DUP for anything that landed
                    // in the broken frame, so this cannot double-store.
                    continue;
                }
            };
            let mut failed = vec![false; send];
            let mut oomed = vec![false; send];
            let mut n_oom = 0usize;
            let mut n_dup = 0usize;
            let mut n_repl = 0usize;
            let mut stale = false;
            let mut last_ok: Option<usize> = None;
            for (i, reply) in replies.iter().enumerate() {
                match reply {
                    Value::Error(msg) if msg.starts_with("OOM") => {
                        failed[i] = true;
                        oomed[i] = true;
                        n_oom += 1;
                    }
                    Value::Error(msg) if msg.starts_with("STALE") => {
                        failed[i] = true;
                        stale = true;
                    }
                    // Chain head stored the record but could not reach
                    // its successor under tail-ack (ISSUE 10): not yet
                    // durable chain-wide, so retry — the head dedupes
                    // (DUP) and re-forwards until the chain heals or a
                    // failover epoch bump reroutes us.
                    Value::Error(msg) if msg.starts_with("REPL") => {
                        failed[i] = true;
                        n_repl += 1;
                    }
                    Value::Error(msg) => bail!("endpoint rejected XADDF: {msg}"),
                    // Bulk id (stored) or +DUP (landed in an earlier
                    // unacked frame) — either way the record is durable.
                    reply => {
                        if matches!(reply, Value::Simple(s) if s == "DUP") {
                            n_dup += 1;
                        }
                        self.metrics.shipped.record(lens[i] as u64);
                        if let Some(t) = traces[i] {
                            self.metrics.trace.hop_ack_us.record(
                                util::epoch_micros().saturating_sub(t.flush_us),
                            );
                        }
                        self.acked_step = Some(
                            self.acked_step
                                .map_or(steps[i], |a| a.max(steps[i])),
                        );
                        last_ok = Some(i);
                    }
                }
            }
            if n_dup > 0 {
                // A re-shipped frame hit the server-side step dedupe —
                // exactly-once held; the journal keeps the evidence.
                self.metrics.events.emit(
                    "fence.dup",
                    format!(
                        "{{\"stream\":\"{}\",\"endpoint\":{},\"deduped\":{n_dup}}}",
                        json_escape(&self.key),
                        self.endpoint
                    ),
                );
            }
            // OOM inversion: a later record of this frame landed while
            // an earlier one was explicitly rejected, so the stream's
            // step watermark now lies about the rejected record.  Its
            // retry must FORCE past the server-side dedupe or it would
            // be swallowed as a DUP and silently lost.  It lands late
            // (out of step order — same as the pre-elastic behaviour;
            // readers' step dedupe skips it at delivery).
            if let Some(hi) = last_ok {
                let mut inverted = 0usize;
                for i in 0..hi {
                    if oomed[i] && !forced[i] {
                        reqs[i].insert_arg(4, "FORCE");
                        forced[i] = true;
                        inverted += 1;
                    }
                }
                if inverted > 0 {
                    log::warn!(
                        "shipper {}: {inverted} record(s) OOM'd behind a landed \
                         successor; retrying with FORCE (will arrive out of order)",
                        self.key
                    );
                }
            }
            if stale {
                // Fenced out: a successor registered at a higher epoch.
                self.metrics.stale_rejections.inc();
                self.metrics.events.emit(
                    "fence.stale",
                    format!(
                        "{{\"stream\":\"{}\",\"epoch\":{},\"at\":\"xaddf\",\
                         \"endpoint\":{}}}",
                        json_escape(&self.key),
                        self.epoch,
                        self.endpoint
                    ),
                );
                if self.topology.epoch() > self.epoch {
                    // A migration we hadn't noticed: follow it and
                    // re-ship the rejected records at the new epoch.
                    if self.ensure_registered(false).is_err() {
                        self.recover()?;
                    }
                } else {
                    bail!(
                        "shipper {}: stream fenced above our epoch {} but the \
                         topology has nothing newer (zombie writer?)",
                        self.key,
                        self.epoch
                    );
                }
            }
            if n_repl > 0 {
                repl_attempts += 1;
                anyhow::ensure!(
                    repl_attempts <= REPL_RETRY_LIMIT,
                    "endpoint {} cannot replicate {} to its chain successor for \
                     more than {:?}",
                    self.endpoint,
                    self.key,
                    REPL_RETRY_EVERY * REPL_RETRY_LIMIT
                );
                self.metrics.repl_blocked.inc();
                if repl_attempts == 1 {
                    self.metrics.events.emit(
                        "repl.blocked",
                        format!(
                            "{{\"stream\":\"{}\",\"endpoint\":{},\"records\":{n_repl}}}",
                            json_escape(&self.key),
                            self.endpoint
                        ),
                    );
                    log::warn!(
                        "shipper {}: endpoint {} cannot reach its chain successor \
                         on {n_repl}/{send} records; retrying",
                        self.key,
                        self.endpoint
                    );
                }
                // A failover may already have rerouted the chain: pick
                // up the new head instead of hammering the broken one.
                if self.topology.epoch() != self.epoch
                    && self.ensure_registered(false).is_err()
                {
                    self.recover()?;
                }
                std::thread::sleep(REPL_RETRY_EVERY);
            } else {
                repl_attempts = 0;
            }
            if n_oom > 0 {
                oom_attempts += 1;
                anyhow::ensure!(
                    oom_attempts <= OOM_RETRY_LIMIT,
                    "endpoint {} OOM for more than {:?} without progress",
                    self.endpoint,
                    OOM_RETRY_EVERY * OOM_RETRY_LIMIT
                );
                if oom_attempts == 1 {
                    log::warn!(
                        "shipper {}: endpoint {} OOM on {n_oom}/{send} records; backing off",
                        self.key,
                        self.endpoint
                    );
                }
                std::thread::sleep(OOM_RETRY_EVERY);
            } else {
                oom_attempts = 0; // progress: next attempt batches again
            }
            // Keep this attempt's rejected records (in order) plus the
            // not-yet-attempted tail.
            let mut i = 0;
            reqs.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
            let mut i = 0;
            lens.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
            let mut i = 0;
            steps.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
            let mut i = 0;
            forced.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
            let mut i = 0;
            traces.retain(|_| {
                let keep = i >= send || failed[i];
                i += 1;
                keep
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::groups::GroupMap;
    use crate::broker::rebalancer::{self, EndpointSample, QosThresholds};
    use crate::endpoint::{EntryId, StoreConfig};
    use crate::transport::sim::{FaultSchedule, SimDialer, SimNet};
    use crate::util::prop::{self, U64Range};
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    fn rec(step: u64) -> StreamRecord {
        StreamRecord::from_f32("u", 0, step, 0, &[1], &[step as f32]).unwrap()
    }

    fn one_rank_rig(
        net: &Arc<SimNet>,
        metrics: &WorkflowMetrics,
    ) -> (TopologyHandle, Shipper) {
        let dummy: std::net::SocketAddr = "127.0.0.1:1".parse().unwrap();
        let topology =
            TopologyHandle::new_static(GroupMap::new(1, 1, 1).unwrap(), vec![dummy])
                .unwrap();
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let shipper = Shipper::register(
            "u/0".into(),
            0,
            topology.clone(),
            dialer,
            metrics.clone(),
            8,
        )
        .unwrap();
        (topology, shipper)
    }

    /// ISSUE 4: reconnecting to an endpoint that crashed and recovered
    /// from its (fsync=always) WAL is loss-free — exactly-once resumes
    /// through the replayed high-water mark, no replay gap counted.
    #[test]
    fn crash_restart_with_wal_resumes_exactly_once() {
        let dir = std::env::temp_dir().join(format!(
            "eb-ship-crash-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let net = SimNet::new();
        let e = net.add_endpoint(StoreConfig {
            wal: Some(crate::endpoint::WalConfig {
                dir: dir.clone(),
                fsync: crate::endpoint::FsyncPolicy::Always,
                segment_bytes: 1 << 20,
            }),
            ..Default::default()
        });
        let metrics = WorkflowMetrics::new();
        let (_topology, mut shipper) = one_rank_rig(&net, &metrics);
        shipper.ship(&[rec(0), rec(1)]).unwrap();
        // crash mid-batch: 1 of 2 records lands (and is logged), the
        // endpoint restarts from its WAL before the shipper reconnects
        net.inject(
            e,
            FaultSchedule {
                drop_after_frames: Some(0),
                partial_commands: 1,
                crash_on_drop: true,
                refuse_connects: 1,
                ..Default::default()
            },
        );
        shipper.ship(&[rec(2), rec(3)]).unwrap();
        // every step landed exactly once across the crash
        let mut seen = Vec::new();
        for entry in net.store(e).read_after("u/0", EntryId::ZERO, 0) {
            seen.push(StreamRecord::decode(&entry.fields[0].1).unwrap().step);
        }
        assert_eq!(seen, vec![0, 1, 2, 3], "exactly-once across the crash");
        assert_eq!(metrics.replay_gaps.get(), 0, "durable restart is loss-free");
        assert_eq!(net.store(e).fenced_last_step("u/0"), Some(3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE 4: an *in-memory* endpoint restarted after a crash lost
    /// acked records; the shipper's HELLO notices the stale high-water
    /// mark and counts the unrecoverable gap.
    #[test]
    fn stale_restart_without_wal_counts_replay_gap() {
        let net = SimNet::new();
        let e = net.add_endpoint(StoreConfig::default());
        let metrics = WorkflowMetrics::new();
        let (_topology, mut shipper) = one_rank_rig(&net, &metrics);
        shipper.ship(&[rec(0), rec(1)]).unwrap();
        net.inject(
            e,
            FaultSchedule {
                drop_after_frames: Some(0),
                partial_commands: 0,
                crash_on_drop: true,
                ..Default::default()
            },
        );
        shipper.ship(&[rec(2), rec(3)]).unwrap();
        assert_eq!(
            metrics.replay_gaps.get(),
            1,
            "stale restart must be detected"
        );
        // the wiped endpoint only has the post-crash records
        let mut seen = Vec::new();
        for entry in net.store(e).read_after("u/0", EntryId::ZERO, 0) {
            seen.push(StreamRecord::decode(&entry.fields[0].1).unwrap().step);
        }
        assert_eq!(seen, vec![2, 3], "acked pre-crash records are gone");
    }

    /// ISSUE 3 satellite: arbitrary sequences of endpoint add / drain /
    /// slowdown / fault events over random (ranks, groups, endpoints)
    /// topologies.  Invariants checked after every event and at the
    /// end:
    ///
    /// 1. every group is assigned to exactly one live endpoint at every
    ///    epoch (`Topology::validate`), and the epoch is monotonic;
    /// 2. replaying the migration protocol loses no record: the union
    ///    of all endpoint segments of a stream, tombstones excluded, is
    ///    exactly the written step set;
    /// 3. per-endpoint segments are strictly step-increasing (the
    ///    server-side dedupe keeps every segment exactly-once), so a
    ///    reader's step-level dedupe delivers each record exactly once.
    ///
    /// Deterministic: no sleeps, no sockets, no threads — writers are
    /// driven synchronously through `Shipper::ship` over `SimConn`.
    #[test]
    fn prop_rebalance_exactly_once() {
        prop::forall(0xE1A5, 60, &U64Range(0, u64::MAX - 1), |seed| {
            run_rebalance_case(*seed).map_err(|e| format!("{e:#}"))
        });
    }

    fn run_rebalance_case(seed: u64) -> Result<()> {
        let mut rng = Rng::new(seed);
        let ranks = 1 + rng.next_below(6) as usize;
        let gsize = 1 + rng.next_below(3) as usize;
        let n_eps = 1 + rng.next_below(3) as usize;

        let net = SimNet::new();
        for _ in 0..n_eps {
            net.add_endpoint(StoreConfig::default());
        }
        let dummy = || -> std::net::SocketAddr { "127.0.0.1:1".parse().unwrap() };
        let groups = GroupMap::new(ranks, gsize, n_eps)?;
        let topology = TopologyHandle::new_static(
            groups.clone(),
            (0..n_eps).map(|_| dummy()).collect(),
        )?;
        let dialer: Arc<dyn Dialer> = Arc::new(SimDialer::new(net.clone()));
        let metrics = WorkflowMetrics::new();

        let mut shippers: Vec<Shipper> = Vec::with_capacity(ranks);
        for r in 0..ranks {
            shippers.push(Shipper::register(
                crate::record::stream_key("u", r as u32),
                groups.group_of_rank(r)?,
                topology.clone(),
                dialer.clone(),
                metrics.clone(),
                8,
            )?);
        }
        let mut next_step = vec![0u64; ranks];
        let mut last_epoch = topology.epoch();

        let n_events = 6 + rng.next_below(14);
        for _ in 0..n_events {
            match rng.next_below(10) {
                // write bursts dominate
                0..=4 => {
                    for r in 0..ranks {
                        let k = 1 + rng.next_below(4);
                        let records: Vec<StreamRecord> = (next_step[r]..next_step[r] + k)
                            .map(|s| {
                                StreamRecord::from_f32("u", r as u32, s, 0, &[1], &[s as f32])
                            })
                            .collect::<Result<_>>()?;
                        shippers[r].ship(&records)?;
                        next_step[r] += k;
                    }
                }
                // scale-out (bounded)
                5 => {
                    if net.len() < 5 {
                        let idx = net.add_endpoint(StoreConfig::default());
                        let (slot, _) = topology.scale_out(dummy())?;
                        anyhow::ensure!(slot == idx, "net/topology slot skew");
                    }
                }
                // scale-in / endpoint failure
                6 => {
                    let live = topology.snapshot().live_endpoints();
                    if live.len() > 1 {
                        let victim = live[rng.next_below(live.len() as u64) as usize];
                        if rng.next_below(2) == 0 {
                            // hard death: conns break, handoff
                            // tombstones get lost, writers migrate via
                            // the topology alone (the sim store stays
                            // readable — it outlives the "process")
                            net.kill(victim);
                        }
                        topology.drain_endpoint(victim)?;
                    }
                }
                // transient mid-frame fault on a random endpoint
                7 => {
                    let e = rng.next_below(net.len() as u64) as usize;
                    net.inject(
                        e,
                        FaultSchedule {
                            drop_after_frames: Some(rng.next_below(2)),
                            partial_commands: rng.next_below(3) as usize,
                            refuse_connects: rng.next_below(2) as u32,
                            ..Default::default()
                        },
                    );
                }
                // slowdown → one rebalancer sweep with synthetic QoS
                _ => {
                    let topo = topology.snapshot();
                    let slow = rng.next_below(topo.endpoints.len() as u64) as usize;
                    let mut samples =
                        vec![EndpointSample::default(); topo.endpoints.len()];
                    samples[slow].flush_p95_us = u64::MAX / 2;
                    let plan =
                        rebalancer::evaluate(&topo, &samples, &QosThresholds::default());
                    rebalancer::apply(&plan, &topology)?;
                }
            }
            // Invariant 1: valid assignment at every epoch, monotonic.
            let topo = topology.snapshot();
            topo.validate()?;
            anyhow::ensure!(topo.epoch >= last_epoch, "epoch went backwards");
            last_epoch = topo.epoch;
        }

        // Invariants 2 + 3: replay every stream across all endpoints.
        for r in 0..ranks {
            let key = crate::record::stream_key("u", r as u32);
            let mut union: BTreeSet<u64> = BTreeSet::new();
            for e in 0..net.len() {
                let mut prev: Option<u64> = None;
                for entry in net.store(e).read_after(&key, EntryId::ZERO, 0) {
                    if entry.fields[0].0 == b"h" {
                        continue; // handoff tombstone
                    }
                    let rec = StreamRecord::decode(&entry.fields[0].1)?;
                    if let Some(p) = prev {
                        anyhow::ensure!(
                            rec.step > p,
                            "{key}: endpoint {e} segment not strictly increasing \
                             ({} after {p})",
                            rec.step
                        );
                    }
                    prev = Some(rec.step);
                    union.insert(rec.step);
                }
            }
            let want: BTreeSet<u64> = (0..next_step[r]).collect();
            anyhow::ensure!(
                union == want,
                "{key}: replay mismatch — {} of {} steps recovered",
                union.len(),
                want.len()
            );
        }
        Ok(())
    }
}

