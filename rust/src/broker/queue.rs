//! Bounded MPSC queue with selectable full-queue policy — the heart of
//! the broker's asynchronous write path.
//!
//! `std::sync::mpsc::SyncSender` only supports blocking; the paper's
//! design discussion (and the Fig 6/7 trade-off) needs both *Block*
//! (lossless backpressure into the simulation) and *DropOldest* (bound
//! the staleness of what the Cloud sees, lose old snapshots first), so
//! this is a small Mutex+Condvar ring with both policies.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// What `push` does when the queue is at capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the producer until space frees up (lossless).
    Block,
    /// Evict the oldest queued item (lossy, bounded staleness).
    DropOldest,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded queue shared between one producer (the simulation thread)
/// and one consumer (the broker writer thread).  Multi-producer safe.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    policy: QueuePolicy,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize, policy: QueuePolicy) -> Self {
        assert!(cap > 0, "queue capacity must be > 0");
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            policy,
        }
    }

    /// Push an item; returns the number of items dropped (0 or 1).
    /// Pushing to a closed queue silently drops the item (returns 1).
    pub fn push(&self, item: T) -> usize {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return 1;
        }
        let mut dropped = 0;
        match self.policy {
            QueuePolicy::Block => {
                while g.items.len() >= self.cap && !g.closed {
                    g = self.not_full.wait(g).unwrap();
                }
                if g.closed {
                    return 1;
                }
            }
            QueuePolicy::DropOldest => {
                if g.items.len() >= self.cap {
                    g.items.pop_front();
                    dropped = 1;
                }
            }
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        dropped
    }

    /// Pop the next item, blocking; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close the queue: producers stop, consumer drains what remains.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8, QueuePolicy::Block);
        for i in 0..5 {
            assert_eq!(q.push(i), 0);
        }
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn block_policy_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(2, QueuePolicy::Block));
        q.push(1);
        q.push(2);
        let q2 = q.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            q2.push(3); // must block until a pop
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(q.pop(), Some(1));
        let blocked_for = h.join().unwrap();
        assert!(
            blocked_for >= Duration::from_millis(80),
            "producer did not block: {blocked_for:?}"
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drop_oldest_policy_keeps_newest() {
        let q = BoundedQueue::new(3, QueuePolicy::DropOldest);
        let mut dropped = 0;
        for i in 0..10 {
            dropped += q.push(i);
        }
        assert_eq!(dropped, 7);
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![7, 8, 9]);
    }

    #[test]
    fn close_unblocks_producer_and_consumer() {
        let q = Arc::new(BoundedQueue::<u32>::new(1, QueuePolicy::Block));
        q.push(1);
        let qp = q.clone();
        let producer = std::thread::spawn(move || qp.push(2)); // blocks
        let qc = q.clone();
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        assert_eq!(producer.join().unwrap(), 1); // dropped at close
        // consumer drains then sees None
        assert_eq!(qc.pop(), Some(1));
        assert_eq!(qc.pop(), None);
    }

    #[test]
    fn push_after_close_is_dropped() {
        let q = BoundedQueue::new(4, QueuePolicy::Block);
        q.push(1);
        q.close();
        assert_eq!(q.push(2), 1);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn stress_producer_consumer_lossless() {
        let q = Arc::new(BoundedQueue::new(16, QueuePolicy::Block));
        let n = 20_000u64;
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i);
            }
            qp.close();
        });
        let mut expected = 0u64;
        while let Some(v) = q.pop() {
            assert_eq!(v, expected);
            expected += 1;
        }
        assert_eq!(expected, n);
        producer.join().unwrap();
    }
}
