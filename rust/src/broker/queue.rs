//! Bounded MPSC queue with selectable full-queue policy — the heart of
//! the broker's asynchronous write path.
//!
//! `std::sync::mpsc::SyncSender` only supports blocking; the paper's
//! design discussion (and the Fig 6/7 trade-off) needs both *Block*
//! (lossless backpressure into the simulation) and *DropOldest* (bound
//! the staleness of what the Cloud sees, lose old snapshots first), so
//! this is a small Mutex+Condvar ring with both policies.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// What `push` does when the queue is at capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the producer until space frees up (lossless).
    Block,
    /// Evict the oldest queued item (lossy, bounded staleness).
    DropOldest,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded queue shared between one producer (the simulation thread)
/// and one consumer (the broker writer thread).  Multi-producer safe.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    policy: QueuePolicy,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize, policy: QueuePolicy) -> Self {
        assert!(cap > 0, "queue capacity must be > 0");
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            policy,
        }
    }

    /// Push an item; returns the number of items dropped (0 or 1).
    /// Pushing to a closed queue silently drops the item (returns 1).
    pub fn push(&self, item: T) -> usize {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return 1;
        }
        let mut dropped = 0;
        match self.policy {
            QueuePolicy::Block => {
                while g.items.len() >= self.cap && !g.closed {
                    g = self.not_full.wait(g).unwrap();
                }
                if g.closed {
                    return 1;
                }
            }
            QueuePolicy::DropOldest => {
                if g.items.len() >= self.cap {
                    g.items.pop_front();
                    dropped = 1;
                }
            }
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        dropped
    }

    /// Pop the next item, blocking; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop a coalesced batch — the consumer side of the broker's
    /// pipelined write path.
    ///
    /// Blocks for the first item exactly like [`pop`](Self::pop), then
    /// greedily takes already-queued items while the batch stays within
    /// `max_n` records and `max_bytes` (per `size_of`; 0 = unbounded).
    /// If `linger` is non-zero and the batch is not yet full, waits up
    /// to that long for more items before returning — the classic
    /// throughput/latency knob.  Returns `None` once closed *and*
    /// drained.  The first item is always taken even when it alone
    /// exceeds `max_bytes`, so oversized records cannot wedge the queue.
    pub fn drain_batch<F>(
        &self,
        max_n: usize,
        max_bytes: usize,
        linger: Duration,
        size_of: F,
    ) -> Option<Vec<T>>
    where
        F: Fn(&T) -> usize,
    {
        let max_n = max_n.max(1);
        let mut g = self.inner.lock().unwrap();
        while g.items.is_empty() {
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        let mut batch = Vec::new();
        let mut bytes = 0usize;
        let deadline = if linger.is_zero() {
            None
        } else {
            Some(Instant::now() + linger)
        };
        loop {
            // Greedily take what is queued right now.
            while batch.len() < max_n {
                let fits = match g.items.front() {
                    None => break,
                    Some(item) => {
                        batch.is_empty()
                            || max_bytes == 0
                            || bytes + size_of(item) <= max_bytes
                    }
                };
                if !fits {
                    // Next item would blow the byte budget: ship what we have.
                    drop(g);
                    self.not_full.notify_all();
                    return Some(batch);
                }
                let item = g.items.pop_front().unwrap();
                bytes += size_of(&item);
                batch.push(item);
            }
            // The greedy take just freed capacity: wake blocked
            // producers NOW (they acquire the lock once we release it
            // in wait_timeout below), otherwise a full-queue producer
            // would stay parked through the whole linger window and
            // the batch could never fill.
            self.not_full.notify_all();
            if batch.len() >= max_n || g.closed {
                break;
            }
            let Some(dl) = deadline else { break };
            let now = Instant::now();
            if now >= dl {
                break;
            }
            let (g2, _) = self.not_empty.wait_timeout(g, dl - now).unwrap();
            g = g2;
        }
        drop(g);
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: producers stop, consumer drains what remains.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8, QueuePolicy::Block);
        for i in 0..5 {
            assert_eq!(q.push(i), 0);
        }
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn block_policy_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(2, QueuePolicy::Block));
        q.push(1);
        q.push(2);
        let q2 = q.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            q2.push(3); // must block until a pop
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(q.pop(), Some(1));
        let blocked_for = h.join().unwrap();
        assert!(
            blocked_for >= Duration::from_millis(80),
            "producer did not block: {blocked_for:?}"
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drop_oldest_policy_keeps_newest() {
        let q = BoundedQueue::new(3, QueuePolicy::DropOldest);
        let mut dropped = 0;
        for i in 0..10 {
            dropped += q.push(i);
        }
        assert_eq!(dropped, 7);
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![7, 8, 9]);
    }

    #[test]
    fn close_unblocks_producer_and_consumer() {
        let q = Arc::new(BoundedQueue::<u32>::new(1, QueuePolicy::Block));
        q.push(1);
        let qp = q.clone();
        let producer = std::thread::spawn(move || qp.push(2)); // blocks
        let qc = q.clone();
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        assert_eq!(producer.join().unwrap(), 1); // dropped at close
        // consumer drains then sees None
        assert_eq!(qc.pop(), Some(1));
        assert_eq!(qc.pop(), None);
    }

    #[test]
    fn push_after_close_is_dropped() {
        let q = BoundedQueue::new(4, QueuePolicy::Block);
        q.push(1);
        q.close();
        assert_eq!(q.push(2), 1);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn drain_batch_takes_queued_up_to_max_n() {
        let q = BoundedQueue::new(16, QueuePolicy::Block);
        for i in 0..10 {
            q.push(i);
        }
        let b = q
            .drain_batch(4, 0, Duration::ZERO, |_| 1)
            .unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = q
            .drain_batch(100, 0, Duration::ZERO, |_| 1)
            .unwrap();
        assert_eq!(b, vec![4, 5, 6, 7, 8, 9]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_batch_respects_byte_budget() {
        let q = BoundedQueue::new(16, QueuePolicy::Block);
        for i in 0..6u64 {
            q.push(i);
        }
        // each item "weighs" 10 bytes; budget 35 → 3 items per batch
        let b = q.drain_batch(100, 35, Duration::ZERO, |_| 10).unwrap();
        assert_eq!(b, vec![0, 1, 2]);
        let b = q.drain_batch(100, 35, Duration::ZERO, |_| 10).unwrap();
        assert_eq!(b, vec![3, 4, 5]);
    }

    #[test]
    fn drain_batch_oversized_first_item_still_ships() {
        let q = BoundedQueue::new(4, QueuePolicy::Block);
        q.push(1);
        q.push(2);
        // every item exceeds the budget alone: batches of exactly one
        let b = q.drain_batch(8, 5, Duration::ZERO, |_| 100).unwrap();
        assert_eq!(b, vec![1]);
        let b = q.drain_batch(8, 5, Duration::ZERO, |_| 100).unwrap();
        assert_eq!(b, vec![2]);
    }

    #[test]
    fn drain_batch_blocks_then_returns_none_after_close() {
        let q = Arc::new(BoundedQueue::<u32>::new(4, QueuePolicy::Block));
        let qc = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut batches = Vec::new();
            while let Some(b) = qc.drain_batch(8, 0, Duration::ZERO, |_| 1) {
                batches.push(b);
            }
            batches
        });
        std::thread::sleep(Duration::from_millis(30));
        q.push(7);
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        let batches = consumer.join().unwrap();
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn drain_batch_linger_collects_stragglers() {
        let q = Arc::new(BoundedQueue::new(16, QueuePolicy::Block));
        q.push(0);
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 1..4 {
                std::thread::sleep(Duration::from_millis(10));
                qp.push(i);
            }
        });
        // generous linger: the batch should absorb all 4 items
        let b = q
            .drain_batch(4, 0, Duration::from_millis(500), |_| 1)
            .unwrap();
        producer.join().unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
    }

    #[test]
    fn drain_batch_linger_deadline_bounds_wait() {
        let q = BoundedQueue::<u32>::new(4, QueuePolicy::Block);
        q.push(9);
        let t0 = Instant::now();
        let b = q
            .drain_batch(4, 0, Duration::from_millis(50), |_| 1)
            .unwrap();
        assert_eq!(b, vec![9]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(40), "left early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "over-waited: {waited:?}");
    }

    #[test]
    fn drain_batch_frees_capacity_for_blocked_producer() {
        let q = Arc::new(BoundedQueue::new(2, QueuePolicy::Block));
        q.push(1);
        q.push(2);
        let qp = q.clone();
        let producer = std::thread::spawn(move || qp.push(3)); // blocks: full
        std::thread::sleep(Duration::from_millis(30));
        let b = q.drain_batch(2, 0, Duration::ZERO, |_| 1).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert_eq!(producer.join().unwrap(), 0); // unblocked, nothing dropped
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn stress_producer_consumer_lossless() {
        let q = Arc::new(BoundedQueue::new(16, QueuePolicy::Block));
        let n = 20_000u64;
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i);
            }
            qp.close();
        });
        let mut expected = 0u64;
        while let Some(v) = q.pop() {
            assert_eq!(v, expected);
            expected += 1;
        }
        assert_eq!(expected, n);
        producer.join().unwrap();
    }
}
