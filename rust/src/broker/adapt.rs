//! Closed-loop adaptive reduction under WAN budgets (ISSUE 8
//! tentpole) — ElasticBroker's elasticity philosophy applied to
//! *fidelity*.
//!
//! The stage pipeline (ISSUE 5) has the lossy dial and the QoS board
//! (ISSUE 3/6) has the measurements; this module connects them.  A
//! mis-sized static `[stages]` config either wastes fidelity or blows
//! the latency budget — the [`AdaptController`] instead samples the
//! existing QoS signals each sweep (windowed flush p95, peak endpoint
//! queue depth, the stream's own writer backlog — the throttled-WAN
//! pressure proxy) and walks each stream's **reduction ladder**:
//!
//! ```text
//!   level 0          1          2            3            4      5
//!   base (f32) →   f16   →  qdelta(q)  → qdelta(4q) →  agg×2 → agg×4
//!   ──────────────── lossier / fewer wire bytes ───────────────────→
//! ```
//!
//! *down* (lossier) under bandwidth pressure and back *up* once the
//! link has been calm for `hysteresis` consecutive sweeps.  An empty
//! flush window is a **stall**, not "fast" — the controller holds
//! rather than walking fidelity back up while the link is wedged
//! (ISSUE 8 bugfix; see [`crate::metrics::Histogram::windowed_quantile`]).
//!
//! **Accuracy is a constraint, not a hope.**  Every stream carries an
//! accuracy target (`stages.max_err`), enforced against the frame's
//! *measured* error bound — never a static config:
//!
//! * rungs whose a-priori bound already violates the target (qdelta
//!   step/2) are pruned at ladder build time;
//! * data-dependent rungs (f16, block-mean aggregation) are admitted
//!   optimistically and checked on the **write path**: a frame whose
//!   measured `err_bound` exceeds the target is never shipped — the
//!   level is permanently disqualified for that stream and the frame
//!   re-encodes at the nearest safer rung (level 0 always admits).
//!
//! **Replay safety.**  Level changes are safe across migration,
//! crash-restart WAL replay and server-side reduced views because the
//! `EBR2` frame meta is the contract: every adaptively-shipped frame —
//! including level 0 — is a staged frame that fully describes its own
//! encoding and carries a `lvl:N@E` provenance tag (ladder level `N`,
//! monotone per-stream change epoch `E`).  Readers never need
//! controller state to decode; a replayed WAL reproduces exactly the
//! fidelity history that was acked.
//!
//! Wiring: [`crate::broker::Broker`] builds one [`Ladder`] per stage
//! config, registers each context's [`StreamAdapt`] in the shared
//! [`AdaptRegistry`], and the workflow starts one [`AdaptController`]
//! next to the [`super::Rebalancer`] — both sample the QoS board
//! through the shared non-destructive [`crate::metrics::QosBoard::sweep`].

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use anyhow::{ensure, Result};

use super::queue::BoundedQueue;
use super::stages::{StagePipeline, StagesConfig};
use super::topology::TopologyHandle;
use crate::metrics::{AdaptMetrics, StageMetrics, WorkflowMetrics};
use crate::record::{Encoding, StreamRecord};

/// Controller knobs (config `[adapt]`, CLI `--adapt-*`).
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptConfig {
    /// Controller sweep period (ms); 0 disables the controller and the
    /// whole adaptive path (contexts then use the static stage config).
    pub sweep_ms: u64,
    /// Latency budget: a windowed flush p95 above this (µs) is
    /// bandwidth pressure.
    pub target_p95_us: u64,
    /// Queue pressure: an endpoint peak queue depth or per-stream
    /// writer backlog at/above this many records is pressure.
    pub queue_hi: u64,
    /// Consecutive calm sweeps required before walking one level back
    /// up (the down direction reacts immediately; recovery is damped).
    pub hysteresis: u32,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            sweep_ms: 0,
            target_p95_us: 50_000,
            queue_hi: 16,
            hysteresis: 3,
        }
    }
}

impl AdaptConfig {
    pub fn enabled(&self) -> bool {
        self.sweep_ms > 0
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled() {
            return Ok(());
        }
        ensure!(self.target_p95_us > 0, "adapt.target_p95_us must be > 0");
        ensure!(self.queue_hi > 0, "adapt.queue_hi must be > 0");
        ensure!(self.hysteresis >= 1, "adapt.hysteresis must be >= 1");
        Ok(())
    }
}

/// The level-0-first rung configs derived from `base`:
/// `base → f16 → qdelta(q) → qdelta(4q) → agg×2 → agg×4` (aggregate
/// rungs stack on the coarsest admitted convert rung).  Rungs that
/// duplicate an earlier one, fail validation, or whose *a-priori*
/// error bound (qdelta step/2) already violates `base.max_err` are
/// skipped; data-dependent rungs (f16, aggregation) are admitted here
/// and policed at runtime by [`StreamAdapt::encode`].
pub fn ladder_configs(base: &StagesConfig) -> Vec<StagesConfig> {
    fn push(out: &mut Vec<StagesConfig>, cfg: StagesConfig, max_err: f32) {
        if max_err > 0.0
            && cfg.convert == Encoding::QDelta
            && cfg.qdelta_step * 0.5 > max_err
        {
            return;
        }
        if cfg.validate().is_err() || out.contains(&cfg) {
            return;
        }
        out.push(cfg);
    }

    let max_err = base.max_err;
    let mut out = vec![base.clone()];
    if base.convert == Encoding::F32 {
        push(
            &mut out,
            StagesConfig { convert: Encoding::F16, ..base.clone() },
            max_err,
        );
    }
    // A base already quantizing at step s coarsens from 4s; otherwise
    // the configured step is the first quantized rung.
    let q0 = if base.convert == Encoding::QDelta {
        base.qdelta_step * 4.0
    } else {
        base.qdelta_step
    };
    for step in [q0, q0 * 4.0] {
        push(
            &mut out,
            StagesConfig {
                convert: Encoding::QDelta,
                qdelta_step: step,
                ..base.clone()
            },
            max_err,
        );
    }
    let tail = out.last().cloned().unwrap_or_else(|| base.clone());
    for factor in [2usize, 4] {
        push(
            &mut out,
            StagesConfig {
                aggregate: base.aggregate.max(1) * factor,
                ..tail.clone()
            },
            max_err,
        );
    }
    out
}

/// A prebuilt, validated reduction ladder — one per stage config, its
/// pipelines shared by every stream using that config (pipelines are
/// stateless per record; per-stream position lives in [`StreamAdapt`]).
pub struct Ladder {
    pipelines: Vec<Arc<StagePipeline>>,
    max_err: f32,
}

impl Ladder {
    pub fn build(base: &StagesConfig, metrics: Arc<StageMetrics>) -> Result<Arc<Ladder>> {
        let configs = ladder_configs(base);
        ensure!(
            configs.len() <= 64,
            "adapt: ladder of {} levels exceeds the 64-level admission mask",
            configs.len()
        );
        let mut pipelines = Vec::with_capacity(configs.len());
        for cfg in configs {
            pipelines.push(Arc::new(StagePipeline::new(cfg, metrics.clone())?));
        }
        Ok(Arc::new(Ladder { pipelines, max_err: base.max_err }))
    }

    pub fn len(&self) -> usize {
        self.pipelines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pipelines.is_empty()
    }

    /// Per-stream accuracy target (0 = unconstrained).
    pub fn max_err(&self) -> f32 {
        self.max_err
    }

    pub fn level(&self, i: usize) -> &Arc<StagePipeline> {
        &self.pipelines[i.min(self.pipelines.len() - 1)]
    }
}

/// One stream's runtime-swappable position on the ladder, shared
/// between its write path and the controller.  All state is atomic:
/// the write path never blocks on the controller.
pub struct StreamAdapt {
    key: String,
    group: usize,
    ladder: Arc<Ladder>,
    queue: Arc<BoundedQueue<StreamRecord>>,
    /// Current ladder level (0 = most faithful).
    level: AtomicUsize,
    /// Monotone change epoch: bumped on every level transition, stamped
    /// into each frame's `lvl:N@E` provenance tag.
    epoch: AtomicU64,
    /// Consecutive calm sweeps seen by the controller (hysteresis).
    calm: AtomicU32,
    /// Max measured `err_bound` shipped since the controller last
    /// drained it (f32 bits; non-negative floats order like their bits).
    worst_err_bits: AtomicU32,
    /// Bitmask of levels disqualified by the write-path admission check
    /// (measured error over target, or encode failure).  Sticky for the
    /// stream's lifetime; level 0 is never disqualified.
    inadmissible: AtomicU64,
}

impl StreamAdapt {
    pub fn new(
        key: String,
        group: usize,
        ladder: Arc<Ladder>,
        queue: Arc<BoundedQueue<StreamRecord>>,
    ) -> Arc<StreamAdapt> {
        Arc::new(StreamAdapt {
            key,
            group,
            ladder,
            queue,
            level: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            calm: AtomicU32::new(0),
            worst_err_bits: AtomicU32::new(0),
            inadmissible: AtomicU64::new(0),
        })
    }

    pub fn key(&self) -> &str {
        &self.key
    }

    pub fn group(&self) -> usize {
        self.group
    }

    pub fn ladder(&self) -> &Arc<Ladder> {
        &self.ladder
    }

    pub fn level(&self) -> usize {
        self.level.load(Ordering::Relaxed)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Records waiting in this stream's writer queue — the throttled-
    /// WAN backlog proxy the controller reads.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Whether `lvl` may be encoded at (level 0 always admits).
    pub fn admissible(&self, lvl: usize) -> bool {
        lvl == 0 || self.inadmissible.load(Ordering::Relaxed) & (1u64 << lvl) == 0
    }

    fn mark_inadmissible(&self, lvl: usize) {
        if lvl > 0 && lvl < 64 {
            self.inadmissible.fetch_or(1u64 << lvl, Ordering::Relaxed);
        }
    }

    fn note_err(&self, err: f32) {
        self.worst_err_bits
            .fetch_max(err.max(0.0).to_bits(), Ordering::Relaxed);
    }

    /// Max measured error bound shipped since the last drain.
    pub fn take_worst_err(&self) -> f32 {
        f32::from_bits(self.worst_err_bits.swap(0, Ordering::Relaxed))
    }

    /// CAS `from → to`, bumping the epoch on success.  Loses gracefully
    /// to a concurrent transition (the caller re-reads).
    fn transition(&self, from: usize, to: usize) -> Option<usize> {
        if self
            .level
            .compare_exchange(from, to, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            self.epoch.fetch_add(1, Ordering::Relaxed);
            Some(to)
        } else {
            None
        }
    }

    /// Walk one rung lossier (skipping disqualified rungs); `None` when
    /// already at the bottom or a concurrent transition won.
    pub fn step_down(&self) -> Option<usize> {
        let cur = self.level();
        let mut next = cur + 1;
        while next < self.ladder.len() {
            if self.admissible(next) {
                return self.transition(cur, next);
            }
            next += 1;
        }
        None
    }

    /// Walk one rung more faithful; `None` at the top (level 0) or on a
    /// lost race.
    pub fn step_up(&self) -> Option<usize> {
        let cur = self.level();
        let mut next = cur.checked_sub(1)?;
        while next > 0 && !self.admissible(next) {
            next -= 1;
        }
        self.transition(cur, next)
    }

    /// Encode one snapshot at the stream's current level, enforcing the
    /// accuracy target per frame: a frame whose measured `err_bound`
    /// exceeds `max_err` (or whose lossy encode fails outright) is
    /// never shipped — the offending level is disqualified and the
    /// frame re-encodes at the nearest safer admissible rung.  Level 0
    /// is the unconditioned fallback: whatever the operator statically
    /// configured as the base ships as-is.
    #[allow(clippy::too_many_arguments)]
    pub fn encode(
        &self,
        field: &str,
        rank: u32,
        step: u64,
        seq: u64,
        gen_micros: u64,
        shape: &[u32],
        data: &[f32],
        metrics: &AdaptMetrics,
    ) -> Result<Option<StreamRecord>> {
        loop {
            let lvl = self.level();
            let tag = format!("lvl:{lvl}@{}", self.epoch());
            let rec = match self.ladder.level(lvl).apply_tagged(
                field,
                rank,
                step,
                seq,
                gen_micros,
                shape,
                data,
                Some(&tag),
            ) {
                Ok(rec) => rec,
                Err(e) if lvl > 0 => {
                    // A lossy rung this data cannot encode (non-finite
                    // after quantization, overflow, …) is as
                    // disqualified as an inaccurate one.
                    log::warn!(
                        "adapt[{}]: level {lvl} encode failed ({e:#}); disqualifying",
                        self.key
                    );
                    self.reject_level(lvl, metrics);
                    continue;
                }
                Err(e) => return Err(e),
            };
            if let Some(r) = &rec {
                if let Some(m) = &r.meta {
                    let max_err = self.ladder.max_err;
                    if lvl > 0 && max_err > 0.0 && m.err_bound > max_err {
                        log::info!(
                            "adapt[{}]: level {lvl} measured err {} over target {max_err}; disqualifying",
                            self.key,
                            m.err_bound
                        );
                        self.reject_level(lvl, metrics);
                        continue;
                    }
                    self.note_err(m.err_bound);
                }
            }
            return Ok(rec);
        }
    }

    fn reject_level(&self, lvl: usize, metrics: &AdaptMetrics) {
        metrics.err_rejections.inc();
        self.mark_inadmissible(lvl);
        // Move off the dead rung; on a lost race the encode loop
        // re-reads whatever level the controller chose instead.
        let mut next = lvl.saturating_sub(1);
        while next > 0 && !self.admissible(next) {
            next -= 1;
        }
        let _ = self.transition(lvl, next);
    }
}

/// Shared directory of every stream's [`StreamAdapt`] — the broker
/// registers contexts as they init; the controller sweeps it.
#[derive(Clone, Default)]
pub struct AdaptRegistry {
    streams: Arc<RwLock<Vec<Arc<StreamAdapt>>>>,
}

impl AdaptRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, s: Arc<StreamAdapt>) {
        self.streams.write().unwrap().push(s);
    }

    pub fn streams(&self) -> Vec<Arc<StreamAdapt>> {
        self.streams.read().unwrap().clone()
    }

    /// Lookup by stream key (tests / diagnostics).
    pub fn stream(&self, key: &str) -> Option<Arc<StreamAdapt>> {
        self.streams
            .read()
            .unwrap()
            .iter()
            .find(|s| s.key == key)
            .cloned()
    }
}

/// Per-stream signals for one controller sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamSignals {
    /// Windowed flush p95 of the stream's endpoint (µs); `None` = no
    /// flushes this window (stall or idle — *not* fast).
    pub flush_p95_us: Option<u64>,
    /// Peak writer-queue depth recorded against the endpoint.
    pub queue_depth: u64,
    /// This stream's own writer backlog (records).
    pub backlog: u64,
}

/// One sweep's verdict for one stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Bandwidth pressure: walk one rung lossier now.
    Down,
    /// No pressure, but either a stalled window (never walk up blind)
    /// or calm not yet sustained past the hysteresis.
    Hold,
    /// Calm sustained: walk one rung more faithful.
    Up,
}

/// The pure per-stream policy (separated from the sampling thread so
/// it unit-tests without clocks): pressure → [`Decision::Down`]
/// immediately; recovery requires `hysteresis` consecutive calm sweeps
/// *with flush evidence* — an empty window holds (ISSUE 8 bugfix).
pub fn decide(sig: &StreamSignals, cfg: &AdaptConfig, calm_sweeps: u32) -> Decision {
    let pressured = sig.flush_p95_us.is_some_and(|p| p > cfg.target_p95_us)
        || sig.queue_depth >= cfg.queue_hi
        || sig.backlog >= cfg.queue_hi;
    if pressured {
        return Decision::Down;
    }
    if sig.flush_p95_us.is_none() {
        return Decision::Hold;
    }
    if calm_sweeps + 1 >= cfg.hysteresis {
        Decision::Up
    } else {
        Decision::Hold
    }
}

/// The sampling thread: shared QoS sweep → [`decide`] per stream →
/// [`StreamAdapt`] transitions, every `cfg.sweep_ms`.  Runs alongside
/// the [`super::Rebalancer`] (both observe the same sweep windows) and
/// works with static topologies too — fidelity adaptation does not
/// require elasticity.
pub struct AdaptController {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl AdaptController {
    pub fn start(
        registry: AdaptRegistry,
        topology: TopologyHandle,
        metrics: WorkflowMetrics,
        cfg: AdaptConfig,
    ) -> AdaptController {
        let interval = Duration::from_millis(cfg.sweep_ms.max(1));
        let stop = Arc::new(AtomicBool::new(false));
        let t_stop = stop.clone();
        let thread = std::thread::Builder::new()
            .name("adapt-controller".into())
            .spawn(move || {
                while !t_stop.load(Ordering::SeqCst) {
                    let sweep = metrics.qos.sweep(interval / 2);
                    let topo = topology.snapshot();
                    for s in registry.streams() {
                        let qs = topo
                            .assignment
                            .get(s.group())
                            .and_then(|&e| sweep.samples.get(e))
                            .copied()
                            .unwrap_or_default();
                        let sig = StreamSignals {
                            flush_p95_us: qs.flush_p95_us,
                            queue_depth: qs.queue_depth,
                            backlog: s.backlog() as u64,
                        };
                        let worst = s.take_worst_err();
                        match decide(&sig, &cfg, s.calm.load(Ordering::Relaxed)) {
                            Decision::Down => {
                                s.calm.store(0, Ordering::Relaxed);
                                if let Some(lvl) = s.step_down() {
                                    metrics.adapt.steps_down.inc();
                                    // The decision and its QoS evidence go
                                    // to the journal (ISSUE 9) so a trace
                                    // reader can correlate fidelity drops
                                    // with the pressure that caused them.
                                    metrics.events.emit(
                                        "adapt.down",
                                        format!(
                                            "{{\"stream\":\"{}\",\"level\":{lvl},\
                                             \"epoch\":{},\"flush_p95_us\":{},\
                                             \"queue_depth\":{},\"backlog\":{}}}",
                                            crate::metrics::obs::json_escape(s.key()),
                                            s.epoch(),
                                            sig.flush_p95_us.map_or(-1, |p| p as i64),
                                            sig.queue_depth,
                                            sig.backlog
                                        ),
                                    );
                                    log::info!(
                                        "adapt[{}]: pressure ({sig:?}) → level {lvl} (epoch {})",
                                        s.key(),
                                        s.epoch()
                                    );
                                } else {
                                    metrics.adapt.holds.inc();
                                }
                            }
                            Decision::Up => {
                                s.calm.store(0, Ordering::Relaxed);
                                if let Some(lvl) = s.step_up() {
                                    metrics.adapt.steps_up.inc();
                                    metrics.events.emit(
                                        "adapt.up",
                                        format!(
                                            "{{\"stream\":\"{}\",\"level\":{lvl},\
                                             \"epoch\":{},\"worst_err\":{worst:e}}}",
                                            crate::metrics::obs::json_escape(s.key()),
                                            s.epoch()
                                        ),
                                    );
                                    log::info!(
                                        "adapt[{}]: calm → level {lvl} (epoch {}, worst err {worst})",
                                        s.key(),
                                        s.epoch()
                                    );
                                } else {
                                    metrics.adapt.holds.inc();
                                }
                            }
                            Decision::Hold => {
                                // Calm only accumulates with flush
                                // evidence; a stalled window freezes
                                // the counter instead of resetting a
                                // legitimately-idle stream's progress.
                                if sig.flush_p95_us.is_some() {
                                    s.calm.fetch_add(1, Ordering::Relaxed);
                                }
                                metrics.adapt.holds.inc();
                            }
                        }
                        metrics.adapt.dwell(s.level()).inc();
                    }
                    // Sleep in small slices so stop() returns promptly.
                    let mut left = interval;
                    while !left.is_zero() && !t_stop.load(Ordering::SeqCst) {
                        let nap = left.min(Duration::from_millis(20));
                        std::thread::sleep(nap);
                        left -= nap;
                    }
                }
            })
            .expect("spawn adapt-controller");
        AdaptController { stop, thread: Some(thread) }
    }

    /// Stop the sweep loop and join the thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AdaptController {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::QueuePolicy;
    use crate::record::CodecKind;

    fn test_stream(base: StagesConfig) -> Arc<StreamAdapt> {
        let ladder =
            Ladder::build(&base, Arc::new(StageMetrics::new())).unwrap();
        let queue = Arc::new(BoundedQueue::new(8, QueuePolicy::Block));
        StreamAdapt::new("u/0".into(), 0, ladder, queue)
    }

    #[test]
    fn ladder_walks_f32_f16_qdelta_aggregate() {
        let cfgs = ladder_configs(&StagesConfig::default());
        assert_eq!(cfgs.len(), 6);
        assert_eq!(cfgs[0], StagesConfig::default());
        assert_eq!(cfgs[1].convert, Encoding::F16);
        assert_eq!(cfgs[2].convert, Encoding::QDelta);
        assert_eq!(cfgs[3].qdelta_step, cfgs[2].qdelta_step * 4.0);
        assert_eq!(cfgs[4].aggregate, 2);
        assert_eq!(cfgs[4].convert, Encoding::QDelta);
        assert_eq!(cfgs[5].aggregate, 4);
        // every rung is a valid config
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn ladder_prunes_rungs_violating_the_accuracy_target() {
        // max_err 1e-4: qdelta rungs at step 1e-3 (bound 5e-4) and
        // 4e-3 are a-priori inadmissible; f16 and aggregation stay
        // (data-dependent, policed at runtime).
        let base = StagesConfig { max_err: 1e-4, ..Default::default() };
        let cfgs = ladder_configs(&base);
        assert!(cfgs.iter().all(|c| c.convert != Encoding::QDelta), "{cfgs:?}");
        assert_eq!(cfgs[0], base);
        assert_eq!(cfgs[1].convert, Encoding::F16);
        assert!(cfgs.iter().any(|c| c.aggregate == 4));
        // a lossy base keeps its own rung 0 even over the target
        let lossy = StagesConfig {
            convert: Encoding::QDelta,
            qdelta_step: 1.0,
            max_err: 1e-4,
            ..Default::default()
        };
        assert_eq!(ladder_configs(&lossy)[0], lossy);
    }

    #[test]
    fn decide_matrix() {
        let cfg = AdaptConfig {
            sweep_ms: 10,
            target_p95_us: 1000,
            queue_hi: 8,
            hysteresis: 3,
        };
        let calm = StreamSignals {
            flush_p95_us: Some(100),
            queue_depth: 0,
            backlog: 0,
        };
        // pressure on any signal → Down, regardless of calm credit
        for sig in [
            StreamSignals { flush_p95_us: Some(5000), ..calm },
            StreamSignals { queue_depth: 8, ..calm },
            StreamSignals { backlog: 9, ..calm },
            StreamSignals { flush_p95_us: None, queue_depth: 20, backlog: 0 },
        ] {
            assert_eq!(decide(&sig, &cfg, 99), Decision::Down, "{sig:?}");
        }
        // stalled window without queue pressure: hold, never up
        let stall = StreamSignals { flush_p95_us: None, queue_depth: 0, backlog: 0 };
        assert_eq!(decide(&stall, &cfg, 99), Decision::Hold);
        // calm under hysteresis holds; at hysteresis walks up
        assert_eq!(decide(&calm, &cfg, 0), Decision::Hold);
        assert_eq!(decide(&calm, &cfg, 1), Decision::Hold);
        assert_eq!(decide(&calm, &cfg, 2), Decision::Up);
    }

    #[test]
    fn steps_bump_epoch_and_skip_disqualified_rungs() {
        let s = test_stream(StagesConfig::default());
        assert_eq!((s.level(), s.epoch()), (0, 0));
        assert_eq!(s.step_down(), Some(1));
        assert_eq!(s.step_down(), Some(2));
        assert_eq!(s.epoch(), 2);
        s.mark_inadmissible(1);
        assert_eq!(s.step_up(), Some(0), "skips the disqualified rung");
        assert_eq!(s.epoch(), 3);
        s.mark_inadmissible(1);
        s.mark_inadmissible(2);
        assert_eq!(s.step_down(), Some(3), "down also skips them");
        // bottom of the ladder: no further down
        while s.step_down().is_some() {}
        assert_eq!(s.step_down(), None);
    }

    #[test]
    fn encode_rejects_levels_over_the_accuracy_target() {
        // Blocky data: block-mean aggregation error ≈ 1.0, far over the
        // target; qdelta rungs are pruned a priori (step/2 = 5e-4 >
        // 1e-4), so the ladder is [f32, f16, agg×2, agg×4] and both
        // aggregate rungs must be rejected by the write path, never
        // shipped.
        let base = StagesConfig {
            max_err: 1e-4,
            codec: CodecKind::ShuffleLz,
            ..Default::default()
        };
        let s = test_stream(base);
        assert_eq!(s.ladder().len(), 4);
        let metrics = AdaptMetrics::new();
        let data: Vec<f32> =
            (0..64).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        // force the stream to the lossiest rung, as the controller would
        while s.step_down().is_some() {}
        assert_eq!(s.level(), 3);
        let rec = s
            .encode("u", 0, 0, 0, 0, &[64], &data, &metrics)
            .unwrap()
            .unwrap();
        let meta = rec.meta.as_ref().unwrap();
        assert!(
            meta.err_bound <= 1e-4,
            "shipped frame err {} over target",
            meta.err_bound
        );
        assert_eq!(metrics.err_rejections.get(), 2, "both agg rungs rejected");
        assert!(!s.admissible(2) && !s.admissible(3));
        assert!(s.level() < 2, "stream walked back to a safe rung");
        // provenance carries the level/epoch tag of the rung that shipped
        let prov = &meta.provenance;
        assert!(prov.contains(&format!("lvl:{}@", s.level())), "{prov}");
    }

    #[test]
    fn encode_tags_every_frame_even_at_level_zero() {
        let s = test_stream(StagesConfig::default());
        let metrics = AdaptMetrics::new();
        let data = vec![1.0f32; 16];
        let rec = s
            .encode("u", 0, 5, 0, 0, &[16], &data, &metrics)
            .unwrap()
            .unwrap();
        let meta = rec.meta.expect("adaptive frames are EBR2 even at level 0");
        assert_eq!(meta.provenance, "lvl:0@0");
        assert_eq!(meta.err_bound, 0.0);
        let back = StreamRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back.payload_f32().unwrap(), data);
    }

    #[test]
    fn config_validation() {
        assert!(AdaptConfig::default().validate().is_ok(), "off is ok");
        assert!(AdaptConfig { sweep_ms: 10, ..Default::default() }
            .validate()
            .is_ok());
        assert!(AdaptConfig { sweep_ms: 10, hysteresis: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(AdaptConfig { sweep_ms: 10, queue_hi: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(AdaptConfig { sweep_ms: 10, target_p95_us: 0, ..Default::default() }
            .validate()
            .is_err());
    }
}
