//! QoS-driven topology rebalancing (the paper's §4 "high quality of
//! service under varying load" behaviour, ISSUE 3).
//!
//! The broker writers already emit per-endpoint QoS into
//! [`crate::metrics::QosBoard`]: batch flush latency, reconnect
//! pressure and peak queue depth.  The [`Rebalancer`] samples that
//! board on a fixed cadence and turns it into topology mutations:
//!
//! * an endpoint whose **reconnect pressure** crossed the threshold
//!   since the last sweep is presumed dead and drained — all its
//!   groups move to the least-loaded survivors;
//! * an endpoint whose **flush p95** or **peak queue depth** crossed a
//!   threshold is saturated and sheds one group per sweep to the
//!   least-loaded calm endpoint (one group at a time keeps the control
//!   loop stable — no oscillation between two half-loaded endpoints).
//!
//! The decision function ([`evaluate`]) is pure — `(topology, samples,
//! thresholds) → plan` — so tests drive it with synthetic QoS
//! deterministically; the sampling thread is just a thin shell around
//! it.  Every applied plan bumps the topology epoch, which is what the
//! writers ([`super::Shipper`]) and readers
//! ([`crate::streamproc::ElasticReader`]) key their migrations off.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::topology::{Topology, TopologyHandle};
use crate::metrics::WorkflowMetrics;

/// When QoS signals trigger action.  A threshold of 0 disables that
/// signal.
#[derive(Clone, Debug)]
pub struct QosThresholds {
    /// Flush p95 (µs, over the last sweep's samples) above which an
    /// endpoint is saturated.
    pub flush_p95_us: u64,
    /// Peak writer-queue depth at/above which an endpoint is saturated.
    pub queue_depth: u64,
    /// Reconnect attempts per sweep at/above which an endpoint is dead.
    pub reconnects: u64,
}

impl Default for QosThresholds {
    fn default() -> Self {
        QosThresholds {
            flush_p95_us: 250_000,
            queue_depth: 48,
            reconnects: 3,
        }
    }
}

/// One endpoint's QoS over the last sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct EndpointSample {
    pub flush_p95_us: u64,
    pub queue_depth: u64,
    /// Reconnect attempts since the previous sweep.
    pub reconnect_delta: u64,
    /// The endpoint persists its streams to a WAL (ISSUE 4): preferred
    /// as a shed target over an equally-loaded in-memory endpoint —
    /// migrating a stream onto durable ground costs nothing extra and
    /// upgrades its fault story.
    pub durable: bool,
}

/// What a sweep decided.  Empty plan = topology untouched.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    /// Endpoints presumed dead: drain (mark not-live, move all groups).
    pub drain: Vec<usize>,
    /// Load-shedding moves: `(group, target endpoint)`.
    pub moves: Vec<(usize, usize)>,
}

impl MigrationPlan {
    pub fn is_empty(&self) -> bool {
        self.drain.is_empty() && self.moves.is_empty()
    }
}

/// Pure decision function: map per-endpoint QoS onto a migration plan.
/// `samples[e]` describes endpoint slot `e`; missing slots read as
/// quiet.  Deterministic (lowest indices win ties).
pub fn evaluate(
    topo: &Topology,
    samples: &[EndpointSample],
    thr: &QosThresholds,
) -> MigrationPlan {
    let mut plan = MigrationPlan::default();
    let quiet = EndpointSample::default();
    let sample = |e: usize| samples.get(e).copied().unwrap_or(quiet);

    let live = topo.live_endpoints();
    // Dead endpoints first: reconnect pressure says nobody can ship.
    for &e in &live {
        if thr.reconnects > 0 && sample(e).reconnect_delta >= thr.reconnects {
            plan.drain.push(e);
        }
    }
    // Survivors that are merely saturated shed one group per sweep.
    let healthy: Vec<usize> = live
        .iter()
        .copied()
        .filter(|e| !plan.drain.contains(e))
        .collect();
    if healthy.len() < 2 {
        return plan; // nowhere to shed to
    }
    let pressured = |e: usize| -> bool {
        let s = sample(e);
        (thr.flush_p95_us > 0 && s.flush_p95_us > thr.flush_p95_us)
            || (thr.queue_depth > 0 && s.queue_depth >= thr.queue_depth)
    };
    for &e in &healthy {
        if !pressured(e) {
            continue;
        }
        let my_groups = topo.groups_of_endpoint(e);
        if my_groups.is_empty() {
            continue;
        }
        let g = my_groups[0];
        // Least-loaded calm endpoint strictly below our load; between
        // equally-loaded candidates a durable (WAL-backed) endpoint
        // wins, then the lowest slot index.  Under replication (ISSUE
        // 10) the target must also be chain-safe for the shed group:
        // either already a member of its replica chain, or in a
        // failure domain distinct from every current member —
        // re-heading onto a co-located endpoint would silently drop a
        // chain position.
        let target = healthy
            .iter()
            .copied()
            .filter(|&t| t != e && !pressured(t) && chain_safe(topo, g, t))
            .min_by_key(|&t| (topo.groups_of_endpoint(t).len(), !sample(t).durable, t));
        if let Some(t) = target {
            if topo.groups_of_endpoint(t).len() < my_groups.len() {
                plan.moves.push((g, t));
            }
        }
    }
    plan
}

/// Whether re-heading group `g` onto endpoint `t` preserves its replica
/// chain (ISSUE 10).  True when replication is off, when `t` already
/// serves in the chain (an in-chain promotion keeps every copy), or
/// when `t`'s failure domain is distinct from every current member's —
/// the re-heading drops co-located followers, so a domain clash would
/// either shorten the chain or evict the old head's full copy.
fn chain_safe(topo: &Topology, g: usize, t: usize) -> bool {
    if topo.replication_factor <= 1 {
        return true;
    }
    let Ok(chain) = topo.replica_chain(g) else {
        return true;
    };
    if chain.contains(&t) {
        return true;
    }
    let td = &topo.endpoints[t].domain;
    chain.iter().all(|&m| topo.endpoints[m].domain != *td)
}

/// Apply a plan to the live topology.  Returns the new epoch if
/// anything changed.  Drains that would remove the last live endpoint
/// are skipped with a warning (better a degraded endpoint than none).
pub fn apply(plan: &MigrationPlan, handle: &TopologyHandle) -> Result<Option<u64>> {
    if plan.is_empty() {
        return Ok(None);
    }
    let mut epoch = None;
    for &e in &plan.drain {
        match handle.drain_endpoint(e) {
            Ok(ep) => epoch = Some(ep),
            Err(err) => log::warn!("rebalancer: cannot drain endpoint {e}: {err:#}"),
        }
    }
    // Moves targeting an endpoint a drain just killed are recomputed
    // next sweep; only apply the ones that still make sense.
    let topo = handle.snapshot();
    let moves: Vec<(usize, usize)> = plan
        .moves
        .iter()
        .copied()
        .filter(|&(g, t)| {
            g < topo.assignment.len()
                && t < topo.endpoints.len()
                && topo.endpoints[t].live
                && topo.assignment[g] != t
        })
        .collect();
    if !moves.is_empty() {
        epoch = Some(handle.assign(&moves)?);
    }
    // Drains and re-headings can shorten replica chains; top them back
    // up immediately so the reduced-redundancy window stays as narrow
    // as one sweep (ISSUE 10).
    if topo.replication_factor > 1 {
        if let Some(ep) = handle.repair_chains()? {
            epoch = Some(ep);
        }
    }
    Ok(epoch)
}

/// The sampling thread: QoS board → [`evaluate`] → [`apply`], every
/// `interval`.  Stop with [`Rebalancer::stop`] (or drop).
pub struct Rebalancer {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Rebalancer {
    pub fn start(
        topology: TopologyHandle,
        metrics: WorkflowMetrics,
        thresholds: QosThresholds,
        interval: Duration,
    ) -> Rebalancer {
        let stop = Arc::new(AtomicBool::new(false));
        let t_stop = stop.clone();
        let thread = std::thread::Builder::new()
            .name("rebalancer".into())
            .spawn(move || {
                let mut last_reconnects: Vec<u64> = Vec::new();
                while !t_stop.load(Ordering::SeqCst) {
                    let topo = topology.snapshot();
                    let n = topo.endpoints.len();
                    last_reconnects.resize(n, 0);
                    // Shared sweep-windowed drain (ISSUE 8 bugfix): the
                    // board performs the destructive reads at most once
                    // per window, so the adapt controller sampling
                    // concurrently observes the *same* sweep instead of
                    // the zeros a second `take()` used to read.  Every
                    // QoS signal stays windowed to the sweep, so a slow
                    // or flaky *spell* decays instead of branding an
                    // endpoint saturated for the rest of the run.
                    let sweep = metrics.qos.sweep(interval / 2);
                    let mut samples = Vec::with_capacity(n);
                    for e in 0..n {
                        // Touch the slot so the board covers every
                        // endpoint the topology knows about.
                        let _ = metrics.qos.slot(e);
                        let s = sweep.samples.get(e).copied().unwrap_or_default();
                        let delta =
                            s.reconnects_total.saturating_sub(last_reconnects[e]);
                        last_reconnects[e] = s.reconnects_total;
                        samples.push(EndpointSample {
                            // No flushes this window reads as quiet for
                            // the *shed* decision (an idle endpoint is
                            // not pressured).
                            flush_p95_us: s.flush_p95_us.unwrap_or(0),
                            queue_depth: s.queue_depth,
                            reconnect_delta: delta,
                            durable: s.durable,
                        });
                    }
                    let plan = evaluate(&topo, &samples, &thresholds);
                    if !plan.is_empty() {
                        log::info!(
                            "rebalancer: drain {:?}, moves {:?} (epoch {})",
                            plan.drain,
                            plan.moves,
                            topo.epoch
                        );
                        // Journal each decision with the QoS evidence
                        // that triggered it (ISSUE 9): post-hoc analysis
                        // can then correlate migrations with pressure
                        // without replaying the board.
                        for &e in &plan.drain {
                            let s = samples.get(e).copied().unwrap_or_default();
                            metrics.events.emit(
                                "rebalance.drain",
                                format!(
                                    "{{\"endpoint\":{e},\"reconnect_delta\":{},\
                                     \"epoch\":{}}}",
                                    s.reconnect_delta, topo.epoch
                                ),
                            );
                        }
                        for &(g, t) in &plan.moves {
                            let from =
                                topo.assignment.get(g).copied().unwrap_or(usize::MAX);
                            let s = samples.get(from).copied().unwrap_or_default();
                            metrics.events.emit(
                                "rebalance.shed",
                                format!(
                                    "{{\"group\":{g},\"from\":{from},\"to\":{t},\
                                     \"flush_p95_us\":{},\"queue_depth\":{},\
                                     \"epoch\":{}}}",
                                    s.flush_p95_us, s.queue_depth, topo.epoch
                                ),
                            );
                        }
                        match apply(&plan, &topology) {
                            Ok(Some(epoch)) => metrics.events.emit(
                                "topology.epoch",
                                format!(
                                    "{{\"epoch\":{epoch},\"drained\":{},\
                                     \"moved\":{}}}",
                                    plan.drain.len(),
                                    plan.moves.len()
                                ),
                            ),
                            Ok(None) => {}
                            Err(e) => log::warn!("rebalancer: apply failed: {e:#}"),
                        }
                    }
                    // Sleep in small slices so stop() returns promptly.
                    let mut left = interval;
                    while !left.is_zero() && !t_stop.load(Ordering::SeqCst) {
                        let nap = left.min(Duration::from_millis(20));
                        std::thread::sleep(nap);
                        left -= nap;
                    }
                }
            })
            .expect("spawn rebalancer");
        Rebalancer {
            stop,
            thread: Some(thread),
        }
    }

    /// Stop the sweep loop and join the thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Rebalancer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::groups::GroupMap;

    fn topo(ranks: usize, gsize: usize, n_eps: usize) -> TopologyHandle {
        let groups = GroupMap::new(ranks, gsize, n_eps).unwrap();
        let addrs = (0..n_eps)
            .map(|i| format!("127.0.0.1:{}", 7200 + i).parse().unwrap())
            .collect();
        TopologyHandle::new_static(groups, addrs).unwrap()
    }

    #[test]
    fn quiet_board_yields_empty_plan() {
        let h = topo(64, 16, 2);
        let plan = evaluate(&h.snapshot(), &[], &QosThresholds::default());
        assert!(plan.is_empty());
        assert_eq!(apply(&plan, &h).unwrap(), None);
        assert_eq!(h.epoch(), 1);
    }

    #[test]
    fn reconnect_pressure_drains_dead_endpoint() {
        let h = topo(64, 16, 2); // groups 0,2 → e0; 1,3 → e1
        let samples = vec![
            EndpointSample::default(),
            EndpointSample {
                reconnect_delta: 5,
                ..Default::default()
            },
        ];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(plan.drain, vec![1]);
        let epoch = apply(&plan, &h).unwrap().unwrap();
        assert_eq!(epoch, 2);
        let t = h.snapshot();
        assert!(!t.endpoints[1].live);
        assert_eq!(t.groups_of_endpoint(0).len(), 4);
        t.validate().unwrap();
    }

    #[test]
    fn saturation_sheds_one_group_to_calm_endpoint() {
        let h = topo(64, 16, 2);
        let samples = vec![
            EndpointSample {
                flush_p95_us: 999_999,
                ..Default::default()
            },
            EndpointSample::default(),
        ];
        let thr = QosThresholds::default();
        let plan = evaluate(&h.snapshot(), &samples, &thr);
        // e0 and e1 both hold 2 groups: no strictly-less target → no move
        assert!(plan.is_empty());
        // skew load: everything on e0, then saturation sheds one group
        h.assign(&[(1, 0), (3, 0)]).unwrap();
        let plan = evaluate(&h.snapshot(), &samples, &thr);
        assert_eq!(plan.moves, vec![(0, 1)]);
        apply(&plan, &h).unwrap().unwrap();
        let t = h.snapshot();
        assert_eq!(t.groups_of_endpoint(1), vec![0]);
        t.validate().unwrap();
    }

    #[test]
    fn queue_depth_also_counts_as_saturation() {
        let h = topo(48, 16, 3);
        h.assign(&[(1, 0), (2, 0)]).unwrap(); // all 3 groups on e0
        let samples = vec![EndpointSample {
            queue_depth: 64,
            ..Default::default()
        }];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(plan.moves.len(), 1);
        let (_, target) = plan.moves[0];
        assert!(target == 1 || target == 2);
    }

    /// ISSUE 4: between equally-loaded calm targets, a durable
    /// endpoint wins the shed.
    #[test]
    fn shed_prefers_durable_target_on_ties() {
        let h = topo(48, 16, 3); // 3 groups over e0..e2
        h.assign(&[(1, 0), (2, 0)]).unwrap(); // all 3 groups on e0
        let samples = vec![
            EndpointSample {
                queue_depth: 64,
                ..Default::default()
            },
            EndpointSample::default(), // e1: empty, in-memory
            EndpointSample {
                durable: true, // e2: empty, WAL-backed
                ..Default::default()
            },
        ];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(plan.moves.len(), 1);
        assert_eq!(plan.moves[0].1, 2, "durable endpoint should win the tie");
        // with no durability info, the lowest index keeps winning
        let plan = evaluate(&h.snapshot(), &samples[..2], &QosThresholds::default());
        assert_eq!(plan.moves[0].1, 1);
    }

    #[test]
    fn never_drains_the_last_live_endpoint() {
        let h = topo(16, 16, 1);
        let samples = vec![EndpointSample {
            reconnect_delta: 99,
            ..Default::default()
        }];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(plan.drain, vec![0]);
        // apply refuses (skips) and leaves the topology valid
        assert_eq!(apply(&plan, &h).unwrap(), None);
        let t = h.snapshot();
        assert!(t.endpoints[0].live);
        t.validate().unwrap();
    }

    #[test]
    fn zero_thresholds_disable_signals() {
        let h = topo(64, 16, 2);
        h.assign(&[(1, 0), (3, 0)]).unwrap();
        let thr = QosThresholds {
            flush_p95_us: 0,
            queue_depth: 0,
            reconnects: 0,
        };
        let samples = vec![
            EndpointSample {
                flush_p95_us: u64::MAX,
                queue_depth: u64::MAX,
                reconnect_delta: u64::MAX,
                durable: false,
            },
            EndpointSample::default(),
        ];
        assert!(evaluate(&h.snapshot(), &samples, &thr).is_empty());
    }

    fn rtopo(
        ranks: usize,
        gsize: usize,
        n_eps: usize,
        domains: &[&str],
        factor: usize,
    ) -> TopologyHandle {
        let groups = GroupMap::new(ranks, gsize, n_eps).unwrap();
        let addrs = (0..n_eps)
            .map(|i| format!("127.0.0.1:{}", 7300 + i).parse().unwrap())
            .collect();
        let domains: Vec<String> = domains.iter().map(|s| s.to_string()).collect();
        TopologyHandle::new_replicated(groups, addrs, &domains, factor).unwrap()
    }

    /// ISSUE 10: a shed never re-heads a group onto an endpoint that
    /// shares a failure domain with its replica chain, even when that
    /// endpoint is the least loaded.
    #[test]
    fn shed_skips_domain_colocated_targets() {
        // 5 endpoints over domains a,b,a,b,c; factor 2.  Group 0's
        // chain is [0, 1] (domains a, b).
        let h = rtopo(80, 16, 5, &["a", "b", "a", "b", "c"], 2);
        h.assign(&[(1, 0), (2, 0)]).unwrap(); // skew: e0 heads 3 groups
        let pressured = EndpointSample {
            queue_depth: 64,
            ..Default::default()
        };
        // e0 sheds; e1 (the in-chain follower) is pressured too, so the
        // calm candidates are e2 (load 0, domain a — co-located with
        // head 0), e3 (load 1, domain b — co-located with follower 1)
        // and e4 (load 1, domain c — safe).
        let samples = vec![pressured, pressured];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(
            plan.moves,
            vec![(0, 4)],
            "only the domain-distinct endpoint is chain-safe"
        );
        apply(&plan, &h).unwrap().unwrap();
        let t = h.snapshot();
        assert_eq!(t.replica_chain(0).unwrap(), &[4, 0]);
        t.validate().unwrap();
    }

    /// ISSUE 10: applying a drain tops shortened chains back up to the
    /// replication factor in the same sweep.
    #[test]
    fn apply_repairs_short_chains_after_a_drain() {
        let h = rtopo(48, 16, 3, &["a", "b", "c"], 2);
        let samples = vec![EndpointSample {
            reconnect_delta: 5,
            ..Default::default()
        }];
        let plan = evaluate(&h.snapshot(), &samples, &QosThresholds::default());
        assert_eq!(plan.drain, vec![0]);
        apply(&plan, &h).unwrap().unwrap();
        let t = h.snapshot();
        assert!(!t.endpoints[0].live);
        for g in 0..t.replicas.len() {
            let chain = t.replica_chain(g).unwrap();
            assert_eq!(chain.len(), 2, "group {g} left short after repair");
            assert!(!chain.contains(&0), "group {g} still references the drained slot");
        }
        t.validate().unwrap();
    }

    #[test]
    fn sampling_thread_reacts_to_injected_reconnect_pressure() {
        let h = topo(64, 16, 2);
        let metrics = WorkflowMetrics::new();
        let reb = Rebalancer::start(
            h.clone(),
            metrics.clone(),
            QosThresholds::default(),
            Duration::from_millis(10),
        );
        // simulate writers failing against endpoint 1
        metrics.qos.slot(1).reconnects.add(10);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while h.epoch() == 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        reb.stop();
        let t = h.snapshot();
        assert!(!t.endpoints[1].live, "endpoint 1 not drained");
        t.validate().unwrap();
    }
}
