//! Per-element value transforms — the paper's §1 "data filtering"
//! vocabulary (stride / magnitude / clamp / threshold).
//!
//! A [`Filter`] is a pipeline of [`FilterStage`]s.  Since ISSUE 6 it
//! no longer runs as a separate pre-serialization step: the broker
//! folds it into the head of the [`super::stages`] filter stage
//! (`StagesConfig::transforms`), so one reduction mechanism exists and
//! transformed bytes are part of the `StageMetrics` byte accounting.
//! [`Filter`] and [`FilterStage`] remain the public config surface
//! ([`super::BrokerConfig::filter`], [`super::Broker::init_filtered`]).
//! Stages reshape both the data and the declared shape so the Cloud
//! side always receives a self-consistent record.

use anyhow::{bail, ensure, Result};

/// One reduction/conversion stage.
#[derive(Clone, Debug, PartialEq)]
pub enum FilterStage {
    /// Keep every k-th element (flattens the shape to 1-D).
    Stride(usize),
    /// Collapse a leading component axis of size 2 (e.g. `[2, H, W]`
    /// velocity) into per-cell magnitude `sqrt(ux² + uy²)` → `[H, W]`.
    Magnitude,
    /// Clamp values into a range (sensor-style sanitation).
    Clamp(f32, f32),
    /// Keep only elements with |v| ≥ threshold, zeroing the rest
    /// (sparsification; shape unchanged).
    Threshold(f32),
}

/// A pipeline of stages (possibly empty = passthrough).
#[derive(Clone, Debug, Default)]
pub struct Filter {
    stages: Vec<FilterStage>,
}

impl Filter {
    pub fn passthrough() -> Self {
        Filter { stages: Vec::new() }
    }

    pub fn new(stages: Vec<FilterStage>) -> Self {
        Filter { stages }
    }

    pub fn is_passthrough(&self) -> bool {
        self.stages.is_empty()
    }

    /// The stage list — consumed when the broker folds this filter
    /// into a `StagesConfig` (ISSUE 6).
    pub fn into_stages(self) -> Vec<FilterStage> {
        self.stages
    }

    /// Apply all stages; returns the (possibly new) shape and data.
    pub fn apply(&self, shape: &[u32], data: &[f32]) -> Result<(Vec<u32>, Vec<f32>)> {
        let expect: usize = shape.iter().map(|&d| d as usize).product();
        ensure!(
            expect == data.len(),
            "filter: shape {shape:?} does not match data len {}",
            data.len()
        );
        if self.stages.is_empty() {
            return Ok((shape.to_vec(), data.to_vec()));
        }
        let mut shape = shape.to_vec();
        let mut data = data.to_vec();
        for stage in &self.stages {
            (shape, data) = apply_stage(stage, shape, data)?;
        }
        Ok((shape, data))
    }
}

fn apply_stage(
    stage: &FilterStage,
    shape: Vec<u32>,
    data: Vec<f32>,
) -> Result<(Vec<u32>, Vec<f32>)> {
    match *stage {
        FilterStage::Stride(k) => {
            ensure!(k > 0, "stride must be > 0");
            let out: Vec<f32> = data.iter().copied().step_by(k).collect();
            Ok((vec![out.len() as u32], out))
        }
        FilterStage::Magnitude => {
            if shape.first() != Some(&2) {
                bail!("Magnitude stage expects a leading axis of 2, got {shape:?}");
            }
            let plane: usize = shape[1..].iter().map(|&d| d as usize).product();
            let (ux, uy) = data.split_at(plane);
            let out: Vec<f32> = ux
                .iter()
                .zip(uy)
                .map(|(&x, &y)| (x * x + y * y).sqrt())
                .collect();
            Ok((shape[1..].to_vec(), out))
        }
        FilterStage::Clamp(lo, hi) => {
            ensure!(lo <= hi, "clamp: lo > hi");
            let out = data.into_iter().map(|v| v.clamp(lo, hi)).collect();
            Ok((shape, out))
        }
        FilterStage::Threshold(t) => {
            let out = data
                .into_iter()
                .map(|v| if v.abs() >= t { v } else { 0.0 })
                .collect();
            Ok((shape, out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_is_identity() {
        let f = Filter::passthrough();
        let (s, d) = f.apply(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(s, vec![2, 3]);
        assert_eq!(d, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn stride_subsamples() {
        let f = Filter::new(vec![FilterStage::Stride(3)]);
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (s, d) = f.apply(&[10], &data).unwrap();
        assert_eq!(s, vec![4]);
        assert_eq!(d, vec![0., 3., 6., 9.]);
    }

    #[test]
    fn magnitude_collapses_components() {
        let f = Filter::new(vec![FilterStage::Magnitude]);
        // ux = [3, 0], uy = [4, 1]
        let (s, d) = f.apply(&[2, 2, 1], &[3., 0., 4., 1.]).unwrap();
        assert_eq!(s, vec![2, 1]);
        assert!((d[0] - 5.0).abs() < 1e-6);
        assert!((d[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn magnitude_requires_component_axis() {
        let f = Filter::new(vec![FilterStage::Magnitude]);
        assert!(f.apply(&[3, 2], &[0.; 6]).is_err());
    }

    #[test]
    fn clamp_and_threshold() {
        let f = Filter::new(vec![
            FilterStage::Clamp(-1.0, 1.0),
            FilterStage::Threshold(0.5),
        ]);
        let (_, d) = f.apply(&[4], &[2.0, 0.2, -0.7, -3.0]).unwrap();
        assert_eq!(d, vec![1.0, 0.0, -0.7, -1.0]);
    }

    #[test]
    fn stages_compose_in_order() {
        // magnitude then stride: shapes must thread through correctly
        let f = Filter::new(vec![FilterStage::Magnitude, FilterStage::Stride(2)]);
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let (s, d) = f.apply(&[2, 4], &data).unwrap();
        assert_eq!(s, vec![2]);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let f = Filter::passthrough();
        assert!(f.apply(&[3], &[1.0, 2.0]).is_err());
    }
}
