//! Process-group → endpoint mapping (the paper's Fig 1).
//!
//! Ranks are divided into contiguous groups of `group_size`; group `g`
//! registers with endpoint `g % n_endpoints`.  The modulo lets users run
//! fewer endpoints than groups (several groups share an endpoint) or
//! exactly one per group (the paper's 16:1 ratio).

use anyhow::{ensure, Result};

/// Immutable rank/group/endpoint topology.
#[derive(Clone, Debug)]
pub struct GroupMap {
    total_ranks: usize,
    group_size: usize,
    n_endpoints: usize,
}

impl GroupMap {
    pub fn new(total_ranks: usize, group_size: usize, n_endpoints: usize) -> Result<Self> {
        ensure!(total_ranks > 0, "total_ranks must be > 0");
        ensure!(group_size > 0, "group_size must be > 0");
        ensure!(n_endpoints > 0, "need at least one endpoint");
        Ok(GroupMap {
            total_ranks,
            group_size,
            n_endpoints,
        })
    }

    pub fn total_ranks(&self) -> usize {
        self.total_ranks
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Number of groups (last one may be partial).
    pub fn n_groups(&self) -> usize {
        (self.total_ranks + self.group_size - 1) / self.group_size
    }

    /// Group of a rank (the paper's `group_id`).
    pub fn group_of_rank(&self, rank: usize) -> Result<usize> {
        ensure!(
            rank < self.total_ranks,
            "rank {rank} out of range 0..{}",
            self.total_ranks
        );
        Ok(rank / self.group_size)
    }

    /// Endpoint index a rank writes to.
    pub fn endpoint_of_rank(&self, rank: usize) -> Result<usize> {
        Ok(self.group_of_rank(rank)? % self.n_endpoints)
    }

    /// All ranks of a group.
    pub fn ranks_of_group(&self, group: usize) -> Vec<usize> {
        let lo = group * self.group_size;
        let hi = ((group + 1) * self.group_size).min(self.total_ranks);
        (lo..hi).collect()
    }

    /// All stream keys an endpoint will receive for a field (used by the
    /// Cloud side to subscribe to exactly its share of the streams).
    pub fn streams_of_endpoint(&self, endpoint: usize, field: &str) -> Vec<String> {
        (0..self.total_ranks)
            .filter(|&r| self.endpoint_of_rank(r).unwrap() == endpoint)
            .map(|r| crate::record::stream_key(field, r as u32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Gen, U64Range};
    use crate::util::rng::Rng;

    #[test]
    fn paper_topology_16_to_1() {
        // 32 ranks, groups of 16, 2 endpoints (the paper's Fig 1 shape).
        let g = GroupMap::new(32, 16, 2).unwrap();
        assert_eq!(g.n_groups(), 2);
        for r in 0..16 {
            assert_eq!(g.endpoint_of_rank(r).unwrap(), 0);
        }
        for r in 16..32 {
            assert_eq!(g.endpoint_of_rank(r).unwrap(), 1);
        }
    }

    #[test]
    fn groups_share_endpoints_when_fewer() {
        let g = GroupMap::new(64, 16, 2).unwrap();
        assert_eq!(g.n_groups(), 4);
        assert_eq!(g.endpoint_of_rank(0).unwrap(), 0);
        assert_eq!(g.endpoint_of_rank(16).unwrap(), 1);
        assert_eq!(g.endpoint_of_rank(32).unwrap(), 0);
        assert_eq!(g.endpoint_of_rank(48).unwrap(), 1);
    }

    #[test]
    fn partial_last_group() {
        let g = GroupMap::new(10, 4, 3).unwrap();
        assert_eq!(g.n_groups(), 3);
        assert_eq!(g.ranks_of_group(2), vec![8, 9]);
    }

    #[test]
    fn out_of_range_rank_rejected() {
        let g = GroupMap::new(8, 4, 1).unwrap();
        assert!(g.group_of_rank(8).is_err());
        assert!(g.endpoint_of_rank(100).is_err());
    }

    #[test]
    fn degenerate_configs_rejected() {
        assert!(GroupMap::new(0, 4, 1).is_err());
        assert!(GroupMap::new(4, 0, 1).is_err());
        assert!(GroupMap::new(4, 4, 0).is_err());
    }

    #[test]
    fn streams_of_endpoint_lists_exactly_its_ranks() {
        let g = GroupMap::new(8, 4, 2).unwrap();
        assert_eq!(
            g.streams_of_endpoint(0, "u"),
            vec!["u/0", "u/1", "u/2", "u/3"]
        );
        assert_eq!(
            g.streams_of_endpoint(1, "u"),
            vec!["u/4", "u/5", "u/6", "u/7"]
        );
    }

    /// Properties from DESIGN.md §7: every rank maps to exactly one
    /// endpoint; groups partition the rank set; endpoint load is
    /// balanced to within one group; the union of per-endpoint stream
    /// sets covers every rank exactly once.
    #[test]
    fn prop_mapping_invariants() {
        struct Topo;
        impl Gen for Topo {
            type Value = (u64, u64, u64);
            fn generate(&self, rng: &mut Rng) -> Self::Value {
                (
                    1 + rng.next_below(200),
                    1 + rng.next_below(32),
                    1 + rng.next_below(8),
                )
            }
        }
        prop::forall(0xF16, 300, &Topo, |&(ranks, gsize, neps)| {
            let g = GroupMap::new(ranks as usize, gsize as usize, neps as usize)
                .map_err(|e| e.to_string())?;
            // partition: every rank in exactly one group, contiguous
            let mut seen = vec![false; ranks as usize];
            for grp in 0..g.n_groups() {
                for r in g.ranks_of_group(grp) {
                    if seen[r] {
                        return Err(format!("rank {r} in two groups"));
                    }
                    seen[r] = true;
                    if g.group_of_rank(r).unwrap() != grp {
                        return Err(format!("rank {r} group mismatch"));
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("uncovered rank".into());
            }
            // endpoint load balance: counts differ by at most one group
            let mut load = vec![0usize; neps as usize];
            for r in 0..ranks as usize {
                load[g.endpoint_of_rank(r).unwrap()] += 1;
            }
            let max = *load.iter().max().unwrap();
            let min = *load.iter().min().unwrap();
            if max - min > gsize as usize {
                return Err(format!("imbalance {max}-{min} > group size {gsize}"));
            }
            // stream cover: union over endpoints = all ranks, disjoint
            let mut covered = vec![false; ranks as usize];
            for e in 0..neps as usize {
                for key in g.streams_of_endpoint(e, "u") {
                    let (_, r) = crate::record::parse_stream_key(&key).unwrap();
                    if covered[r as usize] {
                        return Err(format!("rank {r} streamed to two endpoints"));
                    }
                    covered[r as usize] = true;
                }
            }
            if !covered.iter().all(|&c| c) {
                return Err("rank missing from endpoint streams".into());
            }
            Ok(())
        });
        let _ = U64Range(0, 0); // keep import used
    }
}
