//! Incremental RESP2 decoder.
//!
//! Bytes are appended with [`Decoder::feed`]; [`Decoder::next`] returns
//! `Ok(Some(value))` when a complete value is buffered, `Ok(None)` when
//! more bytes are needed, and `Err` on protocol violations.  Consumed
//! bytes are compacted lazily so long-lived connections don't grow the
//! buffer unboundedly.

use anyhow::{bail, Result};

use super::Value;

/// Streaming RESP2 parser.
#[derive(Default)]
pub struct Decoder {
    buf: Vec<u8>,
    /// Read cursor into `buf` (bytes before it are consumed).
    pos: usize,
    /// Re-parse gate: a failed parse records how many pending bytes it
    /// will take before another attempt can possibly succeed (known
    /// exactly when the failure is inside a length-prefixed bulk).
    /// Without this, feeding a multi-megabyte XREAD reply in socket
    /// sized chunks makes parsing O(n²) — measured as the Cloud-ingest
    /// bottleneck in EXPERIMENTS.md §Perf.
    min_pending: usize,
}

/// Refuse absurd sizes early (protects the endpoint from hostile or
/// corrupt frames).  512 MiB mirrors Redis's proto-max-bulk-len.
const MAX_BULK: i64 = 512 * 1024 * 1024;
const MAX_ARRAY: i64 = 16 * 1024 * 1024;

impl Decoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw socket bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Compact when more than half the buffer is consumed prefix.
        if self.pos > 4096 && self.pos * 2 > self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered but not yet consumed.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Try to decode the next complete value.
    pub fn next(&mut self) -> Result<Option<Value>> {
        if self.pending() < self.min_pending {
            return Ok(None); // a retry cannot succeed yet
        }
        let mut cursor = self.pos;
        let mut need = self.buf.len() + 1; // absolute index required to retry
        match parse_value(&self.buf, &mut cursor, &mut need)? {
            Some(v) => {
                self.pos = cursor;
                self.min_pending = 0;
                Ok(Some(v))
            }
            None => {
                self.min_pending = need.saturating_sub(self.pos).max(self.pending() + 1);
                Ok(None)
            }
        }
    }
}

/// Find `\r\n` starting at `*cursor`; return the line body and advance.
fn parse_line<'a>(buf: &'a [u8], cursor: &mut usize) -> Option<&'a [u8]> {
    let start = *cursor;
    let hay = &buf[start..];
    let idx = hay.windows(2).position(|w| w == b"\r\n")?;
    *cursor = start + idx + 2;
    Some(&hay[..idx])
}

fn parse_int(line: &[u8]) -> Result<i64> {
    let s = std::str::from_utf8(line)?;
    Ok(s.trim().parse::<i64>()?)
}

/// Parse one value at `*cursor`.  On incomplete input returns
/// `Ok(None)` and sets `need` to the smallest absolute buffer length at
/// which a retry could possibly succeed (exact for length-prefixed
/// bulks, `buf.len() + 1` otherwise).
fn parse_value(buf: &[u8], cursor: &mut usize, need: &mut usize) -> Result<Option<Value>> {
    if *cursor >= buf.len() {
        *need = buf.len() + 1;
        return Ok(None);
    }
    let tag = buf[*cursor];
    let mut c = *cursor + 1;
    let v = match tag {
        b'+' => match parse_line(buf, &mut c) {
            Some(line) => Value::Simple(String::from_utf8_lossy(line).into_owned()),
            None => {
                *need = buf.len() + 1;
                return Ok(None);
            }
        },
        b'-' => match parse_line(buf, &mut c) {
            Some(line) => Value::Error(String::from_utf8_lossy(line).into_owned()),
            None => {
                *need = buf.len() + 1;
                return Ok(None);
            }
        },
        b':' => match parse_line(buf, &mut c) {
            Some(line) => Value::Int(parse_int(line)?),
            None => {
                *need = buf.len() + 1;
                return Ok(None);
            }
        },
        b'$' => {
            let len = match parse_line(buf, &mut c) {
                Some(line) => parse_int(line)?,
                None => {
                    *need = buf.len() + 1;
                    return Ok(None);
                }
            };
            if len == -1 {
                Value::NullBulk
            } else {
                if len < 0 || len > MAX_BULK {
                    bail!("invalid bulk length {len}");
                }
                let len = len as usize;
                if buf.len() < c + len + 2 {
                    *need = c + len + 2; // exact requirement
                    return Ok(None);
                }
                if &buf[c + len..c + len + 2] != b"\r\n" {
                    bail!("bulk string missing CRLF terminator");
                }
                let body = buf[c..c + len].to_vec();
                c += len + 2;
                Value::Bulk(body)
            }
        }
        b'*' => {
            let len = match parse_line(buf, &mut c) {
                Some(line) => parse_int(line)?,
                None => {
                    *need = buf.len() + 1;
                    return Ok(None);
                }
            };
            if len == -1 {
                Value::NullArray
            } else {
                if len < 0 || len > MAX_ARRAY {
                    bail!("invalid array length {len}");
                }
                let mut items = Vec::with_capacity((len as usize).min(1024));
                for _ in 0..len {
                    match parse_value(buf, &mut c, need)? {
                        Some(item) => items.push(item),
                        None => return Ok(None),
                    }
                }
                Value::Array(items)
            }
        }
        other => bail!("invalid RESP type byte 0x{other:02x}"),
    };
    *cursor = c;
    Ok(Some(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_then_complete() {
        let mut d = Decoder::new();
        d.feed(b"$5\r\nhel");
        assert!(d.next().unwrap().is_none());
        d.feed(b"lo\r\n");
        assert_eq!(d.next().unwrap().unwrap(), Value::Bulk(b"hello".to_vec()));
    }

    #[test]
    fn pipelined_values() {
        let mut d = Decoder::new();
        d.feed(b"+OK\r\n:7\r\n$-1\r\n");
        assert_eq!(d.next().unwrap().unwrap(), Value::Simple("OK".into()));
        assert_eq!(d.next().unwrap().unwrap(), Value::Int(7));
        assert_eq!(d.next().unwrap().unwrap(), Value::NullBulk);
        assert!(d.next().unwrap().is_none());
    }

    #[test]
    fn rejects_bad_type_byte() {
        let mut d = Decoder::new();
        d.feed(b"#nope\r\n");
        assert!(d.next().is_err());
    }

    #[test]
    fn rejects_oversized_bulk() {
        let mut d = Decoder::new();
        d.feed(b"$999999999999\r\n");
        assert!(d.next().is_err());
    }

    #[test]
    fn rejects_missing_bulk_terminator() {
        let mut d = Decoder::new();
        d.feed(b"$3\r\nabcXY");
        assert!(d.next().is_err());
    }

    #[test]
    fn nested_array_incremental() {
        let mut d = Decoder::new();
        let wire = b"*2\r\n*1\r\n:1\r\n$2\r\nab\r\n";
        for chunk in wire.chunks(3) {
            d.feed(chunk);
        }
        assert_eq!(
            d.next().unwrap().unwrap(),
            Value::Array(vec![
                Value::Array(vec![Value::Int(1)]),
                Value::Bulk(b"ab".to_vec())
            ])
        );
    }

    #[test]
    fn compaction_keeps_pending_bytes() {
        let mut d = Decoder::new();
        // push enough consumed traffic to trigger compaction
        for _ in 0..2000 {
            d.feed(b"+OK\r\n");
            assert_eq!(d.next().unwrap().unwrap(), Value::Simple("OK".into()));
        }
        d.feed(b"$3\r\nab"); // partial across a compaction boundary
        assert!(d.next().unwrap().is_none());
        d.feed(b"c\r\n");
        assert_eq!(d.next().unwrap().unwrap(), Value::Bulk(b"abc".to_vec()));
        assert_eq!(d.pending(), 0);
    }
}
