//! RESP2 wire protocol (the Redis serialization protocol).
//!
//! The paper's Cloud endpoints are Redis 5 servers; our [`crate::endpoint`]
//! speaks the same protocol so the data model and framing on the wire are
//! preserved.  This module is a self-contained codec:
//!
//! * [`Value`] — the RESP2 value model,
//! * [`encode`] / [`encode_command`] — serialization,
//! * [`Decoder`] — an incremental (streaming) parser that consumes bytes
//!   as they arrive from a socket.

mod decode;

pub use decode::Decoder;

use std::fmt;

/// A RESP2 protocol value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Value {
    /// `+OK\r\n`
    Simple(String),
    /// `-ERR message\r\n`
    Error(String),
    /// `:42\r\n`
    Int(i64),
    /// `$5\r\nhello\r\n`
    Bulk(Vec<u8>),
    /// `$-1\r\n`
    NullBulk,
    /// `*2\r\n...`
    Array(Vec<Value>),
    /// `*-1\r\n`
    NullArray,
}

impl Value {
    /// Bulk string from anything byte-like.
    pub fn bulk(b: impl Into<Vec<u8>>) -> Value {
        Value::Bulk(b.into())
    }

    /// Borrow as bytes if this is a bulk or simple string.
    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Value::Bulk(b) => Some(b),
            Value::Simple(s) => Some(s.as_bytes()),
            _ => None,
        }
    }

    /// Lossy string view (diagnostics; error replies yield their message).
    pub fn as_str_lossy(&self) -> String {
        match self {
            Value::Error(e) => e.clone(),
            other => match other.as_bytes() {
                Some(b) => String::from_utf8_lossy(b).into_owned(),
                None => format!("{other:?}"),
            },
        }
    }

    /// Integer view (accepts `Int` and numeric bulk strings, like Redis).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Bulk(b) => std::str::from_utf8(b).ok()?.parse().ok(),
            Value::Simple(s) => s.parse().ok(),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// True if this is a protocol-level error reply.
    pub fn is_error(&self) -> bool {
        matches!(self, Value::Error(_))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Simple(s) => write!(f, "+{s}"),
            Value::Error(e) => write!(f, "-{e}"),
            Value::Int(i) => write!(f, ":{i}"),
            Value::Bulk(b) => write!(f, "\"{}\"", String::from_utf8_lossy(b)),
            Value::NullBulk => write!(f, "(nil)"),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::NullArray => write!(f, "(nil array)"),
        }
    }
}

/// Decimal digit count of an unsigned value.
fn dec_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 10 {
        v /= 10;
        n += 1;
    }
    n
}

/// Decimal length of a signed value (sign included).
fn int_len(v: i64) -> usize {
    if v < 0 {
        1 + dec_len(v.unsigned_abs())
    } else {
        dec_len(v as u64)
    }
}

/// Exact serialized size of a value on the wire.
pub fn encoded_len(v: &Value) -> usize {
    match v {
        Value::Simple(s) => 1 + s.len() + 2,
        Value::Error(e) => 1 + e.len() + 2,
        Value::Int(i) => 1 + int_len(*i) + 2,
        Value::Bulk(b) => 1 + dec_len(b.len() as u64) + 2 + b.len() + 2,
        Value::NullBulk => 5,
        Value::Array(items) => {
            1 + dec_len(items.len() as u64)
                + 2
                + items.iter().map(encoded_len).sum::<usize>()
        }
        Value::NullArray => 5,
    }
}

/// Serialize a value into `out`.  The exact frame length is computed
/// first and reserved in one step, so big frames (endpoint XREAD
/// replies carrying whole snapshot payloads) never reallocate
/// mid-encode.
pub fn encode(v: &Value, out: &mut Vec<u8>) {
    out.reserve(encoded_len(v));
    encode_raw(v, out);
}

fn encode_raw(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Simple(s) => {
            out.push(b'+');
            out.extend_from_slice(s.as_bytes());
            out.extend_from_slice(b"\r\n");
        }
        Value::Error(e) => {
            out.push(b'-');
            out.extend_from_slice(e.as_bytes());
            out.extend_from_slice(b"\r\n");
        }
        Value::Int(i) => {
            out.push(b':');
            out.extend_from_slice(i.to_string().as_bytes());
            out.extend_from_slice(b"\r\n");
        }
        Value::Bulk(b) => {
            out.push(b'$');
            out.extend_from_slice(b.len().to_string().as_bytes());
            out.extend_from_slice(b"\r\n");
            out.extend_from_slice(b);
            out.extend_from_slice(b"\r\n");
        }
        Value::NullBulk => out.extend_from_slice(b"$-1\r\n"),
        Value::Array(items) => {
            out.push(b'*');
            out.extend_from_slice(items.len().to_string().as_bytes());
            out.extend_from_slice(b"\r\n");
            for item in items {
                encode_raw(item, out);
            }
        }
        Value::NullArray => out.extend_from_slice(b"*-1\r\n"),
    }
}

/// Exact serialized size of a client command (array of bulk strings).
pub fn command_len(parts: &[&[u8]]) -> usize {
    let mut n = 1 + dec_len(parts.len() as u64) + 2;
    for p in parts {
        n += 1 + dec_len(p.len() as u64) + 2 + p.len() + 2;
    }
    n
}

/// Serialize a client command (array of bulk strings) — what Redis
/// clients put on the wire.  Reserves the exact frame length up front:
/// the broker's pipelined XADD batches append many commands into one
/// buffer and must not reallocate mid-encode on the hot path.
pub fn encode_command(parts: &[&[u8]], out: &mut Vec<u8>) {
    out.reserve(command_len(parts));
    out.push(b'*');
    out.extend_from_slice(parts.len().to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
    for p in parts {
        out.push(b'$');
        out.extend_from_slice(p.len().to_string().as_bytes());
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(p);
        out.extend_from_slice(b"\r\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, Bytes, Gen, U64Range};
    use crate::util::rng::Rng;

    fn roundtrip(v: &Value) -> Value {
        let mut buf = Vec::new();
        encode(v, &mut buf);
        let mut dec = Decoder::new();
        dec.feed(&buf);
        let got = dec.next().expect("decode").expect("complete value");
        assert!(dec.next().expect("no trailing").is_none());
        got
    }

    #[test]
    fn roundtrip_scalars() {
        for v in [
            Value::Simple("OK".into()),
            Value::Error("ERR boom".into()),
            Value::Int(0),
            Value::Int(-123456789),
            Value::Bulk(b"hello".to_vec()),
            Value::Bulk(Vec::new()),
            Value::NullBulk,
            Value::NullArray,
        ] {
            assert_eq!(roundtrip(&v), v);
        }
    }

    #[test]
    fn roundtrip_nested_arrays() {
        let v = Value::Array(vec![
            Value::Int(1),
            Value::Array(vec![Value::bulk("a"), Value::NullBulk]),
            Value::Simple("x".into()),
        ]);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn bulk_with_crlf_payload_roundtrips() {
        // length-prefixed framing must not care about \r\n in payloads
        let v = Value::Bulk(b"a\r\nb\r\n".to_vec());
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn encode_command_shape() {
        let mut buf = Vec::new();
        encode_command(&[b"PING"], &mut buf);
        assert_eq!(buf, b"*1\r\n$4\r\nPING\r\n");
    }

    /// Property: `encoded_len`/`command_len` predict the exact byte
    /// count, so a single up-front reserve suffices (no reallocation
    /// mid-encode).
    #[test]
    fn prop_encoded_len_is_exact() {
        prop::forall(0x1E4, 150, &U64Range(0, u64::MAX / 2), |seed| {
            let mut rng = Rng::new(*seed);
            let v = gen_value(&mut rng, 3);
            let want = encoded_len(&v);
            let mut buf = Vec::new();
            encode(&v, &mut buf);
            if buf.len() != want {
                return Err(format!("encoded_len {want} != actual {} for {v:?}", buf.len()));
            }
            if buf.capacity() > want.max(8) * 2 {
                return Err(format!(
                    "over-allocated: cap {} for len {want}",
                    buf.capacity()
                ));
            }
            Ok(())
        });
        // negative ints exercise int_len's sign branch
        for i in [i64::MIN, -1_000_000, -1, 0, 9, 10, i64::MAX] {
            let v = Value::Int(i);
            let mut buf = Vec::new();
            encode(&v, &mut buf);
            assert_eq!(buf.len(), encoded_len(&v), "int {i}");
        }
    }

    #[test]
    fn command_len_is_exact() {
        let cases: Vec<Vec<&[u8]>> = vec![
            vec![b"PING"],
            vec![b"XADD", b"u/0", b"*", b"r", &[0u8; 300]],
            vec![b""],
        ];
        for parts in cases {
            let mut buf = Vec::new();
            encode_command(&parts, &mut buf);
            assert_eq!(buf.len(), command_len(&parts));
            // the reserve covered the whole frame: capacity was set
            // once, before any bytes were written
            assert!(buf.capacity() >= buf.len());
        }
    }

    /// Property: arbitrary bulk payloads + ints survive a roundtrip even
    /// when fed to the decoder one byte at a time.
    #[test]
    fn prop_roundtrip_byte_at_a_time() {
        let gen = prop::Pair(Bytes(64), U64Range(0, u64::MAX / 2));
        prop::forall(0xEB, 200, &gen, |(payload, n)| {
            let v = Value::Array(vec![
                Value::Bulk(payload.clone()),
                Value::Int(*n as i64),
            ]);
            let mut buf = Vec::new();
            encode(&v, &mut buf);
            let mut dec = Decoder::new();
            for b in &buf {
                dec.feed(std::slice::from_ref(b));
            }
            match dec.next() {
                Ok(Some(got)) if got == v => Ok(()),
                other => Err(format!("got {other:?}")),
            }
        });
    }

    /// Random RESP value trees (bounded depth/width), for the nested
    /// roundtrip property below.
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        let choice = if depth == 0 {
            rng.next_below(6) // scalars only at the leaves
        } else {
            rng.next_below(8)
        };
        match choice {
            0 => Value::Simple(format!("s{}", rng.next_below(1000))),
            1 => Value::Error(format!("ERR e{}", rng.next_below(1000))),
            2 => Value::Int(rng.next_u64() as i64),
            3 => {
                let len = rng.next_below(64) as usize;
                Value::Bulk((0..len).map(|_| rng.next_u64() as u8).collect())
            }
            4 => Value::NullBulk,
            5 => Value::NullArray,
            _ => {
                let len = rng.next_below(5) as usize;
                Value::Array((0..len).map(|_| gen_value(rng, depth - 1)).collect())
            }
        }
    }

    /// Property: arbitrary nested value trees roundtrip exactly, both in
    /// one feed and byte-at-a-time.
    #[test]
    fn prop_roundtrip_nested_trees() {
        prop::forall(0x17EE, 150, &U64Range(0, u64::MAX / 2), |seed| {
            let mut rng = Rng::new(*seed);
            let v = gen_value(&mut rng, 3);
            let mut buf = Vec::new();
            encode(&v, &mut buf);
            // whole-buffer feed
            let mut dec = Decoder::new();
            dec.feed(&buf);
            match dec.next() {
                Ok(Some(got)) if got == v => {}
                other => return Err(format!("bulk feed: got {other:?} want {v:?}")),
            }
            // byte-at-a-time feed
            let mut dec = Decoder::new();
            for b in &buf {
                dec.feed(std::slice::from_ref(b));
            }
            match dec.next() {
                Ok(Some(got)) if got == v => Ok(()),
                other => Err(format!("trickle feed: got {other:?} want {v:?}")),
            }
        });
    }

    /// Property: any strict prefix of a valid encoding is "incomplete"
    /// (`Ok(None)`), never a protocol error — truncation must be
    /// recoverable when the rest of the bytes arrive.
    #[test]
    fn prop_truncation_is_incomplete_not_error() {
        prop::forall(0x7A11, 60, &U64Range(0, u64::MAX / 2), |seed| {
            let mut rng = Rng::new(*seed);
            let v = gen_value(&mut rng, 2);
            let mut buf = Vec::new();
            encode(&v, &mut buf);
            for cut in 0..buf.len() {
                let mut dec = Decoder::new();
                dec.feed(&buf[..cut]);
                match dec.next() {
                    Ok(None) => {}
                    Ok(Some(got)) => {
                        return Err(format!(
                            "{cut}-byte prefix of {v:?} decoded to {got:?}"
                        ))
                    }
                    Err(e) => return Err(format!("{cut}-byte prefix errored: {e}")),
                }
                // feeding the remainder must complete the value
                dec.feed(&buf[cut..]);
                match dec.next() {
                    Ok(Some(got)) if got == v => {}
                    other => return Err(format!("resume at {cut}: {other:?}")),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bulk_edge_sizes_roundtrip() {
        for len in [0usize, 1, 2, 511, 512, 513] {
            let v = Value::Bulk(vec![0xAB; len]);
            assert_eq!(roundtrip(&v), v, "len {len}");
        }
    }

    #[test]
    fn bulk_length_clamp_at_512mib() {
        // One past Redis's proto-max-bulk-len: rejected at the header,
        // before any payload allocation.
        let mut d = Decoder::new();
        d.feed(format!("${}\r\n", 512 * 1024 * 1024 + 1).as_bytes());
        assert!(d.next().is_err());
        // Exactly the cap is a legal header: decoder just wants bytes.
        let mut d = Decoder::new();
        d.feed(format!("${}\r\n", 512 * 1024 * 1024).as_bytes());
        assert!(d.next().unwrap().is_none());
        // Negative lengths other than -1 are protocol errors.
        let mut d = Decoder::new();
        d.feed(b"$-2\r\n");
        assert!(d.next().is_err());
    }

    #[test]
    fn array_length_clamp() {
        let mut d = Decoder::new();
        d.feed(format!("*{}\r\n", 16 * 1024 * 1024 + 1).as_bytes());
        assert!(d.next().is_err());
        let mut d = Decoder::new();
        d.feed(b"*-2\r\n");
        assert!(d.next().is_err());
    }

    #[test]
    fn crlf_violations_rejected() {
        // bulk body not followed by CRLF
        let mut d = Decoder::new();
        d.feed(b"$3\r\nabcde\r\n");
        assert!(d.next().is_err());
        // integer line with junk
        let mut d = Decoder::new();
        d.feed(b":12a\r\n");
        assert!(d.next().is_err());
    }

    /// Property: random byte soup never panics the decoder (it may error).
    #[test]
    fn prop_decoder_never_panics_on_garbage() {
        let gen = Bytes(256);
        let mut rng = Rng::new(99);
        for _ in 0..500 {
            let junk = gen.generate(&mut rng);
            let mut dec = Decoder::new();
            dec.feed(&junk);
            // drain until error or exhaustion; must not loop forever
            for _ in 0..600 {
                match dec.next() {
                    Ok(Some(_)) => continue,
                    Ok(None) | Err(_) => break,
                }
            }
        }
    }
}
