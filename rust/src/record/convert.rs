//! Lossy format conversion for staged stream records (ISSUE 5).
//!
//! Two encodings below raw little-endian f32, both with a *measured*
//! error bound carried in the frame header ([`super::FrameMeta`]) so
//! the Cloud side knows exactly how far a decoded snapshot can sit
//! from the original:
//!
//! * **f16** ([`encode_f16`]/[`decode_f16`]) — IEEE 754 binary16 with
//!   round-to-nearest-even, implemented by bit manipulation (no `half`
//!   crate in the offline set).  Relative precision ~2⁻¹¹; the encoder
//!   reports the actual max absolute error it introduced.
//! * **quantized delta** ([`encode_qdelta`]/[`decode_qdelta`]) —
//!   uniform quantization to multiples of a configured step (absolute
//!   error ≤ step/2), then first-order delta + zigzag + LEB128-style
//!   varint.  Smooth fields quantize to tiny deltas that fit one byte,
//!   and the downstream LZ pass collapses the rest.
//!
//! Both decoders are fully bounds-checked: corrupt input returns an
//! error, never a panic (the record CRC normally rejects it first).

use anyhow::{ensure, Result};

/// Wire tag of the element encoding of a staged frame's payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Encoding {
    /// Raw little-endian f32 (lossless).
    #[default]
    F32 = 0,
    /// IEEE 754 binary16.
    F16 = 1,
    /// Quantized first-order delta with varint packing.
    QDelta = 2,
}

impl Encoding {
    pub fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(Encoding::F32),
            1 => Ok(Encoding::F16),
            2 => Ok(Encoding::QDelta),
            other => anyhow::bail!("unknown encoding tag {other}"),
        }
    }

    /// Parse the config/CLI spelling (`f32` | `f16` | `qdelta`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Encoding::F32),
            "f16" => Ok(Encoding::F16),
            "qdelta" => Ok(Encoding::QDelta),
            other => anyhow::bail!("unknown encoding '{other}' (f32|f16|qdelta)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Encoding::F32 => "f32",
            Encoding::F16 => "f16",
            Encoding::QDelta => "qdelta",
        }
    }

    /// Width in bytes of one encoded element, for the byte-shuffle
    /// pass; 1 (identity shuffle) for variable-length encodings.
    pub fn elem_size(self) -> usize {
        match self {
            Encoding::F32 => 4,
            Encoding::F16 => 2,
            Encoding::QDelta => 1,
        }
    }

    /// Whether decode(encode(x)) == x bit-exactly.
    pub fn is_lossless(self) -> bool {
        self == Encoding::F32
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN (keep NaN signalling as a quiet payload bit)
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // normal half: 10 mantissa bits, round on the 13 dropped
        let mut h = (((unbiased + 15) as u32) << 10) | (man >> 13);
        let round = man & 0x1FFF;
        if round > 0x1000 || (round == 0x1000 && h & 1 == 1) {
            h += 1; // may carry into the exponent — that is correct
        }
        return sign | h as u16;
    }
    if unbiased < -25 {
        return sign; // underflow → ±0
    }
    // subnormal half: shift the (implicit-bit-extended) mantissa down
    let man = man | 0x0080_0000;
    let shift = (13 + (-14 - unbiased)) as u32;
    let mut h = man >> shift;
    let rem = man & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && h & 1 == 1) {
        h += 1;
    }
    sign | h as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 31 {
        sign | 0x7F80_0000 | (man << 13) // inf / nan
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: value = man × 2⁻²⁴; normalize for f32
            let k = 31 - man.leading_zeros(); // man ≤ 0x3FF → k ∈ 0..=9
            let r = man & !(1u32 << k);
            let exp32 = (k as i32 - 24 + 127) as u32;
            sign | (exp32 << 23) | (r << (23 - k))
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Encode to packed little-endian f16; returns the bytes and the
/// actual max absolute error introduced.  A *finite* input outside the
/// f16 range (|v| > 65504) would saturate to ±inf with an unbounded
/// error, which would make the frame's stated bound a lie — that is
/// rejected as an error, exactly like the qdelta quantizer-range
/// check.  Non-finite inputs (NaN/±inf) pass through faithfully and
/// do not contribute to the bound (the analysis side already skips
/// non-finite windows).
pub fn encode_f16(data: &[f32]) -> Result<(Vec<u8>, f32)> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut max_err = 0f32;
    for &v in data {
        let h = f32_to_f16_bits(v);
        let back = f16_bits_to_f32(h);
        ensure!(
            back.is_finite() || !v.is_finite(),
            "f16: value {v} overflows the f16 range (max 65504)"
        );
        out.extend_from_slice(&h.to_le_bytes());
        let e = (back - v).abs();
        if e.is_finite() && e > max_err {
            max_err = e;
        }
    }
    Ok((out, max_err))
}

/// Reverse [`encode_f16`]; `n` is the element count from the frame
/// shape.
pub fn decode_f16(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    ensure!(
        bytes.len() == n * 2,
        "f16 payload {} bytes, expected {} for {n} elements",
        bytes.len(),
        n * 2
    );
    Ok(bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect())
}

#[inline]
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Quantize to multiples of `step` (absolute error ≤ step/2), then
/// delta + zigzag + varint encode.  Returns the bytes and the actual
/// max absolute error.  Fails on non-finite values and on values too
/// large for the quantizer range (the pipeline surfaces that as a
/// write error rather than silently corrupting the field).
pub fn encode_qdelta(data: &[f32], step: f32) -> Result<(Vec<u8>, f32)> {
    ensure!(
        step > 0.0 && step.is_finite(),
        "qdelta step must be a positive finite number, got {step}"
    );
    let inv = 1.0 / step as f64;
    let mut out = Vec::with_capacity(data.len());
    let mut prev: i64 = 0;
    let mut max_err = 0f32;
    for &v in data {
        ensure!(v.is_finite(), "qdelta: non-finite value {v}");
        let q = (v as f64 * inv).round();
        ensure!(
            q.abs() <= i32::MAX as f64,
            "qdelta: value {v} overflows the quantizer (step {step})"
        );
        let q = q as i64;
        let e = ((q as f64 * step as f64) as f32 - v).abs();
        if e > max_err {
            max_err = e;
        }
        write_varint(&mut out, zigzag(q - prev));
        prev = q;
    }
    Ok((out, max_err))
}

/// Reverse [`encode_qdelta`]; `n` is the element count from the frame
/// shape and `step` the quantization step from the frame header.
pub fn decode_qdelta(bytes: &[u8], n: usize, step: f32) -> Result<Vec<f32>> {
    ensure!(
        step > 0.0 && step.is_finite(),
        "qdelta step must be a positive finite number, got {step}"
    );
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    let mut prev: i64 = 0;
    for _ in 0..n {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            ensure!(pos < bytes.len(), "qdelta: truncated varint");
            ensure!(shift < 64, "qdelta: varint overflow");
            let b = bytes[pos];
            pos += 1;
            v |= ((b & 0x7F) as u64) << shift;
            shift += 7;
            if b & 0x80 == 0 {
                break;
            }
        }
        prev = prev.wrapping_add(unzigzag(v));
        out.push((prev as f64 * step as f64) as f32);
    }
    ensure!(
        pos == bytes.len(),
        "qdelta: {} trailing bytes after {n} elements",
        bytes.len() - pos
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn f16_special_values_roundtrip() {
        for (x, h) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (65504.0, 0x7BFF),        // max finite half
            (f32::INFINITY, 0x7C00),
            (f32::NEG_INFINITY, 0xFC00),
            (6.103_515_6e-5, 0x0400), // smallest normal half
            (5.960_464_5e-8, 0x0001), // smallest subnormal half
        ] {
            assert_eq!(f32_to_f16_bits(x), h, "encoding {x}");
            if x.is_finite() {
                assert_eq!(f16_bits_to_f32(h), x, "decoding 0x{h:04x}");
            }
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow beyond half range → inf
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xFC00);
        // underflow below subnormal range → signed zero
        assert_eq!(f32_to_f16_bits(1e-9), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000);
    }

    #[test]
    fn f16_error_is_bounded_and_reported() {
        let mut rng = Rng::new(7);
        let data: Vec<f32> = (0..2000)
            .map(|_| (rng.next_f64() * 20.0 - 10.0) as f32)
            .collect();
        let (bytes, max_err) = encode_f16(&data).unwrap();
        let back = decode_f16(&bytes, data.len()).unwrap();
        let mut worst = 0f32;
        for (a, b) in back.iter().zip(&data) {
            let e = (a - b).abs();
            // binary16 relative precision: ≤ 2⁻¹¹ of the magnitude
            assert!(e <= b.abs() * (1.0 / 2048.0) + 1e-7, "{b} → {a}");
            if e > worst {
                worst = e;
            }
        }
        assert!((worst - max_err).abs() < 1e-12, "reported bound {max_err} vs {worst}");
    }

    #[test]
    fn f16_subnormal_halves_roundtrip_exactly() {
        // every subnormal half value decodes and re-encodes to itself
        for h in 1u16..0x0400 {
            let x = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(x), h, "subnormal 0x{h:04x}");
        }
    }

    #[test]
    fn qdelta_bound_and_roundtrip() {
        let mut rng = Rng::new(99);
        let step = 1e-3f32;
        let data: Vec<f32> = (0..3000)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
            .collect();
        let (bytes, max_err) = encode_qdelta(&data, step).unwrap();
        assert!(max_err <= step / 2.0 + 1e-9, "err {max_err} over step/2");
        let back = decode_qdelta(&bytes, data.len(), step).unwrap();
        assert_eq!(back.len(), data.len());
        for (a, b) in back.iter().zip(&data) {
            assert!((a - b).abs() <= max_err + 1e-9, "{b} → {a}");
        }
        // smooth data packs into ~1 byte/elem
        let smooth: Vec<f32> = (0..3000).map(|i| (i as f32 * 1e-4).sin()).collect();
        let (bytes, _) = encode_qdelta(&smooth, step).unwrap();
        assert!(bytes.len() <= smooth.len() + 8, "smooth deltas should be 1 byte each");
    }

    #[test]
    fn f16_rejects_finite_overflow_but_passes_nonfinite() {
        // a finite value beyond f16 range would saturate to inf with an
        // unbounded error — rejected, so the stated bound stays honest
        assert!(encode_f16(&[1.0, 70000.0]).is_err());
        assert!(encode_f16(&[-1e9]).is_err());
        // genuine non-finite data passes through faithfully
        let (bytes, _) = encode_f16(&[f32::NAN, f32::INFINITY, 1.0]).unwrap();
        let back = decode_f16(&bytes, 3).unwrap();
        assert!(back[0].is_nan());
        assert_eq!(back[1], f32::INFINITY);
        assert_eq!(back[2], 1.0);
    }

    #[test]
    fn qdelta_rejects_bad_input() {
        assert!(encode_qdelta(&[1.0, f32::NAN], 1e-3).is_err());
        assert!(encode_qdelta(&[f32::INFINITY], 1e-3).is_err());
        assert!(encode_qdelta(&[1.0], 0.0).is_err());
        assert!(encode_qdelta(&[1e30], 1e-6).is_err(), "quantizer overflow");
        // decode: truncation and trailing garbage fail cleanly
        let (bytes, _) = encode_qdelta(&[0.5, -0.25, 0.125], 1e-3).unwrap();
        assert!(decode_qdelta(&bytes[..bytes.len() - 1], 3, 1e-3).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(decode_qdelta(&extra, 3, 1e-3).is_err());
        // every-byte-flip: error or wrong data, never a panic
        for i in 0..bytes.len() {
            let mut fuzzed = bytes.clone();
            fuzzed[i] ^= 0xFF;
            let _ = decode_qdelta(&fuzzed, 3, 1e-3);
        }
    }

    #[test]
    fn encoding_tags_roundtrip() {
        for e in [Encoding::F32, Encoding::F16, Encoding::QDelta] {
            assert_eq!(Encoding::from_u8(e as u8).unwrap(), e);
            assert_eq!(Encoding::parse(e.name()).unwrap(), e);
        }
        assert!(Encoding::from_u8(7).is_err());
        assert!(Encoding::parse("f64").is_err());
        assert_eq!(Encoding::F32.elem_size(), 4);
        assert_eq!(Encoding::F16.elem_size(), 2);
        assert_eq!(Encoding::QDelta.elem_size(), 1);
        assert!(Encoding::F32.is_lossless() && !Encoding::F16.is_lossless());
    }
}
