//! CRC-32 (IEEE 802.3, the zlib polynomial) — table-driven, built once.

use once_cell::sync::Lazy;

static TABLE: Lazy<[u32; 256]> = Lazy::new(|| {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    table
});

/// CRC-32 of `data` (init 0xFFFFFFFF, final xor, reflected — matches
/// zlib's `crc32()` so external tools can verify payloads).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = crc32(b"hello world");
        let b = crc32(b"hello worle");
        assert_ne!(a, b);
    }
}
