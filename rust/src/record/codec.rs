//! Lossless payload compression for staged stream records (ISSUE 5).
//!
//! Two pieces, composed by the `shuffle-lz` codec:
//!
//! * **Byte shuffle** ([`shuffle`]/[`unshuffle`]) — transpose an array
//!   of fixed-size elements into byte planes (all 0th bytes, then all
//!   1st bytes, ...).  Smooth numeric fields have highly repetitive
//!   sign/exponent bytes; grouping them turns per-element entropy into
//!   the long runs an LZ pass eats.  This is the classic
//!   shuffle-before-compress trick of HDF5/Blosc.
//! * **An LZ77-family codec** ([`lz_compress`]/[`lz_decompress`]) —
//!   greedy single-probe hash matching emitting an LZ4-style token
//!   stream (literal-run and match-length nibbles with 255-terminated
//!   extension bytes, 16-bit little-endian match offsets).  No external
//!   crates; decoding is fully bounds-checked and returns an error on
//!   corrupt input — it never panics and never reads out of bounds.
//!
//! [`Codec`] is the trait the broker-side stage pipeline
//! (`crate::broker::stages`) and the staged-frame decoder
//! ([`super::StreamRecord::decode`]) share; [`CodecKind`] is the wire
//! tag carried in [`super::FrameMeta`].  Corruption of a compressed
//! payload is caught by the record CRC before decompression is even
//! attempted; the decoder's own validation is defense in depth.

use anyhow::{bail, ensure, Result};

/// Wire tag of the compression applied to a staged frame's payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CodecKind {
    /// Payload stored as-is.
    #[default]
    None = 0,
    /// Byte shuffle (element-size aware) followed by the LZ pass.
    ShuffleLz = 1,
}

impl CodecKind {
    pub fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(CodecKind::None),
            1 => Ok(CodecKind::ShuffleLz),
            other => bail!("unknown codec tag {other}"),
        }
    }

    /// Parse the config/CLI spelling (`none` | `shuffle-lz`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(CodecKind::None),
            "shuffle-lz" => Ok(CodecKind::ShuffleLz),
            other => bail!("unknown codec '{other}' (none|shuffle-lz)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecKind::None => "none",
            CodecKind::ShuffleLz => "shuffle-lz",
        }
    }
}

/// A lossless payload codec.  `elem_size` is the width in bytes of one
/// encoded element (4 for raw f32, 2 for f16, 1 for variable-length
/// encodings) so shuffle-style codecs can split byte planes correctly.
pub trait Codec: Send + Sync {
    fn kind(&self) -> CodecKind;
    /// Compress `raw` (an array of `elem_size`-byte elements).
    fn compress(&self, raw: &[u8], elem_size: usize) -> Vec<u8>;
    /// Reverse [`Codec::compress`].  `raw_len` is the expected output
    /// length; a stream that does not decode to exactly that length is
    /// corrupt.  Must never panic on malformed input.
    fn decompress(&self, comp: &[u8], raw_len: usize, elem_size: usize) -> Result<Vec<u8>>;
}

/// The identity codec.
pub struct NoneCodec;

impl Codec for NoneCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::None
    }
    fn compress(&self, raw: &[u8], _elem_size: usize) -> Vec<u8> {
        raw.to_vec()
    }
    fn decompress(&self, comp: &[u8], raw_len: usize, _elem_size: usize) -> Result<Vec<u8>> {
        ensure!(
            comp.len() == raw_len,
            "codec none: payload {} bytes, expected {raw_len}",
            comp.len()
        );
        Ok(comp.to_vec())
    }
}

/// Byte shuffle + LZ (the default lossless wire codec).
pub struct ShuffleLzCodec;

impl Codec for ShuffleLzCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::ShuffleLz
    }
    fn compress(&self, raw: &[u8], elem_size: usize) -> Vec<u8> {
        lz_compress(&shuffle(raw, elem_size))
    }
    fn decompress(&self, comp: &[u8], raw_len: usize, elem_size: usize) -> Result<Vec<u8>> {
        let shuffled = lz_decompress(comp, raw_len)?;
        Ok(unshuffle(&shuffled, elem_size))
    }
}

/// The codec implementation for a wire tag.
pub fn codec_for(kind: CodecKind) -> &'static dyn Codec {
    match kind {
        CodecKind::None => &NoneCodec,
        CodecKind::ShuffleLz => &ShuffleLzCodec,
    }
}

/// Transpose `raw` (elements of `elem_size` bytes) into byte planes;
/// trailing bytes that don't fill an element are appended unchanged.
pub fn shuffle(raw: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 {
        return raw.to_vec();
    }
    let n = raw.len() / elem_size;
    let body = n * elem_size;
    let mut out = Vec::with_capacity(raw.len());
    for j in 0..elem_size {
        for i in 0..n {
            out.push(raw[i * elem_size + j]);
        }
    }
    out.extend_from_slice(&raw[body..]);
    out
}

/// Reverse [`shuffle`].
pub fn unshuffle(shuffled: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 {
        return shuffled.to_vec();
    }
    let n = shuffled.len() / elem_size;
    let body = n * elem_size;
    let mut out = vec![0u8; shuffled.len()];
    for j in 0..elem_size {
        for i in 0..n {
            out[i * elem_size + j] = shuffled[j * n + i];
        }
    }
    out[body..].copy_from_slice(&shuffled[body..]);
    out
}

const MIN_MATCH: usize = 4;
const MAX_OFFSET: usize = u16::MAX as usize;
const HASH_BITS: u32 = 14;

#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

#[inline]
fn read_u32(buf: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]])
}

/// 255-terminated extension bytes (LZ4 convention).
fn write_ext(out: &mut Vec<u8>, mut v: usize) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

fn read_ext(comp: &[u8], pos: &mut usize) -> Result<usize> {
    let mut v = 0usize;
    loop {
        ensure!(*pos < comp.len(), "lz: truncated extension length");
        let b = comp[*pos];
        *pos += 1;
        v += b as usize;
        if b < 255 {
            return Ok(v);
        }
        ensure!(v <= (1 << 30), "lz: absurd extension length");
    }
}

/// One sequence: token, extended literal length, literals, then (when
/// a match follows) the 16-bit offset and extended match length.
fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], m: Option<(usize, usize)>) {
    let lit = literals.len();
    let (off, mlen) = m.unwrap_or((0, 0));
    let lit_nib = lit.min(15) as u8;
    let mat_nib = if mlen == 0 { 0 } else { (mlen - MIN_MATCH).min(15) as u8 };
    out.push((lit_nib << 4) | mat_nib);
    if lit >= 15 {
        write_ext(out, lit - 15);
    }
    out.extend_from_slice(literals);
    if mlen > 0 {
        out.extend_from_slice(&(off as u16).to_le_bytes());
        if mlen - MIN_MATCH >= 15 {
            write_ext(out, mlen - MIN_MATCH - 15);
        }
    }
}

thread_local! {
    /// Reusable match table: one 64 KiB buffer per thread instead of a
    /// fresh allocation per record on the broker write path.
    static LZ_TABLE: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Greedy LZ compression.  Output is self-contained; decompression
/// needs only the expected raw length (carried in the frame header).
pub fn lz_compress(raw: &[u8]) -> Vec<u8> {
    LZ_TABLE.with(|t| {
        let mut table = t.borrow_mut();
        if table.len() != 1 << HASH_BITS {
            table.clear();
            table.resize(1 << HASH_BITS, u32::MAX);
        } else {
            table.fill(u32::MAX);
        }
        lz_compress_with(raw, &mut table)
    })
}

/// `u32::MAX` positions are "empty"; inputs that large are impossible
/// anyway (record payload lengths are u32 on the wire).
fn lz_compress_with(raw: &[u8], table: &mut [u32]) -> Vec<u8> {
    let len = raw.len().min(u32::MAX as usize - 1);
    let mut out = Vec::with_capacity(len / 2 + 16);
    let mut anchor = 0usize;
    let mut pos = 0usize;
    while pos + MIN_MATCH <= len {
        let h = hash4(read_u32(raw, pos));
        let cand = table[h] as usize;
        table[h] = pos as u32;
        if cand != u32::MAX as usize
            && pos - cand <= MAX_OFFSET
            && read_u32(raw, cand) == read_u32(raw, pos)
        {
            let mut mlen = MIN_MATCH;
            while pos + mlen < len && raw[cand + mlen] == raw[pos + mlen] {
                mlen += 1;
            }
            emit_sequence(&mut out, &raw[anchor..pos], Some((pos - cand, mlen)));
            // Seed the table inside the match (sparsely for long ones)
            // so the next occurrence of its interior still matches.
            let step = if mlen > 64 { 8 } else { 1 };
            let mut p = pos + 1;
            while p + MIN_MATCH <= len && p < pos + mlen {
                table[hash4(read_u32(raw, p))] = p as u32;
                p += step;
            }
            pos += mlen;
            anchor = pos;
        } else {
            pos += 1;
        }
    }
    if anchor < len {
        emit_sequence(&mut out, &raw[anchor..len], None);
    }
    out
}

/// Reverse [`lz_compress`].  Every read is bounds-checked; malformed
/// input (bad offsets, runs past `raw_len`, truncation) returns an
/// error, never a panic.
pub fn lz_decompress(comp: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while pos < comp.len() {
        let token = comp[pos];
        pos += 1;
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            lit += read_ext(comp, &mut pos)?;
        }
        ensure!(pos + lit <= comp.len(), "lz: literal run past input end");
        ensure!(out.len() + lit <= raw_len, "lz: literals exceed raw length");
        out.extend_from_slice(&comp[pos..pos + lit]);
        pos += lit;
        if pos >= comp.len() {
            break; // final (literal-only) sequence
        }
        ensure!(pos + 2 <= comp.len(), "lz: truncated match offset");
        let off = u16::from_le_bytes([comp[pos], comp[pos + 1]]) as usize;
        pos += 2;
        let mut mlen = (token & 0x0F) as usize + MIN_MATCH;
        if token & 0x0F == 15 {
            mlen += read_ext(comp, &mut pos)?;
        }
        ensure!(off >= 1 && off <= out.len(), "lz: match offset {off} out of window");
        ensure!(out.len() + mlen <= raw_len, "lz: match exceeds raw length");
        let start = out.len() - off;
        for i in 0..mlen {
            // byte-wise: matches may overlap their own output
            let b = out[start + i];
            out.push(b);
        }
    }
    ensure!(
        out.len() == raw_len,
        "lz: decoded {} bytes, expected {raw_len}",
        out.len()
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(codec: &dyn Codec, raw: &[u8], elem_size: usize) {
        let comp = codec.compress(raw, elem_size);
        let back = codec.decompress(&comp, raw.len(), elem_size).unwrap();
        assert_eq!(back, raw, "roundtrip failed (elem_size {elem_size})");
    }

    #[test]
    fn shuffle_roundtrip_with_tail() {
        for elem in [1usize, 2, 4, 8] {
            for len in [0usize, 1, 3, 4, 7, 16, 33] {
                let raw: Vec<u8> = (0..len as u8).collect();
                assert_eq!(unshuffle(&shuffle(&raw, elem), elem), raw, "elem {elem} len {len}");
            }
        }
    }

    #[test]
    fn lz_roundtrip_compressible_and_random() {
        let mut rng = Rng::new(42);
        // highly compressible
        let smooth: Vec<u8> = (0..4096).map(|i| (i / 64) as u8).collect();
        let comp = lz_compress(&smooth);
        assert!(comp.len() < smooth.len() / 3, "smooth data should compress ≥3x");
        assert_eq!(lz_decompress(&comp, smooth.len()).unwrap(), smooth);
        // incompressible
        let noise: Vec<u8> = (0..2048).map(|_| rng.next_below(256) as u8).collect();
        let comp = lz_compress(&noise);
        assert_eq!(lz_decompress(&comp, noise.len()).unwrap(), noise);
        // empty
        assert!(lz_compress(&[]).is_empty());
        assert_eq!(lz_decompress(&[], 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn lz_long_runs_use_extension_bytes() {
        // 5000 identical bytes: one long overlapping match with extended
        // match length; roundtrip must be exact.
        let raw = vec![7u8; 5000];
        let comp = lz_compress(&raw);
        assert!(comp.len() < 64, "run-length case barely compresses: {}", comp.len());
        assert_eq!(lz_decompress(&comp, raw.len()).unwrap(), raw);
        // long literal run (incompressible prefix > 15 bytes, no matches)
        let lits: Vec<u8> = (0..600u32).map(|i| (i * 37 % 251) as u8).collect();
        let comp = lz_compress(&lits);
        assert_eq!(lz_decompress(&comp, lits.len()).unwrap(), lits);
    }

    #[test]
    fn shuffle_lz_codec_roundtrips_f32_planes() {
        let data: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.01).sin()).collect();
        let raw: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let codec = codec_for(CodecKind::ShuffleLz);
        let comp = codec.compress(&raw, 4);
        assert!(comp.len() < raw.len(), "smooth f32 field must shrink");
        roundtrip(codec, &raw, 4);
        roundtrip(codec_for(CodecKind::None), &raw, 4);
    }

    /// Corrupt compressed input must never panic: every single-byte
    /// flip either fails cleanly or decodes to (possibly different)
    /// bytes — the record CRC catches the latter upstream.
    #[test]
    fn lz_decode_never_panics_on_corruption() {
        let raw: Vec<u8> = (0..512u32).map(|i| (i / 7) as u8).collect();
        let comp = lz_compress(&raw);
        for i in 0..comp.len() {
            let mut fuzzed = comp.clone();
            fuzzed[i] ^= 0xFF;
            let _ = lz_decompress(&fuzzed, raw.len()); // Ok or Err, never panic
        }
        // truncation at every length
        for cut in 0..comp.len() {
            let _ = lz_decompress(&comp[..cut], raw.len());
        }
        // wildly wrong raw_len claims
        let _ = lz_decompress(&comp, 0);
        let _ = lz_decompress(&comp, raw.len() * 10);
    }

    #[test]
    fn codec_kind_tags_roundtrip() {
        for k in [CodecKind::None, CodecKind::ShuffleLz] {
            assert_eq!(CodecKind::from_u8(k as u8).unwrap(), k);
            assert_eq!(CodecKind::parse(k.name()).unwrap(), k);
        }
        assert!(CodecKind::from_u8(9).is_err());
        assert!(CodecKind::parse("zstd").is_err());
    }
}
