//! The simulation→Cloud stream record: schema + binary codec.
//!
//! A record is one field snapshot from one simulation process at one
//! timestep (the paper's §3.1: "Each stream record contains the
//! time-step information and the serialized field data of the simulation
//! process").  We add schema (shape, dtype) so the Cloud side can
//! reassemble arrays without out-of-band coordination, and a generation
//! timestamp so the analysis side can measure the §4.3 latency metric.
//!
//! Wire layout (little-endian, CRC-protected):
//!
//! ```text
//! magic    u32   0x4542_5231  ("EBR1")
//! step     u64   simulation timestep
//! gen_us   u64   generation timestamp, µs since epoch
//! rank     u32   source MPI-style rank
//! dtype    u8    0 = f32 (the only dtype the kernels emit today)
//! ndim     u8    number of dims (<= 4)
//! dims     u32 × ndim
//! name_len u16,  name bytes (field name, e.g. "velocity")
//! payload_len u32, payload bytes
//! crc32    u32   over everything above
//! ```
//!
//! # Staged frames (ISSUE 5)
//!
//! Records that passed through the broker-side data-reduction pipeline
//! (`crate::broker::stages`) are framed with a second magic, `"EBR2"`,
//! and a self-describing [`FrameMeta`] header between the field name
//! and the payload:
//!
//! ```text
//! magic    u32   0x4542_5232  ("EBR2")
//! ...      (step, gen_us, rank, dtype, dims, name as in EBR1;
//!           dtype/dims describe the DECODED data)
//! enc      u8    element encoding (0 f32 | 1 f16 | 2 qdelta)
//! codec    u8    payload codec   (0 none | 1 shuffle-lz)
//! enc_param   f32  encoding parameter (qdelta quantization step)
//! err_bound   f32  measured max abs error of the encoding (0 lossless)
//! raw_len  u32   encoded-but-uncompressed payload bytes (codec input)
//! flags    u8    bit 0: sidecar stats present; bit 1: trace present
//! stats    f32 × 3   min, max, mean (iff flag bit 0)
//! trace    u64 × 4   origin, enqueue, flush, deliver µs (iff flag bit 1)
//! prov_len u16,  provenance bytes (e.g. "agg:2|f16|shuffle-lz")
//! payload_len u32, payload bytes (codec output)
//! crc32    u32   over everything above
//! ```
//!
//! [`StreamRecord::decode`] dispatches on the magic and *reverses* the
//! conversion and compression, so every consumer downstream of a
//! decode — endpoint readers, `crate::streamproc`, `crate::analysis` —
//! sees plain f32 payloads whether or not the producer staged them
//! (peers that never enable stages keep emitting byte-identical EBR1
//! frames).  Endpoints and the WAL store the encoded bytes opaquely,
//! so the wire reduction carries through to disk.

mod crc32;

pub mod codec;
pub mod convert;

pub use codec::{codec_for, Codec, CodecKind};
pub use convert::Encoding;
pub use crc32::crc32;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// Payload element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32 = 0,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(Dtype::F32),
            other => bail!("unknown dtype tag {other}"),
        }
    }
}

const MAGIC: u32 = 0x4542_5231;
const MAGIC2: u32 = 0x4542_5232;

/// Per-field sidecar statistics computed by the aggregate stage
/// (carried in [`FrameMeta`] so dashboards and triage can read them
/// without decoding the payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FieldStats {
    pub min: f32,
    pub max: f32,
    pub mean: f32,
}

/// Per-record hop timestamps for the sampled end-to-end staleness
/// trace (ISSUE 9).  Carried in [`FrameMeta`] (flags bit 1,
/// CRC-covered) on a 1-in-N subset of records; a 0 stamp means "hop
/// not reached yet".  `deliver_us` is stamped by the *reader* on its
/// decoded in-memory copy — producers serialize it as 0, so stored and
/// migrated bytes stay stable.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Trace {
    /// µs-since-epoch when the simulation handed the field to the
    /// broker (same clock as `gen_micros`).
    pub origin_us: u64,
    /// µs-since-epoch when the staged record entered the broker queue.
    pub enqueue_us: u64,
    /// µs-since-epoch when the shipper encoded it into a flush batch.
    pub flush_us: u64,
    /// µs-since-epoch when a reader decoded it (never serialized
    /// non-zero by producers; see struct docs).
    pub deliver_us: u64,
}

/// Self-describing header of a staged (`"EBR2"`) frame: how the
/// payload was encoded and compressed, with enough information to
/// reverse both, plus stage provenance and sidecar stats.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FrameMeta {
    /// Element encoding of the payload (before compression).
    pub encoding: Encoding,
    /// Compression applied after the encoding.
    pub codec: CodecKind,
    /// Encoding parameter: the quantization step for
    /// [`Encoding::QDelta`], 0 otherwise.
    pub enc_param: f32,
    /// Measured max absolute error the encoding introduced
    /// (0 for lossless encodings).
    pub err_bound: f32,
    /// Length in bytes of the encoded-but-uncompressed payload — what
    /// the codec must decompress back to.
    pub raw_len: u32,
    /// Sidecar min/max/mean of the (post-aggregate) field data.
    pub stats: Option<FieldStats>,
    /// Sampled staleness-trace hop stamps (ISSUE 9); `None` on the
    /// unsampled hot path, so untraced frames never grow.
    pub trace: Option<Trace>,
    /// Human-readable stage provenance, e.g. `"roi:8:120|agg:2|f16|shuffle-lz"`.
    pub provenance: String,
}

/// One field snapshot travelling HPC → Cloud.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamRecord {
    /// Field name (e.g. `"velocity"`), registered at `broker_init`.
    pub field: String,
    /// Source simulation rank.
    pub rank: u32,
    /// Simulation timestep the snapshot belongs to.
    pub step: u64,
    /// µs-since-epoch at generation (drives the Fig 7a latency metric).
    pub gen_micros: u64,
    /// Element type of `payload`.
    pub dtype: Dtype,
    /// Array shape (row-major payload).
    pub shape: Vec<u32>,
    /// Raw little-endian element bytes; `Arc` so fan-out paths don't
    /// copy.  For a *staged* record on the producer side this holds the
    /// encoded+compressed bytes ([`FrameMeta::raw_len`] describes
    /// them); after [`StreamRecord::decode`] it always holds raw f32.
    pub payload: Arc<Vec<u8>>,
    /// Stage-pipeline header (`None` = classic raw EBR1 frame).
    pub meta: Option<FrameMeta>,
}

impl StreamRecord {
    /// Build an f32 record from a slice (copies once into the payload).
    pub fn from_f32(
        field: &str,
        rank: u32,
        step: u64,
        gen_micros: u64,
        shape: &[u32],
        data: &[f32],
    ) -> Result<Self> {
        let n: usize = shape.iter().map(|&d| d as usize).product();
        if n != data.len() {
            bail!("shape {shape:?} (={n}) does not match data length {}", data.len());
        }
        let mut payload = Vec::with_capacity(data.len() * 4);
        for v in data {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Ok(StreamRecord {
            field: field.to_string(),
            rank,
            step,
            gen_micros,
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            payload: Arc::new(payload),
            meta: None,
        })
    }

    /// Build a staged record from an already encoded+compressed
    /// payload (the stage pipeline's output).  `shape` is the decoded
    /// shape after filtering/aggregation; `meta` describes how to get
    /// the f32 data back.
    pub fn from_staged(
        field: &str,
        rank: u32,
        step: u64,
        gen_micros: u64,
        shape: &[u32],
        payload: Vec<u8>,
        meta: FrameMeta,
    ) -> Self {
        StreamRecord {
            field: field.to_string(),
            rank,
            step,
            gen_micros,
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            payload: Arc::new(payload),
            meta: Some(meta),
        }
    }

    /// Decode the payload as f32 values.
    pub fn payload_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("payload is not f32");
        }
        if self.payload.len() % 4 != 0 {
            bail!("payload length {} not divisible by 4", self.payload.len());
        }
        Ok(self
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Number of elements implied by the shape.
    pub fn element_count(&self) -> usize {
        self.shape.iter().map(|&d| d as usize).product()
    }

    /// The endpoint stream key this record belongs to: one stream per
    /// (field, rank), mirroring the paper's per-process data streams.
    pub fn stream_key(&self) -> String {
        stream_key(&self.field, self.rank)
    }

    /// Serialized size of the encoded record (for metrics/backpressure).
    pub fn encoded_len(&self) -> usize {
        let base = 4 + 8 + 8 + 4 + 1 + 1 + 4 * self.shape.len() + 2 + self.field.len() + 4
            + self.payload.len()
            + 4;
        match &self.meta {
            None => base,
            // enc + codec + enc_param + err_bound + raw_len + flags
            // + optional stats + optional trace + prov_len + provenance
            Some(m) => {
                base + 1
                    + 1
                    + 4
                    + 4
                    + 4
                    + 1
                    + if m.stats.is_some() { 12 } else { 0 }
                    + if m.trace.is_some() { 32 } else { 0 }
                    + 2
                    + m.provenance.len()
            }
        }
    }

    /// Encode to the binary wire format described in the module docs
    /// (`EBR1` for raw records, `EBR2` when a stage header is present).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        let magic = if self.meta.is_some() { MAGIC2 } else { MAGIC };
        out.extend_from_slice(&magic.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.gen_micros.to_le_bytes());
        out.extend_from_slice(&self.rank.to_le_bytes());
        out.push(self.dtype as u8);
        out.push(self.shape.len() as u8);
        for d in &self.shape {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(self.field.len() as u16).to_le_bytes());
        out.extend_from_slice(self.field.as_bytes());
        if let Some(m) = &self.meta {
            out.push(m.encoding as u8);
            out.push(m.codec as u8);
            out.extend_from_slice(&m.enc_param.to_le_bytes());
            out.extend_from_slice(&m.err_bound.to_le_bytes());
            out.extend_from_slice(&m.raw_len.to_le_bytes());
            let flags =
                u8::from(m.stats.is_some()) | (u8::from(m.trace.is_some()) << 1);
            out.push(flags);
            if let Some(s) = &m.stats {
                out.extend_from_slice(&s.min.to_le_bytes());
                out.extend_from_slice(&s.max.to_le_bytes());
                out.extend_from_slice(&s.mean.to_le_bytes());
            }
            if let Some(t) = &m.trace {
                out.extend_from_slice(&t.origin_us.to_le_bytes());
                out.extend_from_slice(&t.enqueue_us.to_le_bytes());
                out.extend_from_slice(&t.flush_us.to_le_bytes());
                out.extend_from_slice(&t.deliver_us.to_le_bytes());
            }
            out.extend_from_slice(&(m.provenance.len() as u16).to_le_bytes());
            out.extend_from_slice(m.provenance.as_bytes());
        }
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode from the binary wire format (validates magic + CRC).
    /// Staged (`EBR2`) frames are decompressed and converted back, so
    /// the returned record always carries a raw f32 payload; the stage
    /// header survives in [`StreamRecord::meta`].
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32()?;
        if magic != MAGIC && magic != MAGIC2 {
            bail!("bad record magic 0x{magic:08x}");
        }
        let staged = magic == MAGIC2;
        let step = r.u64()?;
        let gen_micros = r.u64()?;
        let rank = r.u32()?;
        let dtype = Dtype::from_u8(r.u8()?)?;
        let ndim = r.u8()? as usize;
        if ndim > 4 {
            bail!("too many dims: {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()?);
        }
        let name_len = r.u16()? as usize;
        let name = r.bytes(name_len)?;
        let field = String::from_utf8(name.to_vec()).context("field name not UTF-8")?;
        let meta = if staged {
            let encoding = Encoding::from_u8(r.u8()?)?;
            let codec = CodecKind::from_u8(r.u8()?)?;
            let enc_param = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
            let err_bound = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
            let raw_len = r.u32()?;
            let flags = r.u8()?;
            let stats = if flags & 1 != 0 {
                let min = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
                let max = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
                let mean = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
                Some(FieldStats { min, max, mean })
            } else {
                None
            };
            let trace = if flags & 2 != 0 {
                Some(Trace {
                    origin_us: r.u64()?,
                    enqueue_us: r.u64()?,
                    flush_us: r.u64()?,
                    deliver_us: r.u64()?,
                })
            } else {
                None
            };
            let prov_len = r.u16()? as usize;
            let provenance = String::from_utf8(r.bytes(prov_len)?.to_vec())
                .context("provenance not UTF-8")?;
            Some(FrameMeta {
                encoding,
                codec,
                enc_param,
                err_bound,
                raw_len,
                stats,
                trace,
                provenance,
            })
        } else {
            None
        };
        let payload_len = r.u32()? as usize;
        let payload = r.bytes(payload_len)?.to_vec();
        let crc_pos = r.pos;
        let crc = r.u32()?;
        let want = crc32(&buf[..crc_pos]);
        if crc != want {
            bail!("record CRC mismatch: got 0x{crc:08x} want 0x{want:08x}");
        }
        let n: usize = shape.iter().map(|&d| d as usize).product();
        let (payload, meta) = match meta {
            None => {
                if n * dtype.size() != payload.len() {
                    bail!(
                        "shape {shape:?} implies {} bytes but payload has {}",
                        n * dtype.size(),
                        payload.len()
                    );
                }
                (payload, None)
            }
            Some(m) => {
                // Validate the claimed pre-codec length against what the
                // shape allows BEFORE decompressing — a crafted frame
                // must not be able to demand a huge allocation from a
                // few bytes.  Fixed-width encodings are exact; qdelta
                // varints are at most 10 bytes per element.
                let raw_len = m.raw_len as usize;
                let max_raw = match m.encoding {
                    Encoding::F32 | Encoding::F16 => n.saturating_mul(m.encoding.elem_size()),
                    Encoding::QDelta => n.saturating_mul(10),
                };
                if m.encoding != Encoding::QDelta && raw_len != max_raw {
                    bail!(
                        "staged frame claims {raw_len} encoded bytes, shape {shape:?} \
                         implies {max_raw}"
                    );
                }
                if raw_len > max_raw {
                    bail!(
                        "staged frame claims {raw_len} encoded bytes, more than the \
                         {max_raw} the shape {shape:?} allows"
                    );
                }
                // Reverse compression, then the element encoding — the
                // consumer sees raw f32 regardless of what shipped.
                let encoded = codec_for(m.codec).decompress(
                    &payload,
                    raw_len,
                    m.encoding.elem_size(),
                )?;
                let values: Vec<f32> = match m.encoding {
                    Encoding::F32 => encoded
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                    Encoding::F16 => convert::decode_f16(&encoded, n)?,
                    Encoding::QDelta => convert::decode_qdelta(&encoded, n, m.enc_param)?,
                };
                let mut raw = Vec::with_capacity(values.len() * 4);
                for v in &values {
                    raw.extend_from_slice(&v.to_le_bytes());
                }
                // Rewrite the header to describe the payload the record
                // now actually holds (raw uncompressed f32), keeping the
                // provenance, error bound and sidecar stats.  Re-encoding
                // a decoded record therefore produces a valid frame
                // instead of one whose header lies about compression.
                let decoded_meta = FrameMeta {
                    encoding: Encoding::F32,
                    codec: CodecKind::None,
                    enc_param: 0.0,
                    err_bound: m.err_bound,
                    raw_len: raw.len() as u32,
                    stats: m.stats,
                    trace: m.trace,
                    provenance: m.provenance,
                };
                (raw, Some(decoded_meta))
            }
        };
        Ok(StreamRecord {
            field,
            rank,
            step,
            gen_micros,
            dtype,
            shape,
            payload: Arc::new(payload),
            meta,
        })
    }

    /// Cheap header-only peek at an encoded frame's [`Trace`] stamps:
    /// no payload decode, no CRC, no allocation.  Returns `None` for
    /// `EBR1` frames, untraced `EBR2` frames, and anything malformed —
    /// the endpoint ingest path calls this on every append, so the
    /// common untraced case must exit after a handful of byte reads.
    pub fn peek_trace(buf: &[u8]) -> Option<Trace> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32().ok()?;
        if magic != MAGIC2 {
            return None;
        }
        // step + gen_us + rank + dtype
        r.bytes(8 + 8 + 4 + 1).ok()?;
        let ndim = r.u8().ok()? as usize;
        r.bytes(4 * ndim).ok()?;
        let name_len = r.u16().ok()? as usize;
        r.bytes(name_len).ok()?;
        // enc + codec + enc_param + err_bound + raw_len
        r.bytes(1 + 1 + 4 + 4 + 4).ok()?;
        let flags = r.u8().ok()?;
        if flags & 2 == 0 {
            return None;
        }
        if flags & 1 != 0 {
            r.bytes(12).ok()?;
        }
        Some(Trace {
            origin_us: r.u64().ok()?,
            enqueue_us: r.u64().ok()?,
            flush_us: r.u64().ok()?,
            deliver_us: r.u64().ok()?,
        })
    }
}

/// Stream key for a (field, rank) pair: `"<field>/<rank>"`.
pub fn stream_key(field: &str, rank: u32) -> String {
    format!("{field}/{rank}")
}

/// Parse a stream key back into (field, rank).
pub fn parse_stream_key(key: &str) -> Option<(&str, u32)> {
    let (field, rank) = key.rsplit_once('/')?;
    Some((field, rank.parse().ok()?))
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("record truncated at offset {} (need {n} more bytes)", self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, F32Vec};
    use crate::util::rng::Rng;

    fn sample() -> StreamRecord {
        StreamRecord::from_f32("velocity", 3, 120, 1_700_000_000_000_000, &[2, 4], &[
            0.0, 1.0, -2.5, 3.25, 4.0, 5.5, -6.0, 7.75,
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip_basic() {
        let r = sample();
        let got = StreamRecord::decode(&r.encode()).unwrap();
        assert_eq!(got, r);
        assert_eq!(got.payload_f32().unwrap()[2], -2.5);
    }

    #[test]
    fn encoded_len_is_exact() {
        let r = sample();
        assert_eq!(r.encode().len(), r.encoded_len());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(StreamRecord::from_f32("v", 0, 0, 0, &[3, 3], &[0.0; 8]).is_err());
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = sample().encode();
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        assert!(StreamRecord::decode(&buf).is_err());
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let buf = sample().encode();
        for cut in 0..buf.len() {
            assert!(
                StreamRecord::decode(&buf[..cut]).is_err(),
                "decode of {cut}-byte prefix should fail"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = sample().encode();
        buf[0] ^= 0xFF;
        assert!(StreamRecord::decode(&buf).is_err());
    }

    #[test]
    fn stream_key_roundtrip() {
        assert_eq!(stream_key("velocity", 12), "velocity/12");
        assert_eq!(parse_stream_key("velocity/12"), Some(("velocity", 12)));
        assert_eq!(parse_stream_key("a/b/7"), Some(("a/b", 7)));
        assert_eq!(parse_stream_key("norank"), None);
    }

    /// Property: arbitrary f32 payloads roundtrip bit-exactly.
    #[test]
    fn prop_roundtrip_arbitrary_payloads() {
        let gen = F32Vec { max_len: 512, scale: 1e6 };
        prop::forall(0x5EED, 100, &gen, |data| {
            let shape = [data.len() as u32];
            let r = StreamRecord::from_f32("u", 7, 9, 11, &shape, data)
                .map_err(|e| e.to_string())?;
            let got = StreamRecord::decode(&r.encode()).map_err(|e| e.to_string())?;
            if got != r {
                return Err("record mismatch".into());
            }
            let back = got.payload_f32().map_err(|e| e.to_string())?;
            if back.iter().zip(data).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err("payload bits changed".into());
            }
            Ok(())
        });
    }

    /// Exhaustive corruption sweep: flipping EVERY byte of the encoded
    /// record (one at a time) must make decode reject it — either the
    /// CRC catches it or a schema check does, but it never slips
    /// through as a "valid" record.
    #[test]
    fn every_byte_flip_rejected() {
        let buf = sample().encode();
        for i in 0..buf.len() {
            let mut fuzzed = buf.clone();
            fuzzed[i] ^= 0xFF;
            assert!(
                StreamRecord::decode(&fuzzed).is_err(),
                "flip of byte {i} (of {}) went undetected",
                buf.len()
            );
        }
    }

    /// Build a staged (EBR2) sample: f16 + shuffle-lz over a smooth
    /// ramp, with sidecar stats and provenance.
    fn staged_sample() -> (StreamRecord, Vec<f32>) {
        let data: Vec<f32> = (0..64).map(|i| (i as f32) * 0.125 - 4.0).collect();
        let (encoded, err) = convert::encode_f16(&data).unwrap();
        let raw_len = encoded.len() as u32;
        let payload = codec_for(CodecKind::ShuffleLz).compress(&encoded, 2);
        let rec = StreamRecord::from_staged(
            "velocity",
            3,
            120,
            1_700_000_000_000_000,
            &[8, 8],
            payload,
            FrameMeta {
                encoding: Encoding::F16,
                codec: CodecKind::ShuffleLz,
                enc_param: 0.0,
                err_bound: err,
                raw_len,
                stats: Some(FieldStats { min: -4.0, max: 3.875, mean: -0.0625 }),
                trace: None,
                provenance: "f16|shuffle-lz".into(),
            },
        );
        (rec, data)
    }

    #[test]
    fn staged_roundtrip_decodes_to_raw_f32() {
        let (rec, data) = staged_sample();
        let buf = rec.encode();
        assert_eq!(buf.len(), rec.encoded_len());
        let got = StreamRecord::decode(&buf).unwrap();
        assert_eq!(got.field, "velocity");
        assert_eq!(got.step, 120);
        assert_eq!(got.shape, vec![8, 8]);
        let meta = got.meta.as_ref().expect("stage header survives decode");
        // the header is rewritten to describe the *decoded* payload
        // (raw uncompressed f32); provenance/bound/stats carry through
        assert_eq!(meta.encoding, Encoding::F32);
        assert_eq!(meta.codec, CodecKind::None);
        assert_eq!(meta.raw_len as usize, got.payload.len());
        assert_eq!(meta.provenance, "f16|shuffle-lz");
        assert_eq!(meta.stats.unwrap().max, 3.875);
        let back = got.payload_f32().unwrap();
        assert_eq!(back.len(), data.len());
        for (a, b) in back.iter().zip(&data) {
            assert!((a - b).abs() <= meta.err_bound, "{b} → {a}");
        }
        // this ramp is exactly representable in f16: lossless here
        assert_eq!(meta.err_bound, 0.0);
        assert_eq!(back, data);
        // decode∘encode is stable: re-encoding the decoded record
        // yields a valid frame that decodes to the same record
        let again = StreamRecord::decode(&got.encode()).unwrap();
        assert_eq!(again, got);
    }

    #[test]
    fn staged_frame_is_smaller_than_raw_on_smooth_data() {
        let (rec, data) = staged_sample();
        let raw = StreamRecord::from_f32("velocity", 3, 120, 0, &[8, 8], &data).unwrap();
        assert!(
            rec.encoded_len() < raw.encoded_len(),
            "staged {} vs raw {}",
            rec.encoded_len(),
            raw.encoded_len()
        );
    }

    /// Exhaustive corruption sweep over the staged format: every byte
    /// flip must be rejected cleanly (CRC or schema), never panic.
    #[test]
    fn staged_every_byte_flip_rejected() {
        let (rec, _) = staged_sample();
        let buf = rec.encode();
        for i in 0..buf.len() {
            let mut fuzzed = buf.clone();
            fuzzed[i] ^= 0xFF;
            assert!(
                StreamRecord::decode(&fuzzed).is_err(),
                "flip of staged byte {i} (of {}) went undetected",
                buf.len()
            );
        }
        for cut in 0..buf.len() {
            assert!(StreamRecord::decode(&buf[..cut]).is_err(), "{cut}-byte prefix");
        }
    }

    /// v1 frames must stay byte-identical with the pre-stages encoder
    /// (meta-less records never grow the EBR2 header).
    #[test]
    fn raw_frames_keep_v1_magic() {
        let buf = sample().encode();
        assert_eq!(&buf[0..4], &0x4542_5231u32.to_le_bytes());
        let (staged, _) = staged_sample();
        assert_eq!(&staged.encode()[0..4], &0x4542_5232u32.to_le_bytes());
    }

    /// ISSUE 9: a traced sample — flags bit 1, all four hop stamps.
    fn traced_sample() -> StreamRecord {
        let (mut rec, _) = staged_sample();
        let m = rec.meta.as_mut().unwrap();
        m.trace = Some(Trace {
            origin_us: 1_700_000_000_000_100,
            enqueue_us: 1_700_000_000_000_250,
            flush_us: 1_700_000_000_001_000,
            deliver_us: 0,
        });
        rec
    }

    /// ISSUE 9: the trace rides the frame CRC-covered, survives decode
    /// (including the decoded-header rewrite), and untraced frames stay
    /// byte-identical to the pre-trace encoder.
    #[test]
    fn trace_roundtrips_and_untraced_frames_unchanged() {
        let rec = traced_sample();
        let buf = rec.encode();
        assert_eq!(buf.len(), rec.encoded_len());
        let got = StreamRecord::decode(&buf).unwrap();
        let t = got.meta.as_ref().unwrap().trace.expect("trace survives decode");
        assert_eq!(t.origin_us, 1_700_000_000_000_100);
        assert_eq!(t.enqueue_us, 1_700_000_000_000_250);
        assert_eq!(t.flush_us, 1_700_000_000_001_000);
        assert_eq!(t.deliver_us, 0);
        // decode∘encode stability holds for traced frames too
        let again = StreamRecord::decode(&got.encode()).unwrap();
        assert_eq!(again, got);
        // an identical record without the trace encodes 32 bytes shorter
        let (untraced, _) = staged_sample();
        assert_eq!(untraced.encoded_len() + 32, rec.encoded_len());
    }

    /// ISSUE 9: every byte flip of a traced frame is rejected — the
    /// trace stamps are inside the CRC envelope.
    #[test]
    fn traced_every_byte_flip_rejected() {
        let buf = traced_sample().encode();
        for i in 0..buf.len() {
            let mut fuzzed = buf.clone();
            fuzzed[i] ^= 0xFF;
            assert!(
                StreamRecord::decode(&fuzzed).is_err(),
                "flip of traced byte {i} (of {}) went undetected",
                buf.len()
            );
        }
    }

    /// ISSUE 9: `peek_trace` reads the stamps without decoding and
    /// early-exits on raw and untraced frames.
    #[test]
    fn peek_trace_reads_header_only() {
        let rec = traced_sample();
        let t = StreamRecord::peek_trace(&rec.encode()).expect("peek finds trace");
        assert_eq!(t, rec.meta.as_ref().unwrap().trace.unwrap());
        assert!(StreamRecord::peek_trace(&sample().encode()).is_none());
        let (untraced, _) = staged_sample();
        assert!(StreamRecord::peek_trace(&untraced.encode()).is_none());
        assert!(StreamRecord::peek_trace(b"garbage").is_none());
    }

    /// Property: single-bit flips anywhere are detected (CRC or schema).
    #[test]
    fn prop_bit_flips_detected() {
        let r = sample();
        let buf = r.encode();
        let mut rng = Rng::new(77);
        for _ in 0..300 {
            let byte = rng.next_below(buf.len() as u64) as usize;
            let bit = rng.next_below(8) as u8;
            let mut fuzzed = buf.clone();
            fuzzed[byte] ^= 1 << bit;
            match StreamRecord::decode(&fuzzed) {
                Err(_) => {}
                Ok(got) => panic!("undetected corruption at byte {byte} bit {bit}: {got:?}"),
            }
        }
    }
}
