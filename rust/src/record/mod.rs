//! The simulation→Cloud stream record: schema + binary codec.
//!
//! A record is one field snapshot from one simulation process at one
//! timestep (the paper's §3.1: "Each stream record contains the
//! time-step information and the serialized field data of the simulation
//! process").  We add schema (shape, dtype) so the Cloud side can
//! reassemble arrays without out-of-band coordination, and a generation
//! timestamp so the analysis side can measure the §4.3 latency metric.
//!
//! Wire layout (little-endian, CRC-protected):
//!
//! ```text
//! magic    u32   0x4542_5231  ("EBR1")
//! step     u64   simulation timestep
//! gen_us   u64   generation timestamp, µs since epoch
//! rank     u32   source MPI-style rank
//! dtype    u8    0 = f32 (the only dtype the kernels emit today)
//! ndim     u8    number of dims (<= 4)
//! dims     u32 × ndim
//! name_len u16,  name bytes (field name, e.g. "velocity")
//! payload_len u32, payload bytes
//! crc32    u32   over everything above
//! ```

mod crc32;

pub use crc32::crc32;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// Payload element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32 = 0,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(Dtype::F32),
            other => bail!("unknown dtype tag {other}"),
        }
    }
}

const MAGIC: u32 = 0x4542_5231;

/// One field snapshot travelling HPC → Cloud.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamRecord {
    /// Field name (e.g. `"velocity"`), registered at `broker_init`.
    pub field: String,
    /// Source simulation rank.
    pub rank: u32,
    /// Simulation timestep the snapshot belongs to.
    pub step: u64,
    /// µs-since-epoch at generation (drives the Fig 7a latency metric).
    pub gen_micros: u64,
    /// Element type of `payload`.
    pub dtype: Dtype,
    /// Array shape (row-major payload).
    pub shape: Vec<u32>,
    /// Raw little-endian element bytes; `Arc` so fan-out paths don't copy.
    pub payload: Arc<Vec<u8>>,
}

impl StreamRecord {
    /// Build an f32 record from a slice (copies once into the payload).
    pub fn from_f32(
        field: &str,
        rank: u32,
        step: u64,
        gen_micros: u64,
        shape: &[u32],
        data: &[f32],
    ) -> Result<Self> {
        let n: usize = shape.iter().map(|&d| d as usize).product();
        if n != data.len() {
            bail!("shape {shape:?} (={n}) does not match data length {}", data.len());
        }
        let mut payload = Vec::with_capacity(data.len() * 4);
        for v in data {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Ok(StreamRecord {
            field: field.to_string(),
            rank,
            step,
            gen_micros,
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            payload: Arc::new(payload),
        })
    }

    /// Decode the payload as f32 values.
    pub fn payload_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("payload is not f32");
        }
        if self.payload.len() % 4 != 0 {
            bail!("payload length {} not divisible by 4", self.payload.len());
        }
        Ok(self
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Number of elements implied by the shape.
    pub fn element_count(&self) -> usize {
        self.shape.iter().map(|&d| d as usize).product()
    }

    /// The endpoint stream key this record belongs to: one stream per
    /// (field, rank), mirroring the paper's per-process data streams.
    pub fn stream_key(&self) -> String {
        stream_key(&self.field, self.rank)
    }

    /// Serialized size of the encoded record (for metrics/backpressure).
    pub fn encoded_len(&self) -> usize {
        4 + 8 + 8 + 4 + 1 + 1 + 4 * self.shape.len() + 2 + self.field.len() + 4
            + self.payload.len()
            + 4
    }

    /// Encode to the binary wire format described in the module docs.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.gen_micros.to_le_bytes());
        out.extend_from_slice(&self.rank.to_le_bytes());
        out.push(self.dtype as u8);
        out.push(self.shape.len() as u8);
        for d in &self.shape {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(self.field.len() as u16).to_le_bytes());
        out.extend_from_slice(self.field.as_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode from the binary wire format (validates magic + CRC).
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32()?;
        if magic != MAGIC {
            bail!("bad record magic 0x{magic:08x}");
        }
        let step = r.u64()?;
        let gen_micros = r.u64()?;
        let rank = r.u32()?;
        let dtype = Dtype::from_u8(r.u8()?)?;
        let ndim = r.u8()? as usize;
        if ndim > 4 {
            bail!("too many dims: {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()?);
        }
        let name_len = r.u16()? as usize;
        let name = r.bytes(name_len)?;
        let field = String::from_utf8(name.to_vec()).context("field name not UTF-8")?;
        let payload_len = r.u32()? as usize;
        let payload = r.bytes(payload_len)?.to_vec();
        let crc_pos = r.pos;
        let crc = r.u32()?;
        let want = crc32(&buf[..crc_pos]);
        if crc != want {
            bail!("record CRC mismatch: got 0x{crc:08x} want 0x{want:08x}");
        }
        let n: usize = shape.iter().map(|&d| d as usize).product();
        if n * dtype.size() != payload.len() {
            bail!(
                "shape {shape:?} implies {} bytes but payload has {}",
                n * dtype.size(),
                payload.len()
            );
        }
        Ok(StreamRecord {
            field,
            rank,
            step,
            gen_micros,
            dtype,
            shape,
            payload: Arc::new(payload),
        })
    }
}

/// Stream key for a (field, rank) pair: `"<field>/<rank>"`.
pub fn stream_key(field: &str, rank: u32) -> String {
    format!("{field}/{rank}")
}

/// Parse a stream key back into (field, rank).
pub fn parse_stream_key(key: &str) -> Option<(&str, u32)> {
    let (field, rank) = key.rsplit_once('/')?;
    Some((field, rank.parse().ok()?))
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("record truncated at offset {} (need {n} more bytes)", self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, F32Vec};
    use crate::util::rng::Rng;

    fn sample() -> StreamRecord {
        StreamRecord::from_f32("velocity", 3, 120, 1_700_000_000_000_000, &[2, 4], &[
            0.0, 1.0, -2.5, 3.25, 4.0, 5.5, -6.0, 7.75,
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip_basic() {
        let r = sample();
        let got = StreamRecord::decode(&r.encode()).unwrap();
        assert_eq!(got, r);
        assert_eq!(got.payload_f32().unwrap()[2], -2.5);
    }

    #[test]
    fn encoded_len_is_exact() {
        let r = sample();
        assert_eq!(r.encode().len(), r.encoded_len());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(StreamRecord::from_f32("v", 0, 0, 0, &[3, 3], &[0.0; 8]).is_err());
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = sample().encode();
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        assert!(StreamRecord::decode(&buf).is_err());
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let buf = sample().encode();
        for cut in 0..buf.len() {
            assert!(
                StreamRecord::decode(&buf[..cut]).is_err(),
                "decode of {cut}-byte prefix should fail"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = sample().encode();
        buf[0] ^= 0xFF;
        assert!(StreamRecord::decode(&buf).is_err());
    }

    #[test]
    fn stream_key_roundtrip() {
        assert_eq!(stream_key("velocity", 12), "velocity/12");
        assert_eq!(parse_stream_key("velocity/12"), Some(("velocity", 12)));
        assert_eq!(parse_stream_key("a/b/7"), Some(("a/b", 7)));
        assert_eq!(parse_stream_key("norank"), None);
    }

    /// Property: arbitrary f32 payloads roundtrip bit-exactly.
    #[test]
    fn prop_roundtrip_arbitrary_payloads() {
        let gen = F32Vec { max_len: 512, scale: 1e6 };
        prop::forall(0x5EED, 100, &gen, |data| {
            let shape = [data.len() as u32];
            let r = StreamRecord::from_f32("u", 7, 9, 11, &shape, data)
                .map_err(|e| e.to_string())?;
            let got = StreamRecord::decode(&r.encode()).map_err(|e| e.to_string())?;
            if got != r {
                return Err("record mismatch".into());
            }
            let back = got.payload_f32().map_err(|e| e.to_string())?;
            if back.iter().zip(data).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err("payload bits changed".into());
            }
            Ok(())
        });
    }

    /// Exhaustive corruption sweep: flipping EVERY byte of the encoded
    /// record (one at a time) must make decode reject it — either the
    /// CRC catches it or a schema check does, but it never slips
    /// through as a "valid" record.
    #[test]
    fn every_byte_flip_rejected() {
        let buf = sample().encode();
        for i in 0..buf.len() {
            let mut fuzzed = buf.clone();
            fuzzed[i] ^= 0xFF;
            assert!(
                StreamRecord::decode(&fuzzed).is_err(),
                "flip of byte {i} (of {}) went undetected",
                buf.len()
            );
        }
    }

    /// Property: single-bit flips anywhere are detected (CRC or schema).
    #[test]
    fn prop_bit_flips_detected() {
        let r = sample();
        let buf = r.encode();
        let mut rng = Rng::new(77);
        for _ in 0..300 {
            let byte = rng.next_below(buf.len() as u64) as usize;
            let bit = rng.next_below(8) as u8;
            let mut fuzzed = buf.clone();
            fuzzed[byte] ^= 1 << bit;
            match StreamRecord::decode(&fuzzed) {
                Err(_) => {}
                Ok(got) => panic!("undetected corruption at byte {byte} bit {bit}: {got:?}"),
            }
        }
    }
}
