//! Pure-Rust mirror of the Layer-2 `dmd_reduced` graph + the paper's
//! stability metric.
//!
//! The compiled artifact computes `(Ã, σ)` from a snapshot window; this
//! module computes the same quantities with [`Mat`] ops and
//! [`eig::jacobi_symmetric`].  It serves as
//!
//! 1. the fallback when artifacts are not built (tests, quickstart),
//! 2. the cross-check that the PJRT path returns the right numbers
//!    (integration test `pjrt_matches_fallback`), and
//! 3. the reference semantics documented for downstream users.
//!
//! The eigenvalue step ([`dmd_eigenvalues`]) and the Fig 5 metric
//! ([`stability_metric`]) are shared by both paths.

use anyhow::{ensure, Result};

use super::{eig, Complex, Mat};

/// Result of the DMD reduction for one window.
#[derive(Clone, Debug)]
pub struct DmdReduced {
    /// Projected operator Ã (rank × rank).
    pub atilde: Mat,
    /// Singular values of X1 (descending, length rank).
    pub sigma: Vec<f64>,
}

/// Reusable intermediates for [`dmd_reduce_from_gram_with`]: the
/// analysis engine keeps one per executor thread (its thread-local
/// workspace, reshaped on demand) so the per-fire reduction does not
/// allocate its `m×m` / `m×r` working matrices on every trigger.
#[derive(Default)]
pub struct GramScratch {
    g: Mat,
    k: Mat,
    vr: Mat,
    kv: Mat,
}

impl GramScratch {
    fn ensure(&mut self, m: usize, rank: usize) {
        let resize = |mat: &mut Mat, r: usize, c: usize| {
            if (mat.rows, mat.cols) != (r, c) {
                *mat = Mat::zeros(r, c);
            }
        };
        resize(&mut self.g, m, m);
        resize(&mut self.k, m, m);
        resize(&mut self.vr, m, rank);
        resize(&mut self.kv, m, rank);
    }
}

/// Reduce a snapshot window to `(Ã, σ)` — mirror of `model.dmd_reduced`.
///
/// `x` is `(d, m+1)`: column `j` is the snapshot at window step `j`.
pub fn dmd_reduce(x: &Mat, rank: usize) -> Result<DmdReduced> {
    ensure!(x.cols >= 2, "need at least 2 snapshots, got {}", x.cols);
    // C = XᵀX (the gram kernel's job in the artifact) — symmetric-half
    // sweep, no xᵀ materialization.
    let c = crate::linalg::gram(x); // (m+1, m+1)
    dmd_reduce_from_gram(&c, rank)
}

/// Reduce starting from the window's Gram matrix `C = XᵀX`
/// (`(m+1)×(m+1)`) — the entry point shared by the PJRT mirror and the
/// analysis engine's incrementally-maintained Gram cache: everything
/// downstream of C only ever touches `O(m²)` data, so a caller that can
/// update C in `O(d·m)` per window slide never pays the `O(d·m²)`
/// recompute.
pub fn dmd_reduce_from_gram(c: &Mat, rank: usize) -> Result<DmdReduced> {
    let mut scratch = GramScratch::default();
    dmd_reduce_from_gram_with(c, rank, &mut scratch)
}

/// [`dmd_reduce_from_gram`] with caller-owned scratch (no per-call
/// intermediate allocations beyond the returned `Ã`).
pub fn dmd_reduce_from_gram_with(
    c: &Mat,
    rank: usize,
    scratch: &mut GramScratch,
) -> Result<DmdReduced> {
    ensure!(c.is_square(), "gram matrix must be square, got {}x{}", c.rows, c.cols);
    let m = c.rows.checked_sub(1).filter(|&m| m > 0);
    let m = match m {
        Some(m) => m,
        None => anyhow::bail!("need at least 2 snapshots, got {}", c.rows),
    };
    ensure!(rank >= 1 && rank <= m, "rank {rank} out of range 1..={m}");
    scratch.ensure(m, rank);

    // G = X1ᵀX1, K = X1ᵀX2 are sub-blocks of C.
    for i in 0..m {
        for j in 0..m {
            scratch.g[(i, j)] = c[(i, j)];
            scratch.k[(i, j)] = c[(i, j + 1)];
        }
    }

    // Symmetric eigendecomposition of G (12 sweeps = the HLO solver).
    let (evals, v) = eig::jacobi_symmetric(&scratch.g, 12);

    // Rank-r truncation by descending eigenvalue.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| evals[b].partial_cmp(&evals[a]).unwrap());
    let idx = &order[..rank];
    let sigma: Vec<f64> = idx.iter().map(|&i| evals[i].max(0.0).sqrt()).collect();

    for (col, &i) in idx.iter().enumerate() {
        for row in 0..m {
            scratch.vr[(row, col)] = v[(row, i)];
        }
    }

    // Degenerate-mode guard (mirror of model.py): σ_i ≪ σ_1 modes are
    // zeroed rather than divided by, so float noise cannot masquerade
    // as explosive eigenvalues on near-constant regions.
    let sigma1 = sigma.first().copied().unwrap_or(0.0).max(1e-30);
    let inv_sigma: Vec<f64> = sigma
        .iter()
        .map(|&s| if s > 1e-5 * sigma1 { 1.0 / s } else { 0.0 })
        .collect();

    // Ã = Σ⁻¹ Vᵀ K V Σ⁻¹.  KV lands in scratch; the (r×r) core is
    // contracted directly against Vr without materializing Vrᵀ.
    scratch.k.matmul_into(&scratch.vr, &mut scratch.kv); // (m, r)
    let mut atilde = Mat::zeros(rank, rank);
    for i in 0..rank {
        for j in 0..rank {
            let mut core = 0.0;
            for l in 0..m {
                core += scratch.vr[(l, i)] * scratch.kv[(l, j)];
            }
            atilde[(i, j)] = core * inv_sigma[i] * inv_sigma[j];
        }
    }
    Ok(DmdReduced { atilde, sigma })
}

/// DMD eigenvalues of a projected operator (Francis QR).
pub fn dmd_eigenvalues(atilde: &Mat) -> Result<Vec<Complex>> {
    eig::eigenvalues(atilde).map_err(|e| {
        log::warn!("dmd_eigenvalues failed on {atilde:?}");
        e
    })
}

/// The paper's Fig 5 metric: "average sum of square distances from
/// eigenvalues to the unit circle".  0 ⇒ all modes neutrally stable
/// (steady oscillation); larger ⇒ transient growth/decay in the region.
pub fn stability_metric(eigs: &[Complex]) -> f64 {
    if eigs.is_empty() {
        return 0.0;
    }
    eigs.iter().map(|l| (l.abs() - 1.0).powi(2)).sum::<f64>() / eigs.len() as f64
}

/// Full fallback analysis of a window: reduce → eig → metric.
pub fn analyze_window(x: &Mat, rank: usize) -> Result<(Vec<Complex>, Vec<f64>, f64)> {
    let red = dmd_reduce(x, rank)?;
    let eigs = dmd_eigenvalues(&red.atilde)?;
    let metric = stability_metric(&eigs);
    Ok((eigs, red.sigma, metric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sort_spectrum;
    use crate::util::rng::Rng;

    /// x_{k+1} = A x_k with a known spectrum embedded in a d-dim space.
    fn linear_system_snapshots(
        d: usize,
        n_snap: usize,
        blocks: &[(f64, f64)], // (re, im) per mode; im != 0 ⇒ 2x2 block
        seed: u64,
    ) -> (Mat, Vec<Complex>) {
        let mut rng = Rng::new(seed);
        let mut dims = 0;
        for &(_, im) in blocks {
            dims += if im != 0.0 { 2 } else { 1 };
        }
        let mut dyn_m = Mat::zeros(dims, dims);
        let mut spectrum = Vec::new();
        let mut o = 0;
        for &(re, im) in blocks {
            if im != 0.0 {
                dyn_m[(o, o)] = re;
                dyn_m[(o, o + 1)] = -im;
                dyn_m[(o + 1, o)] = im;
                dyn_m[(o + 1, o + 1)] = re;
                spectrum.push(Complex::new(re, im));
                spectrum.push(Complex::new(re, -im));
                o += 2;
            } else {
                dyn_m[(o, o)] = re;
                spectrum.push(Complex::new(re, 0.0));
                o += 1;
            }
        }
        // random orthonormal spatial modes (Gram-Schmidt)
        let mut phi = Mat::zeros(d, dims);
        for c in 0..dims {
            let mut col: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
            for prev in 0..c {
                let dot: f64 = (0..d).map(|r| col[r] * phi[(r, prev)]).sum();
                for r in 0..d {
                    col[r] -= dot * phi[(r, prev)];
                }
            }
            let norm = col.iter().map(|v| v * v).sum::<f64>().sqrt();
            for r in 0..d {
                phi[(r, c)] = col[r] / norm;
            }
        }
        let mut z: Vec<f64> = (0..dims).map(|_| 1.0 + rng.next_f64()).collect();
        let mut x = Mat::zeros(d, n_snap);
        for snap in 0..n_snap {
            for r in 0..d {
                let mut v = 0.0;
                for c in 0..dims {
                    v += phi[(r, c)] * z[c];
                }
                x[(r, snap)] = v;
            }
            // z ← dyn z
            let mut nz = vec![0.0; dims];
            for i in 0..dims {
                for j in 0..dims {
                    nz[i] += dyn_m[(i, j)] * z[j];
                }
            }
            z = nz;
        }
        (x, spectrum)
    }

    #[test]
    fn recovers_real_spectrum() {
        let (x, want) = linear_system_snapshots(128, 9, &[(0.95, 0.0), (0.8, 0.0), (0.5, 0.0)], 1);
        let (eigs, sigma, _) = analyze_window(&x, 3).unwrap();
        let got = sort_spectrum(eigs);
        let want = sort_spectrum(want);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.re - w.re).abs() < 1e-4 && g.im.abs() < 1e-4, "{g:?} vs {w:?}");
        }
        assert!(sigma.windows(2).all(|w| w[0] >= w[1] - 1e-9));
    }

    #[test]
    fn recovers_complex_pair() {
        let (x, want) =
            linear_system_snapshots(256, 9, &[(0.9, 0.3), (0.7, 0.0)], 2);
        let (eigs, _, _) = analyze_window(&x, 3).unwrap();
        let got = sort_spectrum(eigs);
        let want = sort_spectrum(want);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.re - w.re).abs() < 1e-3 && (g.im - w.im).abs() < 1e-3,
                "{got:?} vs {want:?}"
            );
        }
    }

    #[test]
    fn stability_metric_zero_on_unit_circle() {
        let th: f64 = 0.7;
        let eigs = vec![
            Complex::new(th.cos(), th.sin()),
            Complex::new(th.cos(), -th.sin()),
            Complex::new(1.0, 0.0),
        ];
        assert!(stability_metric(&eigs) < 1e-12);
    }

    #[test]
    fn stability_metric_grows_with_decay() {
        let near = vec![Complex::new(0.99, 0.0)];
        let far = vec![Complex::new(0.5, 0.0)];
        assert!(stability_metric(&near) < stability_metric(&far));
        assert!((stability_metric(&far) - 0.25).abs() < 1e-12);
        assert_eq!(stability_metric(&[]), 0.0);
    }

    #[test]
    fn oscillatory_flow_more_stable_than_decaying() {
        // A steady oscillation (unit-circle modes) must score closer to 0
        // than a fast-decaying transient — the Fig 5 interpretation.
        let (x_osc, _) = linear_system_snapshots(200, 9, &[(0.995_f64.cos() as f64, 0.1), (1.0, 0.0)], 3);
        let (x_dec, _) = linear_system_snapshots(200, 9, &[(0.6, 0.0), (0.4, 0.0)], 4);
        let (_, _, m_osc) = analyze_window(&x_osc, 3).unwrap();
        let (_, _, m_dec) = analyze_window(&x_dec, 2).unwrap();
        assert!(m_osc < m_dec, "osc {m_osc} vs dec {m_dec}");
    }

    #[test]
    fn rejects_degenerate_windows() {
        assert!(dmd_reduce(&Mat::zeros(16, 1), 1).is_err());
        assert!(dmd_reduce(&Mat::zeros(16, 5), 0).is_err());
        assert!(dmd_reduce(&Mat::zeros(16, 5), 5).is_err());
        assert!(dmd_reduce_from_gram(&Mat::zeros(5, 4), 2).is_err()); // not square
        assert!(dmd_reduce_from_gram(&Mat::zeros(1, 1), 1).is_err()); // m = 0
        assert!(dmd_reduce_from_gram(&Mat::zeros(5, 5), 5).is_err()); // rank > m
    }

    /// The Gram entry point is the same computation as the full reduce,
    /// and scratch reuse across shapes does not corrupt results.
    #[test]
    fn reduce_from_gram_matches_reduce() {
        let (x, _) = linear_system_snapshots(96, 9, &[(0.9, 0.2), (0.7, 0.0)], 11);
        let red = dmd_reduce(&x, 3).unwrap();
        let c = crate::linalg::gram(&x);
        let red2 = dmd_reduce_from_gram(&c, 3).unwrap();
        assert!(red.atilde.max_abs_diff(&red2.atilde) < 1e-12);
        assert_eq!(red.sigma.len(), red2.sigma.len());
        for (a, b) in red.sigma.iter().zip(&red2.sigma) {
            assert!((a - b).abs() < 1e-12);
        }
        // reuse one scratch across two different (m, rank) shapes
        let mut scratch = GramScratch::default();
        let red3 = dmd_reduce_from_gram_with(&c, 3, &mut scratch).unwrap();
        assert!(red2.atilde.max_abs_diff(&red3.atilde) < 1e-15);
        let (x2, _) = linear_system_snapshots(64, 6, &[(0.8, 0.0)], 12);
        let c2 = crate::linalg::gram(&x2);
        let red4 = dmd_reduce_from_gram_with(&c2, 2, &mut scratch).unwrap();
        assert_eq!((red4.atilde.rows, red4.atilde.cols), (2, 2));
        // and back to the first shape: identical numbers again
        let red5 = dmd_reduce_from_gram_with(&c, 3, &mut scratch).unwrap();
        assert!(red2.atilde.max_abs_diff(&red5.atilde) < 1e-15);
    }

    #[test]
    fn constant_field_is_neutrally_stable() {
        // A constant (steady) field gives λ ≈ 1 ⇒ metric ≈ 0.
        let mut x = Mat::zeros(64, 9);
        let mut rng = Rng::new(9);
        let col: Vec<f64> = (0..64).map(|_| rng.next_normal()).collect();
        for j in 0..9 {
            for i in 0..64 {
                x[(i, j)] = col[i];
            }
        }
        let (eigs, _, metric) = analyze_window(&x, 1).unwrap();
        assert!((eigs[0].re - 1.0).abs() < 1e-6, "{eigs:?}");
        assert!(metric < 1e-10);
    }
}
