//! Dense linear algebra substrate.
//!
//! The AOT artifact hands the analysis executor a small (r×r, r ≤ 16)
//! non-symmetric projected operator `Ã`; its eigenvalues are the DMD
//! eigenvalues.  A general real eigensolver needs dynamically-converging
//! QR iteration, which does not belong in a static HLO graph and which
//! the CPU PJRT plugin could only do via LAPACK custom-calls it cannot
//! execute — so it lives here, in Rust, on the request path:
//!
//! * [`Mat`] — row-major dense matrix with the handful of ops we need,
//! * [`eig::eigenvalues`] — Householder-Hessenberg + Francis
//!   double-shift QR (the classic EISPACK `hqr` scheme),
//! * [`eig::jacobi_symmetric`] — cyclic Jacobi for symmetric matrices
//!   (test oracle, and the mirror of the L2 HLO eigensolver),
//! * [`dmd`] — a pure-Rust mirror of the L2 `dmd_reduced` graph
//!   (fallback when artifacts are absent + cross-validation of the PJRT
//!   path) and the paper's Fig 5 stability metric.

pub mod dmd;
pub mod eig;

use std::fmt;

use anyhow::{ensure, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from a flat row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f64]) -> Result<Self> {
        ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(Mat { rows, cols, data: data.to_vec() })
    }

    /// f32 convenience (artifact outputs are f32).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(Mat {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        })
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // i-k-j loop order: streams `other` rows, decent cache behaviour
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute element difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  ")?;
            for j in 0..self.cols {
                write!(f, "{:>12.6} ", self[(i, j)])?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

/// A complex number as (re, im) — all we need for eigenvalue lists.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Sort eigenvalues canonically (by |λ| descending, ties by re, im) so
/// spectra can be compared across solvers.
pub fn sort_spectrum(mut eigs: Vec<Complex>) -> Vec<Complex> {
    eigs.sort_by(|a, b| {
        b.abs()
            .partial_cmp(&a.abs())
            .unwrap()
            .then(b.re.partial_cmp(&a.re).unwrap())
            .then(b.im.partial_cmp(&a.im).unwrap())
    });
    eigs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().rows, 3);
    }

    #[test]
    fn fro_norm() {
        let a = Mat::from_rows(&[&[3.0, 4.0]]);
        assert!((a.fro() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sort_spectrum_by_magnitude() {
        let s = sort_spectrum(vec![
            Complex::new(0.1, 0.0),
            Complex::new(0.0, 1.0),
            Complex::new(-0.5, 0.0),
        ]);
        assert_eq!(s[0], Complex::new(0.0, 1.0));
        assert_eq!(s[2], Complex::new(0.1, 0.0));
    }
}
