//! Dense linear algebra substrate.
//!
//! The AOT artifact hands the analysis executor a small (r×r, r ≤ 16)
//! non-symmetric projected operator `Ã`; its eigenvalues are the DMD
//! eigenvalues.  A general real eigensolver needs dynamically-converging
//! QR iteration, which does not belong in a static HLO graph and which
//! the CPU PJRT plugin could only do via LAPACK custom-calls it cannot
//! execute — so it lives here, in Rust, on the request path:
//!
//! * [`Mat`] — row-major dense matrix with the handful of ops we need,
//! * [`eig::eigenvalues`] — Householder-Hessenberg + Francis
//!   double-shift QR (the classic EISPACK `hqr` scheme),
//! * [`eig::jacobi_symmetric`] — cyclic Jacobi for symmetric matrices
//!   (test oracle, and the mirror of the L2 HLO eigensolver),
//! * [`dmd`] — a pure-Rust mirror of the L2 `dmd_reduced` graph
//!   (fallback when artifacts are absent + cross-validation of the PJRT
//!   path) and the paper's Fig 5 stability metric.

pub mod dmd;
pub mod eig;

use std::fmt;

use anyhow::{ensure, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, PartialEq, Default)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from a flat row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f64]) -> Result<Self> {
        ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(Mat { rows, cols, data: data.to_vec() })
    }

    /// f32 convenience (artifact outputs are f32).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(Mat {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        })
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `out = self × other` without allocating: the hot-path variant the
    /// analysis engine uses with scratch matrices reused across fires.
    /// The shared dimension is tiled so a block of `other` rows stays in
    /// cache while every output row accumulates against it.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(
            (out.rows, out.cols),
            (self.rows, other.cols),
            "matmul_into output shape mismatch"
        );
        const TILE: usize = 64;
        let n = other.cols;
        out.data.fill(0.0);
        let mut kb = 0;
        while kb < self.cols {
            let kend = (kb + TILE).min(self.cols);
            // i-k-j loop order: streams `other` rows, decent cache behaviour
            for i in 0..self.rows {
                let arow = &self.data[i * self.cols..(i + 1) * self.cols];
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for k in kb..kend {
                    let a = arow[k];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &other.data[k * n..(k + 1) * n];
                    for (o, &b) in out_row.iter_mut().zip(orow) {
                        *o += a * b;
                    }
                }
            }
            kb = kend;
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute element difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  ")?;
            for j in 0..self.cols {
                write!(f, "{:>12.6} ", self[(i, j)])?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

/// Gram matrix `C = XᵀX` of a (d × m) snapshot matrix, computed
/// symmetric-half-only straight from the row-major storage — no `x.t()`
/// materialization and half the multiplies of `x.t().matmul(x)`.
///
/// One sweep over the rows of `x`: row `i` contributes the outer
/// product of itself with itself to the upper triangle, then the lower
/// triangle is mirrored.  Per entry the products are accumulated over
/// `i` ascending — the same order as [`dot_f32_f64acc`] over column
/// slices, so the incremental analysis cache and this full recompute
/// agree to the last bit.
pub fn gram(x: &Mat) -> Mat {
    let (d, m) = (x.rows, x.cols);
    let mut c = Mat::zeros(m, m);
    for i in 0..d {
        let row = &x.data[i * m..(i + 1) * m];
        for j in 0..m {
            let xj = row[j];
            if xj == 0.0 {
                continue;
            }
            let crow = &mut c.data[j * m..(j + 1) * m];
            for k in j..m {
                crow[k] += xj * row[k];
            }
        }
    }
    for j in 0..m {
        for k in j + 1..m {
            c.data[k * m + j] = c.data[j * m + k];
        }
    }
    c
}

/// Dot product of two raw f32 snapshot slices with f64 accumulation —
/// the primitive the incremental Gram cache is built from.  Consuming
/// the stored f32 snapshots directly kills the per-fire f32→f64
/// widening copy of the whole window the old path paid.
#[inline]
pub fn dot_f32_f64acc(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len(), "dot length mismatch");
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as f64 * y as f64;
    }
    acc
}

/// Gram matrix of a window given as raw f32 snapshot columns
/// (`snaps[j]` is snapshot `j`, all the same length): `C[j][k] =
/// snaps[j] · snaps[k]` with f64 accumulation, symmetric-half-only.
/// This is the full-recompute path of the analysis engine — it never
/// widens the window to f64 storage.
pub fn gram_from_snaps<S: AsRef<[f32]>>(snaps: &[S]) -> Mat {
    let m = snaps.len();
    let mut c = Mat::zeros(m, m);
    for j in 0..m {
        for k in j..m {
            let v = dot_f32_f64acc(snaps[j].as_ref(), snaps[k].as_ref());
            c.data[j * m + k] = v;
            c.data[k * m + j] = v;
        }
    }
    c
}

/// Apply `pending` one-snapshot window slides to a cached Gram matrix
/// in one shot: shift the surviving block up-left (ascending indices,
/// so the source is always at or past the destination — no overlap
/// hazard), then fill every entry involving the `pending` newest
/// snapshots with fresh [`dot_f32_f64acc`] dot products.  `snap(i)`
/// must yield window snapshot `i` (0 = oldest) of the *current* window.
///
/// Returns whether every freshly computed entry is finite: the last
/// column pairs the newest snapshot with every stored one, and a dot
/// against a NaN/∞ snapshot can never come back finite, so a finite
/// batch implies no non-finite snapshot remains anywhere in the window.
///
/// This is the analysis engine's steady-state per-fire kernel
/// (O(pending·d·m) instead of the O(d·m²) full recompute); the
/// `micro_linalg` bench times this same function.
pub fn gram_slide_update<'a, F>(g: &mut Mat, pending: usize, snap: F) -> bool
where
    F: Fn(usize) -> &'a [f32],
{
    debug_assert!(g.is_square());
    let m1 = g.rows;
    debug_assert!(pending <= m1);
    for i in pending..m1 {
        for j in pending..m1 {
            g.data[(i - pending) * m1 + (j - pending)] = g.data[i * m1 + j];
        }
    }
    let mut finite = true;
    for col in m1 - pending..m1 {
        let sc = snap(col);
        for row in 0..=col {
            let v = dot_f32_f64acc(snap(row), sc);
            finite &= v.is_finite();
            g.data[row * m1 + col] = v;
            g.data[col * m1 + row] = v;
        }
    }
    finite
}

/// A complex number as (re, im) — all we need for eigenvalue lists.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Sort eigenvalues canonically (by |λ| descending, ties by re, im) so
/// spectra can be compared across solvers.
pub fn sort_spectrum(mut eigs: Vec<Complex>) -> Vec<Complex> {
    eigs.sort_by(|a, b| {
        b.abs()
            .partial_cmp(&a.abs())
            .unwrap()
            .then(b.re.partial_cmp(&a.re).unwrap())
            .then(b.im.partial_cmp(&a.im).unwrap())
    });
    eigs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matmul_into_matches_matmul_nonsquare() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        // sizes straddling the k-tile boundary (64)
        for (r, k, c) in [(3usize, 5usize, 4usize), (7, 64, 3), (5, 130, 9)] {
            let mut a = Mat::zeros(r, k);
            let mut b = Mat::zeros(k, c);
            for v in a.data.iter_mut() {
                *v = rng.next_normal();
            }
            for v in b.data.iter_mut() {
                *v = rng.next_normal();
            }
            let want = a.matmul(&b);
            let mut out = Mat::zeros(r, c);
            a.matmul_into(&b, &mut out);
            assert!(want.max_abs_diff(&out) < 1e-12, "{r}x{k}x{c}");
        }
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        for (d, m) in [(17usize, 5usize), (128, 9), (64, 1)] {
            let mut x = Mat::zeros(d, m);
            for v in x.data.iter_mut() {
                *v = rng.next_normal();
            }
            let want = x.t().matmul(&x);
            let got = gram(&x);
            assert!(want.max_abs_diff(&got) < 1e-9, "d={d} m={m}");
            // exactly symmetric by construction
            for j in 0..m {
                for k in 0..m {
                    assert_eq!(got[(j, k)], got[(k, j)]);
                }
            }
        }
    }

    #[test]
    fn dot_f32_f64acc_known() {
        assert_eq!(dot_f32_f64acc(&[], &[]), 0.0);
        assert_eq!(dot_f32_f64acc(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        // f64 accumulation: sums that overflow f32 precision stay exact
        let a = vec![16_777_216.0f32; 4]; // 2^24
        let b = vec![1.0f32; 4];
        assert_eq!(dot_f32_f64acc(&a, &b), 4.0 * 16_777_216.0);
    }

    #[test]
    fn gram_from_snaps_matches_widened_gram() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let (d, m1) = (53usize, 6usize);
        let snaps: Vec<Vec<f32>> = (0..m1)
            .map(|_| (0..d).map(|_| rng.next_normal() as f32).collect())
            .collect();
        // widen to a (d, m1) Mat, column j = snapshot j
        let mut x = Mat::zeros(d, m1);
        for (j, s) in snaps.iter().enumerate() {
            for i in 0..d {
                x[(i, j)] = s[i] as f64;
            }
        }
        let want = gram(&x);
        let got = gram_from_snaps(&snaps);
        assert!(want.max_abs_diff(&got) < 1e-12);
    }

    /// Property: sliding an existing Gram (1..=pending evictions at a
    /// time) equals recomputing it from the current window.
    #[test]
    fn gram_slide_update_matches_recompute() {
        use crate::util::rng::Rng;
        use std::collections::VecDeque;
        let mut rng = Rng::new(41);
        let (d, m1) = (37usize, 6usize);
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..d).map(|_| rng.next_normal() as f32).collect()
        };
        let mut window: VecDeque<Vec<f32>> = (0..m1).map(|_| mk(&mut rng)).collect();
        let refs = |w: &VecDeque<Vec<f32>>| -> Vec<Vec<f32>> { w.iter().cloned().collect() };
        let mut g = gram_from_snaps(&refs(&window));
        for step in 0..10usize {
            let pending = 1 + step % 3; // ≤ m1/2
            for _ in 0..pending {
                window.pop_front();
                window.push_back(mk(&mut rng));
            }
            let snaps: Vec<&[f32]> = window.iter().map(|s| s.as_slice()).collect();
            assert!(gram_slide_update(&mut g, pending, |i| snaps[i]));
            let want = gram_from_snaps(&snaps);
            assert!(want.max_abs_diff(&g) < 1e-12, "step {step} pending {pending}");
        }
        // a NaN snapshot is reported non-finite
        let mut bad = mk(&mut rng);
        bad[0] = f32::NAN;
        window.pop_front();
        window.push_back(bad);
        let snaps: Vec<&[f32]> = window.iter().map(|s| s.as_slice()).collect();
        assert!(!gram_slide_update(&mut g, 1, |i| snaps[i]));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().rows, 3);
    }

    #[test]
    fn fro_norm() {
        let a = Mat::from_rows(&[&[3.0, 4.0]]);
        assert!((a.fro() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sort_spectrum_by_magnitude() {
        let s = sort_spectrum(vec![
            Complex::new(0.1, 0.0),
            Complex::new(0.0, 1.0),
            Complex::new(-0.5, 0.0),
        ]);
        assert_eq!(s[0], Complex::new(0.0, 1.0));
        assert_eq!(s[2], Complex::new(0.1, 0.0));
    }
}
