//! Real dense eigensolvers.
//!
//! [`eigenvalues`] = Householder-Hessenberg reduction followed by the
//! Francis implicit double-shift QR iteration (the classic EISPACK
//! `hqr` scheme, as in Numerical Recipes §11.6) — eigenvalues only,
//! which is all DMD needs (the paper's Fig 5 plots spectra, not modes).
//!
//! [`jacobi_symmetric`] is a cyclic Jacobi eigensolver for symmetric
//! matrices: it both serves as an independent oracle for `eigenvalues`
//! in tests and mirrors the Layer-2 HLO Jacobi used inside the
//! `dmd_reduced` artifact, so the Rust fallback path computes exactly
//! the same quantities as the compiled graph.

use anyhow::{bail, ensure, Result};

use super::{Complex, Mat};

/// Reduce a square matrix to upper-Hessenberg form in place
/// (Householder reflections; similarity transform, spectrum preserved).
pub fn hessenberg(a: &mut Mat) {
    assert!(a.is_square());
    let n = a.rows;
    for k in 0..n.saturating_sub(2) {
        // Householder vector for column k, rows k+1..n.
        let mut norm2 = 0.0;
        for i in k + 1..n {
            norm2 += a[(i, k)] * a[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            continue;
        }
        let alpha = -norm.copysign(a[(k + 1, k)]);
        let mut v = vec![0.0; n]; // only k+1.. used
        v[k + 1] = a[(k + 1, k)] - alpha;
        for i in k + 2..n {
            v[i] = a[(i, k)];
        }
        let vnorm2: f64 = v[k + 1..].iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        // A ← (I - 2vvᵀ/vᵀv) A : rows k+1..n
        for j in 0..n {
            let mut dot = 0.0;
            for i in k + 1..n {
                dot += v[i] * a[(i, j)];
            }
            let scale = 2.0 * dot / vnorm2;
            for i in k + 1..n {
                a[(i, j)] -= scale * v[i];
            }
        }
        // A ← A (I - 2vvᵀ/vᵀv) : cols k+1..n
        for i in 0..n {
            let mut dot = 0.0;
            for j in k + 1..n {
                dot += a[(i, j)] * v[j];
            }
            let scale = 2.0 * dot / vnorm2;
            for j in k + 1..n {
                a[(i, j)] -= scale * v[j];
            }
        }
        // Exact zeros below the subdiagonal in this column.
        a[(k + 1, k)] = alpha;
        for i in k + 2..n {
            a[(i, k)] = 0.0;
        }
    }
}

/// Eigenvalues of an upper-Hessenberg matrix via Francis double-shift QR
/// (consumes/overwrites the matrix).
pub fn hqr(mut a: Mat) -> Result<Vec<Complex>> {
    assert!(a.is_square());
    let n = a.rows;
    let mut eigs = Vec::with_capacity(n);
    if n == 0 {
        return Ok(eigs);
    }
    if n == 1 {
        eigs.push(Complex::new(a[(0, 0)], 0.0));
        return Ok(eigs);
    }

    // Norm over the Hessenberg envelope (deflation threshold scale).
    let mut anorm = 0.0;
    for i in 0..n {
        for j in i.saturating_sub(1)..n {
            anorm += a[(i, j)].abs();
        }
    }
    if anorm == 0.0 {
        // zero matrix
        return Ok(vec![Complex::new(0.0, 0.0); n]);
    }
    let eps = f64::EPSILON;
    let mut t = 0.0; // accumulated exceptional shifts
    let mut nn = n as isize - 1;

    while nn >= 0 {
        let mut its = 0;
        loop {
            // Look for a single negligible subdiagonal element.
            let mut l: isize = 0;
            {
                let mut ll = nn;
                while ll >= 1 {
                    let (lu, _) = (ll as usize, ());
                    let mut s = a[(lu - 1, lu - 1)].abs() + a[(lu, lu)].abs();
                    if s == 0.0 {
                        s = anorm;
                    }
                    if a[(lu, lu - 1)].abs() <= eps * s {
                        a[(lu, lu - 1)] = 0.0;
                        l = ll;
                        break;
                    }
                    ll -= 1;
                }
            }
            let nnu = nn as usize;
            let mut x = a[(nnu, nnu)];
            if l == nn {
                // One real root found.
                eigs.push(Complex::new(x + t, 0.0));
                nn -= 1;
                break;
            }
            let mut y = a[(nnu - 1, nnu - 1)];
            let mut w = a[(nnu, nnu - 1)] * a[(nnu - 1, nnu)];
            if l == nn - 1 {
                // Two roots from the trailing 2×2 block.
                let p = 0.5 * (y - x);
                let q = p * p + w;
                let z = q.abs().sqrt();
                let xt = x + t;
                if q >= 0.0 {
                    let z = p + z.copysign(p);
                    let e1 = xt + z;
                    let e2 = if z != 0.0 { xt - w / z } else { e1 };
                    eigs.push(Complex::new(e1, 0.0));
                    eigs.push(Complex::new(e2, 0.0));
                } else {
                    eigs.push(Complex::new(xt + p, z));
                    eigs.push(Complex::new(xt + p, -z));
                }
                nn -= 2;
                break;
            }

            if its == 60 {
                bail!("hqr: no convergence after 60 iterations on a block");
            }
            if its % 10 == 0 && its > 0 {
                // Exceptional shift (Wilkinson's ad-hoc restart).
                t += x;
                for i in 0..=nnu {
                    a[(i, i)] -= x;
                }
                let s = a[(nnu, nnu - 1)].abs() + a[(nnu - 1, nnu - 2)].abs();
                x = 0.75 * s;
                y = x;
                w = -0.4375 * s * s;
            }
            its += 1;

            // Find two consecutive small subdiagonals (start of the bulge).
            let mut m = nn - 2;
            let (mut p, mut q, mut r) = (0.0f64, 0.0f64, 0.0f64);
            while m >= l {
                let mu = m as usize;
                let z = a[(mu, mu)];
                let rr = x - z;
                let ss = y - z;
                p = (rr * ss - w) / a[(mu + 1, mu)] + a[(mu, mu + 1)];
                q = a[(mu + 1, mu + 1)] - z - rr - ss;
                r = a[(mu + 2, mu + 1)];
                let s = p.abs() + q.abs() + r.abs();
                p /= s;
                q /= s;
                r /= s;
                if m == l {
                    break;
                }
                let u = a[(mu, mu - 1)].abs() * (q.abs() + r.abs());
                let v = p.abs()
                    * (a[(mu - 1, mu - 1)].abs() + z.abs() + a[(mu + 1, mu + 1)].abs());
                if u <= eps * v {
                    break;
                }
                m -= 1;
            }
            let m = m.max(l) as usize;
            for i in m + 2..=nnu {
                a[(i, i - 2)] = 0.0;
                if i > m + 2 {
                    a[(i, i - 3)] = 0.0;
                }
            }

            // Double QR sweep: chase the bulge from m to nn-1.
            for k in m..nnu {
                if k != m {
                    p = a[(k, k - 1)];
                    q = a[(k + 1, k - 1)];
                    r = if k != nnu - 1 { a[(k + 2, k - 1)] } else { 0.0 };
                    x = p.abs() + q.abs() + r.abs();
                    if x != 0.0 {
                        p /= x;
                        q /= x;
                        r /= x;
                    }
                }
                let s = (p * p + q * q + r * r).sqrt().copysign(p);
                if s == 0.0 {
                    continue;
                }
                if k == m {
                    if l != m as isize {
                        a[(k, k - 1)] = -a[(k, k - 1)];
                    }
                } else {
                    a[(k, k - 1)] = -s * x;
                }
                p += s;
                x = p / s;
                y = q / s;
                let z = r / s;
                q /= p;
                r /= p;
                // Row modification.
                for j in k..=nnu {
                    let mut pp = a[(k, j)] + q * a[(k + 1, j)];
                    if k != nnu - 1 {
                        pp += r * a[(k + 2, j)];
                        a[(k + 2, j)] -= pp * z;
                    }
                    a[(k + 1, j)] -= pp * y;
                    a[(k, j)] -= pp * x;
                }
                // Column modification.
                let mmin = nnu.min(k + 3);
                for i in l as usize..=mmin {
                    let mut pp = x * a[(i, k)] + y * a[(i, k + 1)];
                    if k != nnu - 1 {
                        pp += z * a[(i, k + 2)];
                        a[(i, k + 2)] -= pp * r;
                    }
                    a[(i, k + 1)] -= pp * q;
                    a[(i, k)] -= pp;
                }
            }
        }
    }
    Ok(eigs)
}

/// Eigenvalues of a general real square matrix.
pub fn eigenvalues(a: &Mat) -> Result<Vec<Complex>> {
    ensure!(a.is_square(), "eigenvalues: matrix must be square");
    let mut h = a.clone();
    hessenberg(&mut h);
    hqr(h)
}

/// Cyclic Jacobi eigendecomposition for symmetric matrices.
///
/// Returns `(eigenvalues, eigenvectors)` with columns of `v` the
/// eigenvectors (unsorted).  `sweeps` full cycles — 12 matches the
/// Layer-2 HLO solver inside the `dmd_reduced` artifact.
pub fn jacobi_symmetric(a: &Mat, sweeps: usize) -> (Vec<f64>, Mat) {
    assert!(a.is_square());
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    for _ in 0..sweeps {
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let tau = (m[(q, q)] - m[(p, p)]) / (2.0 * apq);
                let sgn = if tau >= 0.0 { 1.0 } else { -1.0 };
                let t = sgn / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // M ← Jᵀ M J, applied as row/col updates.
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for j in 0..n {
                    let mpj = m[(p, j)];
                    let mqj = m[(q, j)];
                    m[(p, j)] = c * mpj - s * mqj;
                    m[(q, j)] = s * mpj + c * mqj;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }
    let evals = (0..n).map(|i| m[(i, i)]).collect();
    (evals, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sort_spectrum;
    use crate::util::rng::Rng;

    fn assert_spectrum_close(got: Vec<Complex>, want: Vec<Complex>, tol: f64) {
        let got = sort_spectrum(got);
        let want = sort_spectrum(want);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.re - w.re).abs() < tol && (g.im - w.im).abs() < tol,
                "eig mismatch: got {g:?} want {w:?} (all got {got:?} want {want:?})"
            );
        }
    }

    #[test]
    fn eig_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0, 0.0], &[0.0, -1.0, 0.0], &[0.0, 0.0, 0.5]]);
        let eigs = eigenvalues(&a).unwrap();
        assert_spectrum_close(
            eigs,
            vec![
                Complex::new(3.0, 0.0),
                Complex::new(-1.0, 0.0),
                Complex::new(0.5, 0.0),
            ],
            1e-10,
        );
    }

    #[test]
    fn eig_rotation_block_complex_pair() {
        // 2D rotation scaled by 0.9: eigenvalues 0.9 e^{±iθ}
        let th = 0.4f64;
        let (c, s) = (th.cos(), th.sin());
        let a = Mat::from_rows(&[&[0.9 * c, -0.9 * s], &[0.9 * s, 0.9 * c]]);
        let eigs = eigenvalues(&a).unwrap();
        assert_spectrum_close(
            eigs,
            vec![
                Complex::new(0.9 * c, 0.9 * s),
                Complex::new(0.9 * c, -0.9 * s),
            ],
            1e-10,
        );
    }

    #[test]
    fn eig_companion_matrix_known_roots() {
        // p(x) = (x-1)(x-2)(x-3) = x^3 - 6x^2 + 11x - 6
        let a = Mat::from_rows(&[&[6.0, -11.0, 6.0], &[1.0, 0.0, 0.0], &[0.0, 1.0, 0.0]]);
        let eigs = eigenvalues(&a).unwrap();
        assert_spectrum_close(
            eigs,
            vec![
                Complex::new(1.0, 0.0),
                Complex::new(2.0, 0.0),
                Complex::new(3.0, 0.0),
            ],
            1e-8,
        );
    }

    #[test]
    fn eig_defective_jordan_block() {
        // Jordan block: double eigenvalue 2, defective.
        let a = Mat::from_rows(&[&[2.0, 1.0], &[0.0, 2.0]]);
        let eigs = eigenvalues(&a).unwrap();
        assert_spectrum_close(
            eigs,
            vec![Complex::new(2.0, 0.0), Complex::new(2.0, 0.0)],
            1e-7,
        );
    }

    #[test]
    fn eig_matches_jacobi_on_random_symmetric() {
        let mut rng = Rng::new(101);
        for n in [2usize, 3, 5, 8, 12, 16] {
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.next_normal();
                    a[(i, j)] = v;
                    a[(j, i)] = v;
                }
            }
            let got = eigenvalues(&a).unwrap();
            let (mut want, _) = jacobi_symmetric(&a, 20);
            want.sort_by(|x, y| y.partial_cmp(x).unwrap());
            let got = sort_spectrum(got);
            for g in &got {
                assert!(g.im.abs() < 1e-8, "symmetric matrix gave complex eig {g:?}");
            }
            let mut got_re: Vec<f64> = got.iter().map(|c| c.re).collect();
            got_re.sort_by(|x, y| y.partial_cmp(x).unwrap());
            for (g, w) in got_re.iter().zip(&want) {
                assert!((g - w).abs() < 1e-7 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn eig_similarity_invariant_known_spectrum() {
        // Build A = Q B Qᵀ with B block-diagonal (known spectrum), Q a
        // product of random Householder reflections.
        let mut rng = Rng::new(55);
        let spectrum = [
            Complex::new(0.95, 0.0),
            Complex::new(0.7, 0.3),
            Complex::new(0.7, -0.3),
            Complex::new(-0.2, 0.0),
            Complex::new(0.1, 0.8),
            Complex::new(0.1, -0.8),
        ];
        let n = spectrum.len();
        let mut b = Mat::zeros(n, n);
        b[(0, 0)] = 0.95;
        b[(1, 1)] = 0.7;
        b[(1, 2)] = -0.3;
        b[(2, 1)] = 0.3;
        b[(2, 2)] = 0.7;
        b[(3, 3)] = -0.2;
        b[(4, 4)] = 0.1;
        b[(4, 5)] = -0.8;
        b[(5, 4)] = 0.8;
        b[(5, 5)] = 0.1;
        // random orthogonal similarity
        let mut q = Mat::eye(n);
        for _ in 0..3 {
            let mut v: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            v.iter_mut().for_each(|x| *x /= norm);
            let mut h = Mat::eye(n);
            for i in 0..n {
                for j in 0..n {
                    h[(i, j)] -= 2.0 * v[i] * v[j];
                }
            }
            q = q.matmul(&h);
        }
        let a = q.matmul(&b).matmul(&q.t());
        let eigs = eigenvalues(&a).unwrap();
        assert_spectrum_close(eigs, spectrum.to_vec(), 1e-8);
    }

    #[test]
    fn eig_scale_edge_cases() {
        for scale in [1e-8, 1.0, 1e8] {
            let a = Mat::from_rows(&[
                &[0.0 * scale, 1.0 * scale],
                &[-1.0 * scale, 0.0 * scale],
            ]);
            let eigs = eigenvalues(&a).unwrap();
            assert_spectrum_close(
                eigs,
                vec![Complex::new(0.0, scale), Complex::new(0.0, -scale)],
                1e-8 * scale,
            );
        }
    }

    #[test]
    fn eig_zero_and_tiny_matrices() {
        assert!(eigenvalues(&Mat::zeros(0, 0)).unwrap().is_empty());
        let e = eigenvalues(&Mat::from_rows(&[&[7.0]])).unwrap();
        assert_eq!(e, vec![Complex::new(7.0, 0.0)]);
        let e = eigenvalues(&Mat::zeros(4, 4)).unwrap();
        assert_eq!(e.len(), 4);
        for c in e {
            assert_eq!((c.re, c.im), (0.0, 0.0));
        }
    }

    #[test]
    fn hessenberg_preserves_spectrum_structure() {
        let mut rng = Rng::new(7);
        let n = 8;
        let mut a = Mat::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.next_normal();
        }
        let mut h = a.clone();
        hessenberg(&mut h);
        // zero below subdiagonal
        for i in 0..n {
            for j in 0..i.saturating_sub(1) {
                assert_eq!(h[(i, j)], 0.0, "({i},{j}) not zeroed");
            }
        }
        // Frobenius norm preserved by the orthogonal similarity
        assert!((a.fro() - h.fro()).abs() < 1e-9 * a.fro());
    }

    #[test]
    fn jacobi_diagonalizes() {
        let a = Mat::from_rows(&[&[4.0, 1.0, 0.5], &[1.0, 3.0, 0.2], &[0.5, 0.2, 1.0]]);
        let (evals, v) = jacobi_symmetric(&a, 15);
        // A v_i = λ_i v_i
        for i in 0..3 {
            for r in 0..3 {
                let mut av = 0.0;
                for c in 0..3 {
                    av += a[(r, c)] * v[(c, i)];
                }
                assert!((av - evals[i] * v[(r, i)]).abs() < 1e-9);
            }
        }
        let mut sorted = evals.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let trace: f64 = (0..3).map(|i| a[(i, i)]).sum();
        assert!((sorted.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    /// Property: eigenvalue sum ≈ trace, product of |λ| ≈ |det| (via the
    /// spectrum of random matrices against those invariants).
    #[test]
    fn prop_trace_invariant_random() {
        let mut rng = Rng::new(2024);
        for trial in 0..50 {
            let n = 2 + (trial % 9);
            let mut a = Mat::zeros(n, n);
            for v in a.data.iter_mut() {
                *v = rng.next_normal();
            }
            let eigs = eigenvalues(&a).unwrap();
            assert_eq!(eigs.len(), n);
            let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum_re: f64 = eigs.iter().map(|c| c.re).sum();
            let sum_im: f64 = eigs.iter().map(|c| c.im).sum();
            assert!(
                (sum_re - trace).abs() < 1e-7 * (1.0 + trace.abs()),
                "trial {trial}: trace {trace} vs eig-sum {sum_re}"
            );
            assert!(sum_im.abs() < 1e-7, "imaginary parts don't cancel: {sum_im}");
        }
    }
}
