//! Online DMD analysis of incoming data streams — the paper's §3.2
//! analysis application (PyDMD inside Spark executors).
//!
//! Each data stream (one simulation rank's field) keeps a sliding
//! window of the last `m+1` snapshots.  When the window is full, the
//! engine computes the windowed exact-DMD reduction `(Ã, σ)` — through
//! the **AOT-compiled PJRT artifact** when one matches the snapshot
//! dimension, else through the pure-Rust mirror — then the DMD
//! eigenvalues (Francis QR, [`crate::linalg::eig`]) and the paper's
//! Fig 5 stability metric.
//!
//! The engine is `Sync` and is shared by all executor threads: state is
//! per-stream, so partitions (≡ streams) never contend on the same
//! window.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::linalg::{dmd, Complex, Mat};
use crate::metrics::WorkflowMetrics;
use crate::record::StreamRecord;
use crate::runtime::ArtifactSet;
use crate::streamproc::MicroBatch;
use crate::util;

/// One analysis output (a point in a Fig 5 subplot).
#[derive(Clone, Debug)]
pub struct AnalysisResult {
    /// Stream key (`"<field>/<rank>"`).
    pub key: String,
    pub rank: u32,
    /// Simulation step of the newest snapshot in the window.
    pub step: u64,
    /// Mean squared distance of the DMD eigenvalues to the unit circle.
    pub stability: f64,
    /// DMD eigenvalues of the window.
    pub eigs: Vec<Complex>,
    /// Singular values of X1 (descending).
    pub sigma: Vec<f64>,
    /// Generation → analysis latency of the newest snapshot (µs) — the
    /// paper's §4.3 quality-of-service metric.
    pub latency_us: u64,
    /// Which path computed the reduction ("pjrt" or "rust").
    pub backend: &'static str,
}

/// Which implementation computes the (Ã, σ) reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DmdBackend {
    /// The AOT-compiled PJRT artifact when one matches the snapshot
    /// dimension, else the Rust mirror.  This is the three-layer
    /// architecture's default: on accelerator-class PJRT backends the
    /// compiled gram kernel wins; on the CPU plugin its per-dispatch
    /// overhead (~2 ms) can exceed the maths for small `d` — see
    /// EXPERIMENTS.md §Perf for measurements.
    #[default]
    Pjrt,
    /// Always the pure-Rust mirror (identical semantics).
    Rust,
}

/// When a stream's window is (re)analysed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FirePolicy {
    /// Once per new snapshot (subject to `hop`) — maximal time
    /// resolution, cost ∝ snapshot rate.
    #[default]
    PerSnapshot,
    /// Once per micro-batch per stream, on the newest window — the
    /// paper's behaviour ("the DMD analysis [is] triggered every 3
    /// seconds for all data streams"); cost ∝ trigger rate.
    PerBatch,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DmdConfig {
    /// Window length m (the reduction uses m+1 snapshots).
    pub window: usize,
    /// Truncation rank r ≤ m.
    pub rank: usize,
    /// Recompute every `hop` new snapshots once the window is full
    /// (`PerSnapshot` only).
    pub hop: usize,
    /// Reduction backend policy.
    pub backend: DmdBackend,
    /// Analysis cadence.
    pub fire: FirePolicy,
}

impl Default for DmdConfig {
    fn default() -> Self {
        DmdConfig {
            window: 8,
            rank: 6,
            hop: 1,
            backend: DmdBackend::Pjrt,
            fire: FirePolicy::PerSnapshot,
        }
    }
}

struct WindowState {
    /// (step, gen_micros, snapshot) in arrival order.
    snaps: VecDeque<(u64, u64, Vec<f32>)>,
    /// New snapshots since the last analysis.
    since_last: usize,
    last_step: Option<u64>,
}

/// The per-stream windowed DMD engine.
pub struct DmdEngine {
    cfg: DmdConfig,
    artifacts: Option<Arc<ArtifactSet>>,
    windows: Mutex<HashMap<String, WindowState>>,
    metrics: WorkflowMetrics,
}

impl DmdEngine {
    pub fn new(
        cfg: DmdConfig,
        artifacts: Option<Arc<ArtifactSet>>,
        metrics: WorkflowMetrics,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.window >= 2, "window must be >= 2");
        anyhow::ensure!(
            cfg.rank >= 1 && cfg.rank <= cfg.window,
            "rank {} out of 1..={}",
            cfg.rank,
            cfg.window
        );
        anyhow::ensure!(cfg.hop >= 1, "hop must be >= 1");
        Ok(DmdEngine {
            cfg,
            artifacts,
            windows: Mutex::new(HashMap::new()),
            metrics,
        })
    }

    /// Process one micro-batch (one partition of a trigger): push every
    /// record into its stream's window, emit an analysis per full
    /// window (respecting the hop).
    pub fn process(&self, batch: &MicroBatch) -> Vec<AnalysisResult> {
        let mut out = Vec::new();
        let n = batch.records.len();
        for (i, rec) in batch.records.iter().enumerate() {
            let may_fire = match self.cfg.fire {
                FirePolicy::PerSnapshot => true,
                FirePolicy::PerBatch => i + 1 == n, // newest window only
            };
            match self.push_inner(&batch.key, rec, may_fire) {
                Ok(Some(res)) => out.push(res),
                Ok(None) => {}
                Err(e) => {
                    log::warn!("analysis: {}: {e:#}", batch.key);
                }
            }
        }
        out
    }

    /// Push one snapshot; returns an analysis when the window fires.
    pub fn push(&self, key: &str, rec: &StreamRecord) -> Result<Option<AnalysisResult>> {
        self.push_inner(key, rec, true)
    }

    fn push_inner(
        &self,
        key: &str,
        rec: &StreamRecord,
        may_fire: bool,
    ) -> Result<Option<AnalysisResult>> {
        let data = rec.payload_f32()?;
        let m1 = self.cfg.window + 1;
        let mut windows = self.windows.lock().unwrap();
        let st = windows.entry(key.to_string()).or_insert_with(|| WindowState {
            snaps: VecDeque::with_capacity(m1),
            since_last: 0,
            last_step: None,
        });
        // Drop duplicate/reordered steps (at-least-once transport).
        if let Some(last) = st.last_step {
            if rec.step <= last {
                log::debug!("analysis: {key}: dropping stale step {} <= {last}", rec.step);
                return Ok(None);
            }
        }
        st.last_step = Some(rec.step);
        if let Some(front) = st.snaps.front() {
            anyhow::ensure!(
                front.2.len() == data.len(),
                "snapshot dim changed mid-stream: {} vs {}",
                front.2.len(),
                data.len()
            );
        }
        st.snaps.push_back((rec.step, rec.gen_micros, data));
        while st.snaps.len() > m1 {
            st.snaps.pop_front();
        }
        if st.snaps.len() < m1 {
            return Ok(None);
        }
        st.since_last += 1;
        if !may_fire {
            return Ok(None);
        }
        if self.cfg.fire == FirePolicy::PerSnapshot && st.since_last < self.cfg.hop {
            return Ok(None);
        }
        st.since_last = 0;

        // Assemble X (d × m+1), column j = snapshot j.
        let d = st.snaps[0].2.len();
        let mut x = vec![0.0f32; d * m1];
        for (j, (_, _, snap)) in st.snaps.iter().enumerate() {
            for i in 0..d {
                x[i * m1 + j] = snap[i];
            }
        }
        let (step, gen_us) = {
            let newest = st.snaps.back().unwrap();
            (newest.0, newest.1)
        };
        drop(windows); // analysis itself runs without the map lock

        let (atilde, sigma, backend) = self.reduce(d, m1, &x)?;
        let eigs = dmd::dmd_eigenvalues(&atilde)?;
        let stability = dmd::stability_metric(&eigs);
        let latency_us = util::epoch_micros().saturating_sub(gen_us);
        self.metrics.e2e_latency_us.record(latency_us);
        self.metrics.analyzed.record((d * 4) as u64);
        let (_, rank) = crate::record::parse_stream_key(key).unwrap_or((key, u32::MAX));
        Ok(Some(AnalysisResult {
            key: key.to_string(),
            rank,
            step,
            stability,
            eigs,
            sigma,
            latency_us,
            backend,
        }))
    }

    /// Pre-compile the PJRT reduction for an expected snapshot
    /// dimension so the first trigger doesn't pay the compile (the
    /// paper's service is warm by the time the simulation connects).
    pub fn warm(&self, d: usize) {
        if let Some(arts) = &self.artifacts {
            let key = format!("d{}_m{}_r{}", d, self.cfg.window + 1, self.cfg.rank);
            if arts.find("dmd", &key).is_some() {
                if let Err(e) = arts.executable("dmd", &key) {
                    log::warn!("analysis: warm-up compile failed for {key}: {e:#}");
                }
            } else {
                log::info!(
                    "analysis: no dmd artifact for d={d} (key {key}); Rust fallback will serve"
                );
            }
        }
    }

    /// The (Ã, σ) reduction: PJRT artifact when the shape matches, else
    /// the Rust mirror.
    fn reduce(&self, d: usize, m1: usize, x: &[f32]) -> Result<(Mat, Vec<f64>, &'static str)> {
        if self.cfg.backend == DmdBackend::Pjrt {
            if let Some(arts) = &self.artifacts {
                let key = format!("d{}_m{}_r{}", d, m1, self.cfg.rank);
                if arts.find("dmd", &key).is_some() {
                    let exe = arts.executable("dmd", &key)?;
                    let out = exe.run_f32(&[x])?;
                    if out[0].iter().all(|v| v.is_finite()) {
                        let r = self.cfg.rank;
                        let atilde = Mat::from_f32(r, r, &out[0]).context("atilde shape")?;
                        let sigma = out[1].iter().map(|&v| v as f64).collect();
                        return Ok((atilde, sigma, "pjrt"));
                    }
                    // Diagnosed in EXPERIMENTS.md §Perf: extremely
                    // settled windows can drive the f32 Jacobi sweep in
                    // the artifact to a non-finite rotation.  Keep the
                    // service available: fall through to the f64 mirror.
                    if std::env::var("ELASTICBROKER_DUMP_NAN").is_ok() {
                        let path = format!("/tmp/eb_nan_window_{d}_{m1}.bin");
                        let bytes: Vec<u8> =
                            x.iter().flat_map(|v| v.to_le_bytes()).collect();
                        let _ = std::fs::write(&path, bytes);
                        log::warn!("analysis: dumped NaN-producing window to {path}");
                    }
                    log::warn!(
                        "analysis: PJRT dmd artifact returned non-finite Ã (d={d}); \
                         using Rust mirror for this window"
                    );
                }
            }
        }
        let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let xm = Mat::from_slice(d, m1, &xf)?;
        let red = dmd::dmd_reduce(&xm, self.cfg.rank)?;
        Ok((red.atilde, red.sigma, "rust"))
    }

    /// Streams currently tracked.
    pub fn tracked_streams(&self) -> usize {
        self.windows.lock().unwrap().len()
    }
}

/// CSV sink for analysis results (the Fig 5 data file).
pub struct CsvSink {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl CsvSink {
    pub fn create(path: &str) -> Result<Self> {
        let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(
            w,
            "key,rank,step,stability,latency_us,backend,sigma0,eigs_re_im"
        )?;
        Ok(CsvSink { w: Mutex::new(w) })
    }

    pub fn write(&self, r: &AnalysisResult) -> Result<()> {
        let eigs: Vec<String> = r
            .eigs
            .iter()
            .map(|c| format!("{:.6}:{:.6}", c.re, c.im))
            .collect();
        let mut w = self.w.lock().unwrap();
        writeln!(
            w,
            "{},{},{},{:.8},{},{},{:.6},{}",
            r.key,
            r.rank,
            r.step,
            r.stability,
            r.latency_us,
            r.backend,
            r.sigma.first().copied().unwrap_or(0.0),
            eigs.join(";")
        )?;
        Ok(())
    }

    pub fn flush(&self) -> Result<()> {
        self.w.lock().unwrap().flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap_record(rank: u32, step: u64, data: &[f32]) -> StreamRecord {
        StreamRecord::from_f32("u", rank, step, util::epoch_micros(), &[data.len() as u32], data)
            .unwrap()
    }

    fn engine(window: usize, rank: usize) -> DmdEngine {
        DmdEngine::new(
            DmdConfig {
                window,
                rank,
                hop: 1,
                ..Default::default()
            },
            None, // rust fallback: deterministic, no artifacts needed
            WorkflowMetrics::new(),
        )
        .unwrap()
    }

    /// Decaying oscillation snapshots: x_k = cos(θk)·a·rᵏ + sin(θk)·b·rᵏ.
    fn oscillating_snapshot(d: usize, k: usize, r: f64, theta: f64) -> Vec<f32> {
        let growth = r.powi(k as i32);
        (0..d)
            .map(|i| {
                let phase = i as f64 * 0.37;
                (growth * ((theta * k as f64) + phase).cos()) as f32
            })
            .collect()
    }

    #[test]
    fn window_fills_then_fires() {
        let eng = engine(4, 2);
        let d = 64;
        let mut fired = 0;
        for step in 0..8 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.95, 0.5));
            if eng.push("u/0", &rec).unwrap().is_some() {
                fired += 1;
            }
        }
        // window m+1 = 5 fills at step index 4; fires every push after
        assert_eq!(fired, 4);
        assert_eq!(eng.tracked_streams(), 1);
    }

    #[test]
    fn recovers_decay_rate() {
        let eng = engine(8, 2);
        let d = 128;
        let r = 0.9;
        let mut last = None;
        for step in 0..9 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, r, 0.4));
            if let Some(res) = eng.push("u/0", &rec).unwrap() {
                last = Some(res);
            }
        }
        let res = last.expect("window should have fired");
        // dominant eigenvalue magnitude ≈ decay rate r
        let lead = res.eigs.iter().map(|e| e.abs()).fold(0.0, f64::max);
        assert!((lead - r).abs() < 0.05, "lead |λ|={lead} want ~{r}");
        assert!(res.stability > 0.0);
        assert_eq!(res.backend, "rust");
        assert!(res.latency_us < 10_000_000);
    }

    #[test]
    fn neutral_oscillation_scores_near_zero() {
        let eng = engine(8, 2);
        let d = 96;
        let mut last = None;
        for step in 0..9 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 1.0, 0.6));
            if let Some(res) = eng.push("u/0", &rec).unwrap() {
                last = Some(res);
            }
        }
        let res = last.unwrap();
        assert!(
            res.stability < 1e-3,
            "unit-circle dynamics should be ~stable: {}",
            res.stability
        );
    }

    #[test]
    fn duplicate_and_stale_steps_ignored() {
        let eng = engine(3, 2);
        let d = 32;
        let mk = |s: u64| snap_record(0, s, &oscillating_snapshot(d, s as usize, 0.9, 0.3));
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none());
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none()); // dup
        assert!(eng.push("u/0", &mk(1)).unwrap().is_none());
        assert!(eng.push("u/0", &mk(1)).unwrap().is_none()); // dup
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none()); // stale
        assert!(eng.push("u/0", &mk(2)).unwrap().is_none());
        // 4th distinct snapshot fills window m+1=4 → fires
        assert!(eng.push("u/0", &mk(3)).unwrap().is_some());
    }

    #[test]
    fn streams_are_independent() {
        let eng = engine(2, 1);
        let d = 16;
        for step in 0..3 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.9, 0.2));
            eng.push("u/0", &rec).unwrap();
        }
        // u/1 only has 1 snapshot: must not fire
        let rec = snap_record(1, 0, &oscillating_snapshot(d, 0, 0.9, 0.2));
        assert!(eng.push("u/1", &rec).unwrap().is_none());
        assert_eq!(eng.tracked_streams(), 2);
    }

    #[test]
    fn hop_reduces_fire_rate() {
        let eng = DmdEngine::new(
            DmdConfig {
                window: 3,
                rank: 2,
                hop: 3,
                ..Default::default()
            },
            None,
            WorkflowMetrics::new(),
        )
        .unwrap();
        let d = 32;
        let mut fired = 0;
        for step in 0..12 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.9, 0.3));
            if eng.push("u/0", &rec).unwrap().is_some() {
                fired += 1;
            }
        }
        // window fills at snapshot 4; 8 more pushes → fires at hop=3 → 2-3
        assert!((2..=3).contains(&fired), "fired {fired}");
    }

    #[test]
    fn dim_change_is_error() {
        let eng = engine(3, 2);
        let rec = snap_record(0, 0, &vec![1.0; 32]);
        eng.push("u/0", &rec).unwrap();
        let bad = snap_record(0, 1, &vec![1.0; 64]);
        assert!(eng.push("u/0", &bad).is_err());
    }

    #[test]
    fn process_batch_end_to_end() {
        let eng = engine(3, 2);
        let d = 48;
        let records: Vec<StreamRecord> = (0..6)
            .map(|s| snap_record(2, s, &oscillating_snapshot(d, s as usize, 0.92, 0.5)))
            .collect();
        let batch = MicroBatch {
            key: "u/2".into(),
            records,
        };
        let out = eng.process(&batch);
        assert_eq!(out.len(), 3); // fills at 4th, fires on 4,5,6th
        assert!(out.iter().all(|r| r.rank == 2));
        assert!(out.windows(2).all(|w| w[0].step < w[1].step));
    }

    #[test]
    fn csv_sink_writes_rows() {
        let dir = std::env::temp_dir().join(format!("eb-csv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.csv");
        let sink = CsvSink::create(path.to_str().unwrap()).unwrap();
        let res = AnalysisResult {
            key: "u/0".into(),
            rank: 0,
            step: 42,
            stability: 0.125,
            eigs: vec![Complex::new(0.9, 0.1)],
            sigma: vec![3.0, 1.0],
            latency_us: 1234,
            backend: "rust",
        };
        sink.write(&res).unwrap();
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 2);
        assert!(text.contains("u/0,0,42,0.12500000,1234,rust"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
