//! Online DMD analysis of incoming data streams — the paper's §3.2
//! analysis application (PyDMD inside Spark executors).
//!
//! Each data stream (one simulation rank's field) keeps a sliding
//! window of the last `m+1` snapshots.  When the window is full, the
//! engine computes the windowed exact-DMD reduction `(Ã, σ)` — through
//! the **AOT-compiled PJRT artifact** when one matches the snapshot
//! dimension, else through the pure-Rust mirror — then the DMD
//! eigenvalues (Francis QR, [`crate::linalg::eig`]) and the paper's
//! Fig 5 stability metric.
//!
//! # Analysis perf model
//!
//! Everything downstream of the Gram matrix `C = XᵀX` only touches
//! `O(m²)` data, and a one-snapshot window slide changes exactly one
//! row and column of C.  The engine therefore keeps a cached
//! `(m+1)×(m+1)` Gram per stream, synced at fire time: the slides since
//! the last fire are applied in one shot — shift the surviving block,
//! fill the new rows/cols with [`crate::linalg::dot_f32_f64acc`] dot
//! products against the stored f32 snapshots — so the steady-state
//! per-fire snapshot-dimension cost drops from `O(d·m²)` (flatten +
//! widen + `XᵀX` from scratch) to `O(d·m)`, and non-firing pushes
//! (PerBatch cadence, hop) pay nothing.  The cached entries are
//! *exact*: each is the same f64-accumulated dot product a full
//! recompute would produce, so incremental and full reductions agree to
//! the last bit.  Belt and braces anyway: the cache is rebuilt from the
//! stored snapshots when more than half the window changed between
//! fires, every [`DmdConfig::gram_refresh`] slides, and when a fresh
//! entry is non-finite (the fire is skipped while non-finite data is in
//! the window).  Benchmark with `cargo bench --bench micro_linalg`
//! (see `BENCH_linalg.json`).
//!
//! The engine is `Sync` and is shared by all executor threads: window
//! state is FNV-sharded by stream key across [`DmdConfig::shards`]
//! independent maps (the same pattern as `endpoint::store`), so
//! executor threads analysing different streams never contend on one
//! global lock, and the reduction itself runs with no lock held at all.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::linalg::{dmd, Complex, Mat};
use crate::metrics::WorkflowMetrics;
use crate::record::StreamRecord;
use crate::runtime::ArtifactSet;
use crate::streamproc::MicroBatch;
use crate::util;

/// One analysis output (a point in a Fig 5 subplot).
#[derive(Clone, Debug)]
pub struct AnalysisResult {
    /// Stream key (`"<field>/<rank>"`).
    pub key: String,
    pub rank: u32,
    /// Simulation step of the newest snapshot in the window.
    pub step: u64,
    /// Mean squared distance of the DMD eigenvalues to the unit circle.
    pub stability: f64,
    /// DMD eigenvalues of the window.
    pub eigs: Vec<Complex>,
    /// Singular values of X1 (descending).
    pub sigma: Vec<f64>,
    /// Generation → analysis latency of the newest snapshot (µs) — the
    /// paper's §4.3 quality-of-service metric.
    pub latency_us: u64,
    /// Which path computed the reduction ("pjrt" or "rust").
    pub backend: &'static str,
}

/// Stream-key prefix for published analysis results (ISSUE 6).
pub const RESULTS_PREFIX: &str = "results";

/// The endpoint stream key `source_key`'s analysis results are
/// published on: `results/<field>/<rank>`.
/// [`crate::record::parse_stream_key`] splits on the *last* `/`, so
/// the published record's field is `results/<field>` and the rank
/// survives round trips through the reader machinery unchanged.
pub fn results_key(source_key: &str) -> String {
    format!("{RESULTS_PREFIX}/{source_key}")
}

/// Results-record payload magic (`EBRA` little-endian).
const RESULTS_MAGIC: u32 = 0x4152_4245;
const RESULTS_VERSION: u32 = 1;
/// Fixed payload bytes before the eigenvalue/σ arrays.
const RESULTS_HEADER: usize = 40;

impl AnalysisResult {
    /// Pack this result into a compact [`StreamRecord`] for the
    /// results stream.  Every f64 travels as its raw IEEE-754 bytes
    /// inside the payload — no f32 round trip anywhere — so
    /// [`AnalysisResult::from_record`] recovers the engine's values
    /// bit-exactly.  Payload layout (all little-endian):
    ///
    /// ```text
    /// u32 magic "EBRA"   u32 version   u32 backend (0=rust 1=pjrt)
    /// u32 n_eigs         u32 n_sigma   u32 pad
    /// u64 latency_us     f64 stability
    /// (f64 re, f64 im) × n_eigs        f64 × n_sigma
    /// ```
    ///
    /// The record's field is [`results_key`]`(self.key)` minus the
    /// rank suffix, its rank/step mirror the source fire, and
    /// `gen_micros` is stamped at publish time so subscriber latency
    /// tracking keeps working.
    pub fn to_record(&self) -> StreamRecord {
        let ne = self.eigs.len();
        let ns = self.sigma.len();
        let mut p = Vec::with_capacity(RESULTS_HEADER + 16 * ne + 8 * ns);
        p.extend_from_slice(&RESULTS_MAGIC.to_le_bytes());
        p.extend_from_slice(&RESULTS_VERSION.to_le_bytes());
        let backend_tag: u32 = u32::from(self.backend == "pjrt");
        p.extend_from_slice(&backend_tag.to_le_bytes());
        p.extend_from_slice(&(ne as u32).to_le_bytes());
        p.extend_from_slice(&(ns as u32).to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&self.latency_us.to_le_bytes());
        p.extend_from_slice(&self.stability.to_le_bytes());
        for c in &self.eigs {
            p.extend_from_slice(&c.re.to_le_bytes());
            p.extend_from_slice(&c.im.to_le_bytes());
        }
        for s in &self.sigma {
            p.extend_from_slice(&s.to_le_bytes());
        }
        let (field, rank) = crate::record::parse_stream_key(&self.key)
            .unwrap_or((self.key.as_str(), self.rank));
        StreamRecord {
            field: format!("{RESULTS_PREFIX}/{field}"),
            rank,
            step: self.step,
            gen_micros: util::epoch_micros(),
            dtype: crate::record::Dtype::F32,
            shape: vec![(p.len() / 4) as u32],
            payload: Arc::new(p),
            meta: None,
        }
    }

    /// Decode a results-stream record published by
    /// [`AnalysisResult::to_record`] (bit-exact inverse).
    pub fn from_record(rec: &StreamRecord) -> Result<AnalysisResult> {
        let p: &[u8] = &rec.payload;
        anyhow::ensure!(
            p.len() >= RESULTS_HEADER,
            "results payload too short: {} bytes",
            p.len()
        );
        let u32_at = |o: usize| u32::from_le_bytes(p[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(p[o..o + 8].try_into().unwrap());
        let f64_at = |o: usize| f64::from_le_bytes(p[o..o + 8].try_into().unwrap());
        anyhow::ensure!(
            u32_at(0) == RESULTS_MAGIC,
            "not a results record (magic 0x{:08x})",
            u32_at(0)
        );
        anyhow::ensure!(
            u32_at(4) == RESULTS_VERSION,
            "unsupported results version {}",
            u32_at(4)
        );
        let backend = if u32_at(8) == 1 { "pjrt" } else { "rust" };
        let ne = u32_at(12) as usize;
        let ns = u32_at(16) as usize;
        anyhow::ensure!(
            p.len() == RESULTS_HEADER + 16 * ne + 8 * ns,
            "results payload {} bytes, header implies {}",
            p.len(),
            RESULTS_HEADER + 16 * ne + 8 * ns
        );
        let latency_us = u64_at(24);
        let stability = f64_at(32);
        let mut off = RESULTS_HEADER;
        let mut eigs = Vec::with_capacity(ne);
        for _ in 0..ne {
            eigs.push(Complex::new(f64_at(off), f64_at(off + 8)));
            off += 16;
        }
        let mut sigma = Vec::with_capacity(ns);
        for _ in 0..ns {
            sigma.push(f64_at(off));
            off += 8;
        }
        let field = rec
            .field
            .strip_prefix(RESULTS_PREFIX)
            .and_then(|s| s.strip_prefix('/'))
            .unwrap_or(&rec.field);
        Ok(AnalysisResult {
            key: crate::record::stream_key(field, rec.rank),
            rank: rec.rank,
            step: rec.step,
            stability,
            eigs,
            sigma,
            latency_us,
            backend,
        })
    }
}

/// Which implementation computes the (Ã, σ) reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DmdBackend {
    /// The AOT-compiled PJRT artifact when one matches the snapshot
    /// dimension, else the Rust mirror.  This is the three-layer
    /// architecture's default: on accelerator-class PJRT backends the
    /// compiled gram kernel wins; on the CPU plugin its per-dispatch
    /// overhead (~2 ms) can exceed the maths for small `d` — see
    /// EXPERIMENTS.md §Perf for measurements.
    #[default]
    Pjrt,
    /// Always the pure-Rust mirror (identical semantics).
    Rust,
}

/// When a stream's window is (re)analysed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FirePolicy {
    /// Once per new snapshot (subject to `hop`) — maximal time
    /// resolution, cost ∝ snapshot rate.
    #[default]
    PerSnapshot,
    /// Once per micro-batch per stream, on the newest window — the
    /// paper's behaviour ("the DMD analysis [is] triggered every 3
    /// seconds for all data streams"); cost ∝ trigger rate.
    PerBatch,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DmdConfig {
    /// Window length m (the reduction uses m+1 snapshots).
    pub window: usize,
    /// Truncation rank r ≤ m.
    pub rank: usize,
    /// Recompute every `hop` new snapshots once the window is full
    /// (`PerSnapshot` only).
    pub hop: usize,
    /// Reduction backend policy.
    pub backend: DmdBackend,
    /// Analysis cadence.
    pub fire: FirePolicy,
    /// Rebuild the cached Gram from the stored snapshots every
    /// `gram_refresh` incremental slides (drift bound; 0 = never
    /// refresh periodically — the non-finite fallback still applies).
    pub gram_refresh: usize,
    /// FNV-hashed shards the per-stream window map is split across;
    /// executor threads on different streams never contend (values < 1
    /// are clamped to 1).
    pub shards: usize,
}

impl Default for DmdConfig {
    fn default() -> Self {
        DmdConfig {
            window: 8,
            rank: 6,
            hop: 1,
            backend: DmdBackend::Pjrt,
            fire: FirePolicy::PerSnapshot,
            gram_refresh: 64,
            shards: 8,
        }
    }
}

struct WindowState {
    /// (step, gen_micros, snapshot) in arrival order.
    snaps: VecDeque<(u64, u64, Vec<f32>)>,
    /// New snapshots since the last analysis.
    since_last: usize,
    last_step: Option<u64>,
    /// Cached `(m+1)×(m+1)` Gram matrix XᵀX of the window as of the
    /// last fire (None until the window first fills and fires).
    gram: Option<Mat>,
    /// Window slides since the Gram was last synced — applied in one
    /// shot at fire time, so non-firing pushes (PerBatch, hop) pay no
    /// Gram work at all.
    pending_slides: usize,
    /// Incremental slides since the last full Gram rebuild.
    slides_since_full: usize,
    /// Whether a PJRT artifact serves this stream's shape (decided once
    /// when the window first fills — the dimension is fixed per stream,
    /// the artifact registry per engine).  When true the Gram cache is
    /// never consumed, so it is not maintained either.
    pjrt_serves: Option<bool>,
}

impl WindowState {
    fn new(m1: usize) -> Self {
        WindowState {
            snaps: VecDeque::with_capacity(m1),
            since_last: 0,
            last_step: None,
            gram: None,
            pending_slides: 0,
            slides_since_full: 0,
            pjrt_serves: None,
        }
    }
}

/// The per-stream windowed DMD engine.
pub struct DmdEngine {
    cfg: DmdConfig,
    artifacts: Option<Arc<ArtifactSet>>,
    shards: Vec<Mutex<HashMap<String, WindowState>>>,
    metrics: WorkflowMetrics,
}

impl DmdEngine {
    pub fn new(
        cfg: DmdConfig,
        artifacts: Option<Arc<ArtifactSet>>,
        metrics: WorkflowMetrics,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.window >= 2, "window must be >= 2");
        anyhow::ensure!(
            cfg.rank >= 1 && cfg.rank <= cfg.window,
            "rank {} out of 1..={}",
            cfg.rank,
            cfg.window
        );
        anyhow::ensure!(cfg.hop >= 1, "hop must be >= 1");
        let n_shards = cfg.shards.max(1);
        Ok(DmdEngine {
            cfg,
            artifacts,
            shards: (0..n_shards).map(|_| Mutex::new(HashMap::new())).collect(),
            metrics,
        })
    }

    /// Which shard a stream key's window lives on.
    fn shard_of(&self, key: &str) -> usize {
        (util::fnv1a(key.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Process one micro-batch (one partition of a trigger): push every
    /// record into its stream's window, emit an analysis per full
    /// window (respecting the hop).
    pub fn process(&self, batch: &MicroBatch) -> Vec<AnalysisResult> {
        let mut out = Vec::new();
        let n = batch.records.len();
        for (i, rec) in batch.records.iter().enumerate() {
            let may_fire = match self.cfg.fire {
                FirePolicy::PerSnapshot => true,
                FirePolicy::PerBatch => i + 1 == n, // newest window only
            };
            match self.push_inner(&batch.key, rec, may_fire) {
                Ok(Some(res)) => out.push(res),
                Ok(None) => {}
                Err(e) => {
                    log::warn!("analysis: {}: {e:#}", batch.key);
                }
            }
        }
        out
    }

    /// Push one snapshot; returns an analysis when the window fires.
    pub fn push(&self, key: &str, rec: &StreamRecord) -> Result<Option<AnalysisResult>> {
        self.push_inner(key, rec, true)
    }

    fn push_inner(
        &self,
        key: &str,
        rec: &StreamRecord,
        may_fire: bool,
    ) -> Result<Option<AnalysisResult>> {
        let data = rec.payload_f32()?;
        let d = data.len();
        let m1 = self.cfg.window + 1;
        let mut windows = self.shards[self.shard_of(key)].lock().unwrap();
        // Borrowed-key fast path: no String allocation once the stream
        // is known (i.e. on every steady-state record).
        if !windows.contains_key(key) {
            windows.insert(key.to_string(), WindowState::new(m1));
        }
        let st = windows.get_mut(key).expect("window state just ensured");
        // Drop duplicate/reordered steps (at-least-once transport).
        if let Some(last) = st.last_step {
            if rec.step <= last {
                log::debug!("analysis: {key}: dropping stale step {} <= {last}", rec.step);
                return Ok(None);
            }
        }
        st.last_step = Some(rec.step);
        if let Some(front) = st.snaps.front() {
            anyhow::ensure!(
                front.2.len() == data.len(),
                "snapshot dim changed mid-stream: {} vs {}",
                front.2.len(),
                data.len()
            );
        }
        st.snaps.push_back((rec.step, rec.gen_micros, data));
        let mut slid = false;
        while st.snaps.len() > m1 {
            st.snaps.pop_front();
            slid = true;
        }
        if st.snaps.len() < m1 {
            return Ok(None);
        }
        if slid {
            st.pending_slides += 1;
        }
        st.since_last += 1;
        if !may_fire {
            return Ok(None);
        }
        if self.cfg.fire == FirePolicy::PerSnapshot && st.since_last < self.cfg.hop {
            return Ok(None);
        }
        st.since_last = 0;
        // Per-fire cost clock: covers Gram sync / window assembly and
        // the reduction — everything this fire pays.
        let t0 = Instant::now();
        // Decided once per stream: when a PJRT artifact serves this
        // shape, the fires consume the flattened f32 window and the
        // Gram cache is never read — so don't pay to maintain it.
        let pjrt_serves = *st.pjrt_serves.get_or_insert_with(|| {
            self.cfg.backend == DmdBackend::Pjrt
                && self.artifacts.as_ref().is_some_and(|arts| {
                    let akey = format!("d{}_m{}_r{}", d, m1, self.cfg.rank);
                    arts.find("dmd", &akey).is_some()
                })
        });
        // PJRT path: flatten the window to the artifact's f32 layout
        // (finiteness checked during the copy).  Rust path: sync the
        // cached Gram — O(m²) downstream, no flatten, no f32→f64
        // widening of the window.
        let (pjrt_x, window_finite) = if pjrt_serves {
            let (x, finite) = Self::assemble_window(st, d, m1);
            (Some(x), finite)
        } else {
            (None, self.sync_gram(st, m1))
        };
        if !window_finite {
            // Non-finite data in the window: the reduction could only
            // produce garbage, so skip this fire; analyses resume once
            // the bad snapshot slides out.
            log::warn!("analysis: {key}: non-finite window at step {}; skipping fire", rec.step);
            return Ok(None);
        }
        if pjrt_x.is_none() {
            // Copy the synced Gram into the executor thread's workspace
            // so the reduction runs without the shard lock.
            let gram = st.gram.as_ref().expect("gram cached when window is full");
            WORKSPACE.with(|w| {
                let mut ws = w.borrow_mut();
                let gbuf = &mut ws.0;
                if (gbuf.rows, gbuf.cols) != (gram.rows, gram.cols) {
                    *gbuf = gram.clone();
                } else {
                    gbuf.data.copy_from_slice(&gram.data);
                }
            });
        }
        let (step, gen_us) = {
            let newest = st.snaps.back().unwrap();
            (newest.0, newest.1)
        };
        drop(windows); // analysis itself runs without any shard lock

        let (atilde, sigma, backend) = match &pjrt_x {
            Some(x) => self.reduce_pjrt(d, m1, x)?,
            None => WORKSPACE.with(|w| -> Result<(Mat, Vec<f64>, &'static str)> {
                let mut ws = w.borrow_mut();
                let (gbuf, scratch) = &mut *ws;
                let red = dmd::dmd_reduce_from_gram_with(gbuf, self.cfg.rank, scratch)?;
                Ok((red.atilde, red.sigma, "rust"))
            })?,
        };
        let eigs = dmd::dmd_eigenvalues(&atilde)?;
        let stability = dmd::stability_metric(&eigs);
        self.metrics.analysis_us.record(t0.elapsed().as_micros() as u64);
        let now_us = util::epoch_micros();
        let latency_us = now_us.saturating_sub(gen_us);
        self.metrics.e2e_latency_us.record(latency_us);
        self.metrics.analyzed.record((d * 4) as u64);
        // Sampled flight-recorder hop: the fire is triggered by the
        // newest record (`rec`), so its trace — when the 1-in-N sampler
        // stamped one — closes the chain: origin → insight.
        if let Some(t) = rec.meta.as_ref().and_then(|m| m.trace) {
            self.metrics.trace.staleness_us.record(now_us.saturating_sub(t.origin_us));
            if t.deliver_us > 0 {
                self.metrics.trace.hop_analysis_us.record(now_us.saturating_sub(t.deliver_us));
            }
        }
        let (_, rank) = crate::record::parse_stream_key(key).unwrap_or((key, u32::MAX));
        Ok(Some(AnalysisResult {
            key: key.to_string(),
            rank,
            step,
            stability,
            eigs,
            sigma,
            latency_us,
            backend,
        }))
    }

    /// Bring the cached Gram up to date with the current window (fire
    /// time only — non-firing pushes just count slides).  `pending`
    /// deferred slides are applied in one shot: shift the surviving
    /// block up-left by `pending`, then fill every entry involving the
    /// `pending` newest snapshots with fresh dot products — O(pending ·
    /// d·m), exactly what eager per-slide updates would have cost, but
    /// skipped entirely for windows that never fire.  Entries are exact
    /// dot products either way, so no drift accumulates.  Falls back to
    /// a full O(d·m²) rebuild on window fill, when more than half the
    /// window changed, on the [`DmdConfig::gram_refresh`] cadence, and
    /// when a fresh entry is non-finite.  Returns whether the resulting
    /// Gram is entirely finite.
    fn sync_gram(&self, st: &mut WindowState, m1: usize) -> bool {
        debug_assert_eq!(st.snaps.len(), m1);
        let pending = st.pending_slides;
        st.pending_slides = 0;
        let refresh_due =
            self.cfg.gram_refresh > 0 && st.slides_since_full >= self.cfg.gram_refresh;
        let incremental_wins = pending <= m1 / 2;
        if let Some(g) = st.gram.as_mut().filter(|_| !refresh_due && incremental_wins) {
            if pending > 0 {
                let snaps = &st.snaps;
                let finite =
                    crate::linalg::gram_slide_update(g, pending, |i| snaps[i].2.as_slice());
                if !finite {
                    log::debug!("analysis: non-finite Gram slide; full recompute fallback");
                    return self.rebuild_gram(st);
                }
                st.slides_since_full += pending;
                self.metrics.gram_incremental.inc();
            }
            return true; // pending == 0: cache already current and finite
        }
        self.rebuild_gram(st)
    }

    /// Full Gram rebuild from the stored snapshots (window fill,
    /// refresh cadence, bulk slide, or non-finite fallback).
    fn rebuild_gram(&self, st: &mut WindowState) -> bool {
        let snaps: Vec<&[f32]> = st.snaps.iter().map(|(_, _, s)| s.as_slice()).collect();
        let g = crate::linalg::gram_from_snaps(&snaps);
        let finite = g.data.iter().all(|v| v.is_finite());
        st.gram = Some(g);
        st.slides_since_full = 0;
        self.metrics.gram_full.inc();
        finite
    }

    /// Flatten the window to the artifact's (d × m+1) f32 layout,
    /// checking finiteness during the copy (so PJRT-served streams skip
    /// non-finite fires exactly like the Gram path does).
    fn assemble_window(st: &WindowState, d: usize, m1: usize) -> (Vec<f32>, bool) {
        let mut x = vec![0.0f32; d * m1];
        let mut finite = true;
        for (j, (_, _, snap)) in st.snaps.iter().enumerate() {
            for i in 0..d {
                let v = snap[i];
                finite &= v.is_finite();
                x[i * m1 + j] = v;
            }
        }
        (x, finite)
    }

    /// Pre-compile the PJRT reduction for an expected snapshot
    /// dimension so the first trigger doesn't pay the compile (the
    /// paper's service is warm by the time the simulation connects).
    pub fn warm(&self, d: usize) {
        if let Some(arts) = &self.artifacts {
            let key = format!("d{}_m{}_r{}", d, self.cfg.window + 1, self.cfg.rank);
            if arts.find("dmd", &key).is_some() {
                if let Err(e) = arts.executable("dmd", &key) {
                    log::warn!("analysis: warm-up compile failed for {key}: {e:#}");
                }
            } else {
                log::info!(
                    "analysis: no dmd artifact for d={d} (key {key}); Rust fallback will serve"
                );
            }
        }
    }

    /// The (Ã, σ) reduction through the PJRT artifact (the caller
    /// already verified one is registered for this shape).
    fn reduce_pjrt(&self, d: usize, m1: usize, x: &[f32]) -> Result<(Mat, Vec<f64>, &'static str)> {
        let arts = self.artifacts.as_ref().expect("pjrt path without artifacts");
        let key = format!("d{}_m{}_r{}", d, m1, self.cfg.rank);
        let exe = arts.executable("dmd", &key)?;
        let out = exe.run_f32(&[x])?;
        if out[0].iter().all(|v| v.is_finite()) {
            let r = self.cfg.rank;
            let atilde = Mat::from_f32(r, r, &out[0]).context("atilde shape")?;
            let sigma = out[1].iter().map(|&v| v as f64).collect();
            return Ok((atilde, sigma, "pjrt"));
        }
        // Diagnosed in EXPERIMENTS.md §Perf: extremely settled windows
        // can drive the f32 Jacobi sweep in the artifact to a
        // non-finite rotation.  Keep the service available: fall
        // through to the f64 mirror.
        if std::env::var("ELASTICBROKER_DUMP_NAN").is_ok() {
            let path = format!("/tmp/eb_nan_window_{d}_{m1}.bin");
            let bytes: Vec<u8> = x.iter().flat_map(|v| v.to_le_bytes()).collect();
            let _ = std::fs::write(&path, bytes);
            log::warn!("analysis: dumped NaN-producing window to {path}");
        }
        log::warn!(
            "analysis: PJRT dmd artifact returned non-finite Ã (d={d}); \
             using Rust mirror for this window"
        );
        let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let xm = Mat::from_slice(d, m1, &xf)?;
        let red = dmd::dmd_reduce(&xm, self.cfg.rank)?;
        Ok((red.atilde, red.sigma, "rust"))
    }

    /// Streams currently tracked (across all shards).
    pub fn tracked_streams(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

thread_local! {
    /// Per-executor-thread reduction workspace: the Gram copy the fire
    /// works on plus the reusable reduction intermediates.  Kept
    /// thread-local so the reduction runs with no shard lock held and
    /// allocates nothing per fire after the first use on each thread.
    static WORKSPACE: std::cell::RefCell<(Mat, dmd::GramScratch)> =
        std::cell::RefCell::new((Mat::zeros(0, 0), dmd::GramScratch::default()));
}

/// CSV sink for analysis results (the Fig 5 data file).
pub struct CsvSink {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl CsvSink {
    pub fn create(path: &str) -> Result<Self> {
        let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(
            w,
            "key,rank,step,stability,latency_us,backend,sigma0,eigs_re_im"
        )?;
        Ok(CsvSink { w: Mutex::new(w) })
    }

    pub fn write(&self, r: &AnalysisResult) -> Result<()> {
        let eigs: Vec<String> = r
            .eigs
            .iter()
            .map(|c| format!("{:.6}:{:.6}", c.re, c.im))
            .collect();
        let mut w = self.w.lock().unwrap();
        writeln!(
            w,
            "{},{},{},{:.8},{},{},{:.6},{}",
            r.key,
            r.rank,
            r.step,
            r.stability,
            r.latency_us,
            r.backend,
            r.sigma.first().copied().unwrap_or(0.0),
            eigs.join(";")
        )?;
        Ok(())
    }

    pub fn flush(&self) -> Result<()> {
        self.w.lock().unwrap().flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap_record(rank: u32, step: u64, data: &[f32]) -> StreamRecord {
        StreamRecord::from_f32("u", rank, step, util::epoch_micros(), &[data.len() as u32], data)
            .unwrap()
    }

    fn engine(window: usize, rank: usize) -> DmdEngine {
        DmdEngine::new(
            DmdConfig {
                window,
                rank,
                hop: 1,
                ..Default::default()
            },
            None, // rust fallback: deterministic, no artifacts needed
            WorkflowMetrics::new(),
        )
        .unwrap()
    }

    /// Decaying oscillation snapshots: x_k = cos(θk)·a·rᵏ + sin(θk)·b·rᵏ.
    fn oscillating_snapshot(d: usize, k: usize, r: f64, theta: f64) -> Vec<f32> {
        let growth = r.powi(k as i32);
        (0..d)
            .map(|i| {
                let phase = i as f64 * 0.37;
                (growth * ((theta * k as f64) + phase).cos()) as f32
            })
            .collect()
    }

    #[test]
    fn window_fills_then_fires() {
        let eng = engine(4, 2);
        let d = 64;
        let mut fired = 0;
        for step in 0..8 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.95, 0.5));
            if eng.push("u/0", &rec).unwrap().is_some() {
                fired += 1;
            }
        }
        // window m+1 = 5 fills at step index 4; fires every push after
        assert_eq!(fired, 4);
        assert_eq!(eng.tracked_streams(), 1);
    }

    #[test]
    fn recovers_decay_rate() {
        let eng = engine(8, 2);
        let d = 128;
        let r = 0.9;
        let mut last = None;
        for step in 0..9 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, r, 0.4));
            if let Some(res) = eng.push("u/0", &rec).unwrap() {
                last = Some(res);
            }
        }
        let res = last.expect("window should have fired");
        // dominant eigenvalue magnitude ≈ decay rate r
        let lead = res.eigs.iter().map(|e| e.abs()).fold(0.0, f64::max);
        assert!((lead - r).abs() < 0.05, "lead |λ|={lead} want ~{r}");
        assert!(res.stability > 0.0);
        assert_eq!(res.backend, "rust");
        assert!(res.latency_us < 10_000_000);
    }

    #[test]
    fn neutral_oscillation_scores_near_zero() {
        let eng = engine(8, 2);
        let d = 96;
        let mut last = None;
        for step in 0..9 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 1.0, 0.6));
            if let Some(res) = eng.push("u/0", &rec).unwrap() {
                last = Some(res);
            }
        }
        let res = last.unwrap();
        assert!(
            res.stability < 1e-3,
            "unit-circle dynamics should be ~stable: {}",
            res.stability
        );
    }

    #[test]
    fn duplicate_and_stale_steps_ignored() {
        let eng = engine(3, 2);
        let d = 32;
        let mk = |s: u64| snap_record(0, s, &oscillating_snapshot(d, s as usize, 0.9, 0.3));
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none());
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none()); // dup
        assert!(eng.push("u/0", &mk(1)).unwrap().is_none());
        assert!(eng.push("u/0", &mk(1)).unwrap().is_none()); // dup
        assert!(eng.push("u/0", &mk(0)).unwrap().is_none()); // stale
        assert!(eng.push("u/0", &mk(2)).unwrap().is_none());
        // 4th distinct snapshot fills window m+1=4 → fires
        assert!(eng.push("u/0", &mk(3)).unwrap().is_some());
    }

    #[test]
    fn streams_are_independent() {
        let eng = engine(2, 1);
        let d = 16;
        for step in 0..3 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.9, 0.2));
            eng.push("u/0", &rec).unwrap();
        }
        // u/1 only has 1 snapshot: must not fire
        let rec = snap_record(1, 0, &oscillating_snapshot(d, 0, 0.9, 0.2));
        assert!(eng.push("u/1", &rec).unwrap().is_none());
        assert_eq!(eng.tracked_streams(), 2);
    }

    #[test]
    fn hop_reduces_fire_rate() {
        let eng = DmdEngine::new(
            DmdConfig {
                window: 3,
                rank: 2,
                hop: 3,
                ..Default::default()
            },
            None,
            WorkflowMetrics::new(),
        )
        .unwrap();
        let d = 32;
        let mut fired = 0;
        for step in 0..12 {
            let rec = snap_record(0, step, &oscillating_snapshot(d, step as usize, 0.9, 0.3));
            if eng.push("u/0", &rec).unwrap().is_some() {
                fired += 1;
            }
        }
        // window fills at snapshot 4; 8 more pushes → fires at hop=3 → 2-3
        assert!((2..=3).contains(&fired), "fired {fired}");
    }

    #[test]
    fn dim_change_is_error() {
        let eng = engine(3, 2);
        let rec = snap_record(0, 0, &vec![1.0; 32]);
        eng.push("u/0", &rec).unwrap();
        let bad = snap_record(0, 1, &vec![1.0; 64]);
        assert!(eng.push("u/0", &bad).is_err());
    }

    #[test]
    fn process_batch_end_to_end() {
        let eng = engine(3, 2);
        let d = 48;
        let records: Vec<StreamRecord> = (0..6)
            .map(|s| snap_record(2, s, &oscillating_snapshot(d, s as usize, 0.92, 0.5)))
            .collect();
        let batch = MicroBatch {
            key: "u/2".into(),
            records,
        };
        let out = eng.process(&batch);
        assert_eq!(out.len(), 3); // fills at 4th, fires on 4,5,6th
        assert!(out.iter().all(|r| r.rank == 2));
        assert!(out.windows(2).all(|w| w[0].step < w[1].step));
    }

    /// Property: cached-Gram incremental fires ≡ full recompute.
    /// Random slide sequences over varying (d, m, hop), comparing the
    /// engine's fired (σ, eigs) — i.e. (Ã, σ) — against an oracle full
    /// `dmd_reduce` on an independently-maintained copy of the window.
    /// `gram_refresh: 5` so the periodic rebuild cadence is exercised
    /// mid-sequence too.
    #[test]
    fn prop_incremental_gram_matches_full_recompute() {
        use crate::linalg::sort_spectrum;
        use crate::util::rng::Rng;
        for &(d, m, hop, seed) in &[
            (16usize, 3usize, 1usize, 5u64),
            (64, 4, 2, 6),
            (33, 6, 1, 7),
            (128, 8, 3, 8),
        ] {
            let rank = m.min(3);
            let metrics = WorkflowMetrics::new();
            let eng = DmdEngine::new(
                DmdConfig {
                    window: m,
                    rank,
                    hop,
                    backend: DmdBackend::Rust,
                    gram_refresh: 5,
                    ..Default::default()
                },
                None,
                metrics.clone(),
            )
            .unwrap();
            let mut rng = Rng::new(seed);
            let mut window: VecDeque<Vec<f32>> = VecDeque::new();
            let mut fired = 0;
            for step in 0..40u64 {
                let snap: Vec<f32> = (0..d)
                    .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
                    .collect();
                window.push_back(snap.clone());
                if window.len() > m + 1 {
                    window.pop_front();
                }
                let res = match eng.push("u/0", &snap_record(0, step, &snap)).unwrap() {
                    Some(res) => res,
                    None => continue,
                };
                fired += 1;
                // Oracle: widen the reference window, full dmd_reduce.
                let mut x = Mat::zeros(d, m + 1);
                for (j, s) in window.iter().enumerate() {
                    for (i, &v) in s.iter().enumerate() {
                        x[(i, j)] = v as f64;
                    }
                }
                let red = dmd::dmd_reduce(&x, rank).unwrap();
                assert_eq!(res.sigma.len(), red.sigma.len());
                for (a, b) in res.sigma.iter().zip(&red.sigma) {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "d={d} m={m} hop={hop} step={step}: σ {a} vs {b}"
                    );
                }
                let want = sort_spectrum(dmd::dmd_eigenvalues(&red.atilde).unwrap());
                let got = sort_spectrum(res.eigs.clone());
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.re - w.re).abs() < 1e-9 && (g.im - w.im).abs() < 1e-9,
                        "d={d} m={m} hop={hop} step={step}: λ {g:?} vs {w:?}"
                    );
                }
            }
            assert!(fired > 0, "d={d} m={m} hop={hop} never fired");
            assert!(metrics.gram_incremental.get() > 0, "d={d} m={m}");
            // fill + at least one periodic refresh
            assert!(metrics.gram_full.get() >= 2, "d={d} m={m}");
        }
    }

    /// Regression: a NaN/Inf snapshot makes the incremental update fall
    /// back to a full recompute, the fire is skipped while the bad
    /// snapshot is in the window, and analyses resume after it evicts.
    #[test]
    fn nan_snapshot_triggers_full_recompute_and_skips_fire() {
        let metrics = WorkflowMetrics::new();
        let eng = DmdEngine::new(
            DmdConfig {
                window: 3,
                rank: 2,
                hop: 1,
                backend: DmdBackend::Rust,
                gram_refresh: 0, // isolate the non-finite fallback
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap();
        let d = 16;
        let mk = |s: u64| snap_record(0, s, &oscillating_snapshot(d, s as usize, 0.9, 0.4));
        // Fill the window (m+1 = 4): one full Gram build, first fire.
        for s in 0..3 {
            assert!(eng.push("u/0", &mk(s)).unwrap().is_none());
        }
        assert!(eng.push("u/0", &mk(3)).unwrap().is_some());
        assert_eq!(metrics.gram_full.get(), 1);
        // One clean slide: served incrementally.
        assert!(eng.push("u/0", &mk(4)).unwrap().is_some());
        assert_eq!(metrics.gram_incremental.get(), 1);
        assert_eq!(metrics.gram_full.get(), 1);
        // Inject NaN: fallback full recompute, fire skipped.
        let mut bad = oscillating_snapshot(d, 5, 0.9, 0.4);
        bad[3] = f32::NAN;
        assert!(eng.push("u/0", &snap_record(0, 5, &bad)).unwrap().is_none());
        assert_eq!(metrics.gram_full.get(), 2);
        assert_eq!(metrics.gram_incremental.get(), 1);
        // Every slide with the NaN still in the window falls back + skips.
        for s in 6..9 {
            assert!(eng.push("u/0", &mk(s)).unwrap().is_none(), "step {s}");
        }
        assert_eq!(metrics.gram_full.get(), 5);
        // Window [6,7,8,9] no longer holds the NaN: incremental resumes.
        assert!(eng.push("u/0", &mk(9)).unwrap().is_some());
        assert_eq!(metrics.gram_incremental.get(), 2);
        assert_eq!(metrics.gram_full.get(), 5);
    }

    /// An Inf snapshot takes the same fallback path as NaN.
    #[test]
    fn inf_snapshot_also_falls_back() {
        let metrics = WorkflowMetrics::new();
        let eng = DmdEngine::new(
            DmdConfig {
                window: 2,
                rank: 1,
                hop: 1,
                backend: DmdBackend::Rust,
                gram_refresh: 0,
                ..Default::default()
            },
            None,
            metrics.clone(),
        )
        .unwrap();
        let d = 8;
        let mk = |s: u64| snap_record(0, s, &oscillating_snapshot(d, s as usize, 0.9, 0.4));
        for s in 0..3 {
            let _ = eng.push("u/0", &mk(s)).unwrap();
        }
        let mut bad = oscillating_snapshot(d, 3, 0.9, 0.4);
        bad[0] = f32::INFINITY;
        assert!(eng.push("u/0", &snap_record(0, 3, &bad)).unwrap().is_none());
        assert_eq!(metrics.gram_full.get(), 2); // fill + fallback
    }

    /// Executor threads on distinct streams drive the sharded engine
    /// concurrently; every stream fires independently.
    #[test]
    fn sharded_windows_concurrent_streams() {
        let eng = Arc::new(engine(4, 2));
        let d = 32;
        let handles: Vec<_> = (0..8u32)
            .map(|r| {
                let eng = eng.clone();
                std::thread::spawn(move || {
                    let mut fired = 0usize;
                    for step in 0..16u64 {
                        let snap = oscillating_snapshot(
                            d,
                            step as usize,
                            0.95,
                            0.3 + r as f64 * 0.05,
                        );
                        let rec = snap_record(r, step, &snap);
                        if eng.push(&format!("u/{r}"), &rec).unwrap().is_some() {
                            fired += 1;
                        }
                    }
                    fired
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        // window 4+1 fills at the 5th push → 12 fires per stream
        assert_eq!(total, 8 * 12);
        assert_eq!(eng.tracked_streams(), 8);
    }

    /// ISSUE 5: records that travelled as staged lossless frames
    /// (shuffle-lz, no lossy conversion) analyse bit-identically to
    /// raw ones — the decode reverses the stages exactly.
    #[test]
    fn staged_lossless_records_match_raw_analysis() {
        use crate::broker::{StagePipeline, StagesConfig};
        use crate::record::CodecKind;

        let raw_eng = engine(4, 2);
        let staged_eng = engine(4, 2);
        let pipeline = StagePipeline::new(
            StagesConfig { codec: CodecKind::ShuffleLz, ..Default::default() },
            Arc::new(crate::metrics::StageMetrics::new()),
        )
        .unwrap();
        let d = 64;
        for step in 0..10u64 {
            let data = oscillating_snapshot(d, step as usize, 0.95, 0.5);
            let raw_rec = snap_record(0, step, &data);
            let staged = pipeline
                .apply("u", 0, step, step, raw_rec.gen_micros, &[d as u32], &data)
                .unwrap()
                .unwrap();
            // roundtrip through the wire format like a real consumer
            let wire_rec = StreamRecord::decode(&staged.encode()).unwrap();
            let a = raw_eng.push("u/0", &raw_rec).unwrap();
            let b = staged_eng.push("u/0", &wire_rec).unwrap();
            assert_eq!(a.is_some(), b.is_some(), "step {step}");
            if let (Some(a), Some(b)) = (a, b) {
                assert_eq!(a.sigma, b.sigma, "step {step}: σ diverged");
                assert_eq!(a.stability, b.stability, "step {step}");
                for (x, y) in a.eigs.iter().zip(&b.eigs) {
                    assert_eq!(x.re, y.re, "step {step}");
                    assert_eq!(x.im, y.im, "step {step}");
                }
            }
        }
    }

    /// ISSUE 6: the results-stream codec is a bit-exact f64 round trip
    /// through the real wire format (encode → EBR1 frame → decode).
    #[test]
    fn results_record_roundtrip_is_bit_exact() {
        let res = AnalysisResult {
            key: "u/3".into(),
            rank: 3,
            step: 17,
            stability: 0.123_456_789_012_345_67,
            eigs: vec![
                Complex::new(0.999_999_999_999_9, -1.0e-17),
                Complex::new(f64::MIN_POSITIVE, -0.25),
            ],
            sigma: vec![3.141_592_653_589_793, 1e-300, 0.0],
            latency_us: 987_654_321,
            backend: "pjrt",
        };
        let rec = res.to_record();
        assert_eq!(rec.stream_key(), results_key("u/3"));
        assert_eq!(rec.step, 17);
        // round trip through the wire format like a real subscriber
        let wire = StreamRecord::decode(&rec.encode()).unwrap();
        let got = AnalysisResult::from_record(&wire).unwrap();
        assert_eq!(got.key, "u/3");
        assert_eq!(got.rank, 3);
        assert_eq!(got.step, 17);
        assert_eq!(got.backend, "pjrt");
        assert_eq!(got.latency_us, res.latency_us);
        assert_eq!(got.stability.to_bits(), res.stability.to_bits());
        assert_eq!(got.eigs.len(), res.eigs.len());
        for (a, b) in got.eigs.iter().zip(&res.eigs) {
            assert_eq!(a.re.to_bits(), b.re.to_bits());
            assert_eq!(a.im.to_bits(), b.im.to_bits());
        }
        assert_eq!(got.sigma.len(), res.sigma.len());
        for (a, b) in got.sigma.iter().zip(&res.sigma) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn results_decode_rejects_non_results_records() {
        // a plain snapshot record is not a results frame
        let snap = snap_record(0, 1, &[1.0; 16]);
        assert!(AnalysisResult::from_record(&snap).is_err());
        // truncated payloads are rejected before any array reads
        let res = AnalysisResult {
            key: "u/0".into(),
            rank: 0,
            step: 1,
            stability: 0.5,
            eigs: vec![Complex::new(1.0, 0.0)],
            sigma: vec![2.0],
            latency_us: 10,
            backend: "rust",
        };
        let mut rec = res.to_record();
        let mut short = (*rec.payload).clone();
        short.truncate(super::RESULTS_HEADER - 4);
        rec.payload = Arc::new(short);
        assert!(AnalysisResult::from_record(&rec).is_err());
    }

    #[test]
    fn csv_sink_writes_rows() {
        let dir = std::env::temp_dir().join(format!("eb-csv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.csv");
        let sink = CsvSink::create(path.to_str().unwrap()).unwrap();
        let res = AnalysisResult {
            key: "u/0".into(),
            rank: 0,
            step: 42,
            stability: 0.125,
            eigs: vec![Complex::new(0.9, 0.1)],
            sigma: vec![3.0, 1.0],
            latency_us: 1234,
            backend: "rust",
        };
        sink.write(&res).unwrap();
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() == 2);
        assert!(text.contains("u/0,0,42,0.12500000,1234,rust"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
