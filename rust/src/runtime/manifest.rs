//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`).
//!
//! Format: one artifact per line, whitespace-separated `key=value`
//! pairs; tensor lists are comma-separated `name:dtype:AxBxC` triples:
//!
//! ```text
//! artifact name=lbm_step key=h16_w128 path=lbm_step_h16_w128.hlo.txt \
//!   inputs=f:f32:9x18x128,mask:f32:18x128 outputs=... meta=tau:0.56,...
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Shape/dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// Only f32 is emitted today; kept as a field for forward-compat.
    pub dtype: String,
    pub dims: Vec<i64>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Logical name (`lbm_step`, `lbm_init`, `dmd`).
    pub name: String,
    /// Shape-variant key (`h16_w128`, `d4096_m9_r6`).
    pub key: String,
    /// HLO text file, relative to the artifact dir.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (`tau`, `u0`, `rank`, `window`, ...).
    pub meta: HashMap<String, String>,
}

impl ArtifactSpec {
    /// Metadata value parsed as f64.
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key)?.parse().ok()
    }

    /// Metadata value parsed as usize.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key)?.parse().ok()
    }
}

/// Parse the whole manifest text.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("artifact") => {}
            Some(other) => bail!("manifest line {}: unknown entry '{other}'", lineno + 1),
            None => continue,
        }
        let mut fields: HashMap<&str, &str> = HashMap::new();
        for kv in parts {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("manifest line {}: bad pair '{kv}'", lineno + 1))?;
            fields.insert(k, v);
        }
        let get = |k: &str| -> Result<&str> {
            fields
                .get(k)
                .copied()
                .with_context(|| format!("manifest line {}: missing '{k}'", lineno + 1))
        };
        let spec = ArtifactSpec {
            name: get("name")?.to_string(),
            key: get("key")?.to_string(),
            path: get("path")?.to_string(),
            inputs: parse_tensor_list(get("inputs")?)?,
            outputs: parse_tensor_list(get("outputs")?)?,
            meta: parse_meta(fields.get("meta").copied().unwrap_or("")),
        };
        if spec.path.contains("..") || spec.path.starts_with('/') {
            bail!("manifest line {}: suspicious path '{}'", lineno + 1, spec.path);
        }
        specs.push(spec);
    }
    Ok(specs)
}

fn parse_tensor_list(s: &str) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for item in s.split(',').filter(|x| !x.is_empty()) {
        let mut it = item.split(':');
        let name = it.next().context("tensor: missing name")?;
        let dtype = it.next().with_context(|| format!("tensor '{name}': missing dtype"))?;
        if dtype != "f32" {
            bail!("tensor '{name}': unsupported dtype '{dtype}'");
        }
        let dims_s = it.next().with_context(|| format!("tensor '{name}': missing dims"))?;
        let dims = dims_s
            .split('x')
            .map(|d| d.parse::<i64>().map_err(Into::into))
            .collect::<Result<Vec<i64>>>()
            .with_context(|| format!("tensor '{name}': bad dims '{dims_s}'"))?;
        if dims.iter().any(|&d| d <= 0) {
            bail!("tensor '{name}': non-positive dim in {dims:?}");
        }
        out.push(TensorSpec {
            name: name.to_string(),
            dtype: dtype.to_string(),
            dims,
        });
    }
    Ok(out)
}

fn parse_meta(s: &str) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for item in s.split(',').filter(|x| !x.is_empty()) {
        if let Some((k, v)) = item.split_once(':') {
            out.insert(k.to_string(), v.to_string());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
artifact name=lbm_step key=h16_w128 path=lbm_step_h16_w128.hlo.txt \
inputs=f:f32:9x18x128,mask:f32:18x128 outputs=f:f32:9x18x128,u:f32:2x16x128 \
meta=tau:0.56,u0:0.1,block_h:6

artifact name=dmd key=d512_m9_r6 path=dmd_d512_m9_r6.hlo.txt \
inputs=x:f32:512x9 outputs=atilde:f32:6x6,sigma:f32:6 meta=rank:6,window:8
";

    #[test]
    fn parses_sample() {
        let specs = parse_manifest(SAMPLE).unwrap();
        assert_eq!(specs.len(), 2);
        let s = &specs[0];
        assert_eq!(s.name, "lbm_step");
        assert_eq!(s.key, "h16_w128");
        assert_eq!(s.inputs.len(), 2);
        assert_eq!(s.inputs[0].dims, vec![9, 18, 128]);
        assert_eq!(s.inputs[0].element_count(), 9 * 18 * 128);
        assert_eq!(s.outputs[1].name, "u");
        assert_eq!(s.meta_f64("tau"), Some(0.56));
        assert_eq!(s.meta_usize("block_h"), Some(6));
        let d = &specs[1];
        assert_eq!(d.meta_usize("rank"), Some(6));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_manifest("garbage name=x\n").is_err());
        assert!(parse_manifest("artifact name=a key=k\n").is_err()); // missing path
        assert!(parse_manifest(
            "artifact name=a key=k path=p inputs=x:f64:3 outputs= meta=\n"
        )
        .is_err()); // f64 unsupported
        assert!(parse_manifest(
            "artifact name=a key=k path=../evil inputs= outputs= meta=\n"
        )
        .is_err()); // path traversal
        assert!(parse_manifest(
            "artifact name=a key=k path=p inputs=x:f32:0x3 outputs= meta=\n"
        )
        .is_err()); // zero dim
    }

    #[test]
    fn real_manifest_parses_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.txt") {
            let specs = parse_manifest(&text).unwrap();
            assert!(specs.iter().any(|s| s.name == "lbm_step"));
            assert!(specs.iter().any(|s| s.name == "dmd"));
            for s in &specs {
                assert!(!s.inputs.is_empty());
                assert!(!s.outputs.is_empty());
            }
        }
    }
}
