//! The real PJRT runtime (requires the `pjrt` cargo feature and the
//! `xla` bindings crate): load AOT artifacts (HLO text) and execute
//! them on a shared PJRT CPU client.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `/opt/xla-example/README.md`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::{manifest, ArtifactSpec};

/// A registry of compiled artifacts backed by one PJRT CPU client.
///
/// Compilation is lazy and cached: the first `executable("lbm_step",
/// "h16_w128")` compiles, later calls share the `Arc`.
pub struct ArtifactSet {
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

// SAFETY: see the note on `Executable` below.  The `xla` crate wraps the
// PJRT client in an `Rc` purely for intra-process refcounting; the
// underlying TfrtCpuClient is thread-safe (XLA executes from arbitrary
// threads), we guard the compile cache with a Mutex, and `Arc` semantics
// prevent concurrent frees.  Cloning the inner `Rc` only happens while
// holding `&self` during `compile`, which the cache Mutex serializes.
unsafe impl Send for ArtifactSet {}
unsafe impl Sync for ArtifactSet {}

impl ArtifactSet {
    /// Load the manifest in `dir` and bring up the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let specs = manifest::parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: loaded {} artifact specs from {} (platform={})",
            specs.len(),
            dir.display(),
            client.platform_name()
        );
        Ok(ArtifactSet {
            dir,
            specs,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Look for artifacts in `$ELASTICBROKER_ARTIFACTS`, `./artifacts`,
    /// or next to the executable; `None` if absent (callers fall back to
    /// the pure-Rust implementations).
    pub fn try_load_default() -> Option<Arc<Self>> {
        let mut candidates: Vec<PathBuf> = Vec::new();
        if let Ok(p) = std::env::var("ELASTICBROKER_ARTIFACTS") {
            candidates.push(p.into());
        }
        candidates.push("artifacts".into());
        if let Ok(exe) = std::env::current_exe() {
            for anc in exe.ancestors().take(5) {
                candidates.push(anc.join("artifacts"));
            }
        }
        for c in candidates {
            if c.join("manifest.txt").is_file() {
                match Self::load(&c) {
                    Ok(set) => return Some(Arc::new(set)),
                    Err(e) => {
                        log::warn!("runtime: failed to load artifacts at {}: {e:#}", c.display());
                        return None;
                    }
                }
            }
        }
        None
    }

    /// All parsed specs (diagnostics, `elasticbroker info`).
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find a spec by artifact name + shape key.
    pub fn find(&self, name: &str, key: &str) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.name == name && s.key == key)
    }

    /// Compile (or fetch the cached) executable for `name`/`key`.
    ///
    /// The cache Mutex is held across compilation on purpose: it both
    /// dedups concurrent compiles of the same artifact and serializes
    /// every clone of the crate's internal `Rc<PjRtClientInternal>`
    /// (see the `Send`/`Sync` safety note above).
    pub fn executable(&self, name: &str, key: &str) -> Result<Arc<Executable>> {
        let cache_key = format!("{name}/{key}");
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&cache_key) {
            return Ok(e.clone());
        }
        let spec = self
            .find(name, key)
            .with_context(|| format!("no artifact '{name}' with key '{key}' in manifest"))?
            .clone();
        let path = self.dir.join(&spec.path);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::info!(
            "runtime: compiled {name}/{key} in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        let exec = Arc::new(Executable { exe, spec });
        cache.insert(cache_key, exec.clone());
        Ok(exec)
    }
}

/// A compiled artifact plus its manifest schema.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

// SAFETY: the PJRT C API is thread-safe for compilation and execution
// (XLA guards client state internally; CPU buffers are immutable once
// created).  The raw pointers inside the wrapper types make them !Send
// by default; we only ever share the executable read-only across the
// coordinator's threads and never free it concurrently (Arc semantics).
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with f32 host inputs, returning f32 host outputs in the
    /// manifest's output order.  Input lengths are validated against the
    /// manifest shapes.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, ts) in inputs.iter().zip(&self.spec.inputs) {
            if data.len() != ts.element_count() {
                bail!(
                    "{}: input '{}' expects {} elements ({:?}), got {}",
                    self.spec.name,
                    ts.name,
                    ts.element_count(),
                    ts.dims,
                    data.len()
                );
            }
            let bytes = f32_slice_as_bytes(data);
            let dims: Vec<usize> = ts.dims.iter().map(|&d| d as usize).collect();
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                bytes,
            )?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .context("PJRT returned no output buffers")?;
        let root = first.to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        let mut outputs = Vec::with_capacity(parts.len());
        for (part, ts) in parts.into_iter().zip(&self.spec.outputs) {
            let v = part.to_vec::<f32>()?;
            if v.len() != ts.element_count() {
                bail!(
                    "{}: output '{}' expected {} elements, got {}",
                    self.spec.name,
                    ts.name,
                    ts.element_count(),
                    v.len()
                );
            }
            outputs.push(v);
        }
        Ok(outputs)
    }
}

fn f32_slice_as_bytes(data: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have run; they are the
    /// heart of the AOT bridge validation (HLO text → PJRT → numbers).
    fn artifacts() -> Option<Arc<ArtifactSet>> {
        let set = ArtifactSet::try_load_default();
        if set.is_none() {
            eprintln!("WARNING: artifacts not built; skipping PJRT runtime test");
        }
        set
    }

    #[test]
    fn manifest_loads_and_lists_specs() {
        let Some(set) = artifacts() else { return };
        assert!(set.find("lbm_step", "h16_w128").is_some());
        assert!(set.find("lbm_init", "h16_w128").is_some());
        assert!(set.find("dmd", "d4096_m9_r6").is_some());
        assert!(set.find("nope", "x").is_none());
    }

    #[test]
    fn lbm_init_executes_and_is_equilibrium() {
        let Some(set) = artifacts() else { return };
        let exe = set.executable("lbm_init", "h8_w64").unwrap();
        let (hp, w) = (10usize, 64usize);
        let mask = vec![0.0f32; hp * w];
        let out = exe.run_f32(&[&mask]).unwrap();
        assert_eq!(out.len(), 1);
        let f = &out[0];
        assert_eq!(f.len(), 9 * hp * w);
        // density = sum_c f_c == 1 everywhere at equilibrium init
        let plane = hp * w;
        for cell in 0..plane {
            let rho: f32 = (0..9).map(|c| f[c * plane + cell]).sum();
            assert!((rho - 1.0).abs() < 1e-5, "rho={rho} at {cell}");
        }
    }

    #[test]
    fn executable_cache_returns_same_arc() {
        let Some(set) = artifacts() else { return };
        let a = set.executable("lbm_init", "h8_w64").unwrap();
        let b = set.executable("lbm_init", "h8_w64").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(set) = artifacts() else { return };
        let exe = set.executable("lbm_init", "h8_w64").unwrap();
        assert!(exe.run_f32(&[]).is_err());
        let wrong = vec![0.0f32; 7];
        assert!(exe.run_f32(&[&wrong]).is_err());
    }

    #[test]
    fn dmd_artifact_matches_rust_fallback() {
        let Some(set) = artifacts() else { return };
        use crate::linalg::{dmd, Mat};
        use crate::util::rng::Rng;
        let (d, m1, r) = (512usize, 9usize, 6usize);
        let exe = set.executable("dmd", "d512_m9_r6").unwrap();
        let mut rng = Rng::new(42);
        let mut x = vec![0.0f32; d * m1];
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let out = exe.run_f32(&[&x]).unwrap();
        assert_eq!(out.len(), 2);
        let atilde_pjrt = Mat::from_f32(r, r, &out[0]).unwrap();
        let sigma_pjrt: Vec<f64> = out[1].iter().map(|&v| v as f64).collect();

        let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let xm = Mat::from_slice(d, m1, &xf).unwrap();
        let red = dmd::dmd_reduce(&xm, r).unwrap();
        // f32 artifact vs f64 fallback: agreement to ~1e-2 relative.
        for i in 0..r {
            let rel = (sigma_pjrt[i] - red.sigma[i]).abs() / red.sigma[i];
            assert!(rel < 1e-2, "sigma[{i}]: {} vs {}", sigma_pjrt[i], red.sigma[i]);
        }
        // Compare spectra (eigensolver basis may differ, spectra must not).
        let e_pjrt = crate::linalg::sort_spectrum(dmd::dmd_eigenvalues(&atilde_pjrt).unwrap());
        let e_rust = crate::linalg::sort_spectrum(dmd::dmd_eigenvalues(&red.atilde).unwrap());
        for (a, b) in e_pjrt.iter().zip(&e_rust) {
            assert!(
                (a.re - b.re).abs() < 5e-2 && (a.im - b.im).abs() < 5e-2,
                "spectrum mismatch {e_pjrt:?} vs {e_rust:?}"
            );
        }
    }
}
