//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the AOT bridge of the three-layer architecture: `make
//! artifacts` lowers the Layer-2 JAX graphs (with Layer-1 Pallas kernels
//! inlined) to `artifacts/*.hlo.txt` + `manifest.txt`; this module
//! parses the manifest, compiles each artifact once on a shared PJRT
//! CPU client, and exposes a typed f32 execute call to the coordinator.
//!
//! The compiled path needs the `xla` bindings crate, which is not part
//! of the offline crate set, so it is gated behind the **`pjrt` cargo
//! feature** (`pjrt` module).  The default build uses `stub`: an
//! API-identical runtime whose artifact loading always reports absence,
//! so every caller (sim, analysis, workflow, benches) transparently
//! takes its pure-Rust fallback.  Only the manifest parser is shared —
//! it has no native dependencies and keeps the artifact schema testable
//! in every build.

mod manifest;

pub use manifest::{ArtifactSpec, TensorSpec};

// A clear diagnostic instead of "unresolved crate `xla`".  Note that
// no build configuration type-checks pjrt.rs today: the default build
// compiles it out, and a `--features pjrt` build stops here — the
// module stays in-tree for when the dependency can be declared, but
// it is NOT protected against rot by CI.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the `xla` bindings crate, which is not in \
     the offline crate set; add `xla` to rust/Cargo.toml [dependencies] and \
     remove this guard to enable the compiled runtime"
);

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactSet, Executable};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ArtifactSet, Executable};
