//! No-PJRT runtime: the API surface of `super::pjrt` (compiled out of
//! the default build — see the `pjrt` cargo feature) without the
//! `xla` dependency.
//!
//! [`ArtifactSet::try_load_default`] always answers `None`, so the sim,
//! analysis engine, workflow drivers, benches and examples all take
//! their pure-Rust fallback paths — semantically identical to the
//! compiled artifacts (the mirrors are cross-validated when a `pjrt`
//! build runs the integration suite).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::ArtifactSpec;

/// Artifact registry placeholder: never holds a compiled artifact.
pub struct ArtifactSet {
    specs: Vec<ArtifactSpec>,
}

impl ArtifactSet {
    /// Always fails: compiled artifacts need the `pjrt` feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "cannot load artifacts from {}: elasticbroker was built without \
             the `pjrt` feature (pure-Rust fallbacks are active)",
            dir.as_ref().display()
        )
    }

    /// Always `None` in a stub build; warns once per call site when
    /// artifacts are present on disk but unusable.
    pub fn try_load_default() -> Option<Arc<Self>> {
        let candidate = std::env::var("ELASTICBROKER_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into());
        if Path::new(&candidate).join("manifest.txt").is_file() {
            log::debug!(
                "runtime: artifacts found at {candidate} but this build has no \
                 `pjrt` feature; using pure-Rust fallbacks"
            );
        }
        None
    }

    /// All parsed specs (always empty in a stub build).
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find a spec by artifact name + shape key.
    pub fn find(&self, name: &str, key: &str) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.name == name && s.key == key)
    }

    /// Always fails: there is no PJRT client to compile with.
    pub fn executable(&self, name: &str, key: &str) -> Result<Arc<Executable>> {
        bail!("no PJRT runtime in this build (artifact {name}/{key} requested)")
    }
}

/// Compiled-artifact placeholder (never constructed in a stub build).
pub struct Executable {
    spec: ArtifactSpec,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Always fails: there is no PJRT executable behind this handle.
    pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!("no PJRT runtime in this build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_absence_not_panic() {
        assert!(ArtifactSet::try_load_default().is_none());
        assert!(ArtifactSet::load("artifacts").is_err());
    }
}
