//! Command-line interface (hand-rolled; no clap in the offline set).
//!
//! Subcommands mirror the deployment units of the paper's Fig 2 so the
//! system can run split across processes exactly like HPC + Cloud:
//!
//! ```text
//! elasticbroker info
//! elasticbroker endpoint  --bind 0.0.0.0:6379
//! elasticbroker sim       --endpoints host:6379[,host:6380] [--ranks 16] ...
//! elasticbroker analysis  --endpoints host:6379 --ranks 16 [--field velocity]
//! elasticbroker synth     --endpoints host:6379 --ranks 16 ...
//! elasticbroker workflow  [--config wf.toml] [--io-mode broker] ...
//! ```

use std::collections::HashMap;

use anyhow::{Context, Result};

/// Parsed `--key value` flags + positional args.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

/// Flags that never take a value.
const BOOLEAN_FLAGS: &[&str] = &[
    "no-pjrt",
    "help",
    "verbose",
    "dmd-per-batch",
    "retention",
    "stage-stats",
    "results-stream",
];

impl Args {
    /// Parse from raw argv (not including the subcommand itself).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if BOOLEAN_FLAGS.contains(&name) {
                    out.bools.push(name.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.bools.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated socket addresses.
    pub fn get_addrs(&self, key: &str) -> Result<Option<Vec<std::net::SocketAddr>>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => {
                let mut out = Vec::new();
                for part in v.split(',') {
                    out.push(
                        part.trim()
                            .parse()
                            .with_context(|| format!("--{key}: bad address '{part}'"))?,
                    );
                }
                Ok(Some(out))
            }
        }
    }
}

/// Apply CLI overrides on top of a [`crate::config::WorkflowConfig`].
pub fn apply_overrides(
    cfg: &mut crate::config::WorkflowConfig,
    args: &Args,
) -> Result<()> {
    if let Some(v) = args.get_parsed::<usize>("ranks")? {
        cfg.ranks = v;
    }
    if let Some(v) = args.get_parsed::<usize>("height")? {
        cfg.height = v;
    }
    if let Some(v) = args.get_parsed::<usize>("width")? {
        cfg.width = v;
    }
    if let Some(v) = args.get_parsed::<u64>("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.get_parsed::<u64>("write-interval")? {
        cfg.write_interval = v;
    }
    if let Some(v) = args.get("io-mode") {
        cfg.io_mode = crate::config::IoMode::parse(v)?;
    }
    if let Some(v) = args.get("out-dir") {
        cfg.out_dir = v.to_string();
    }
    if args.has_flag("no-pjrt") {
        cfg.use_pjrt = false;
    }
    if let Some(v) = args.get_parsed::<u64>("pfs-commit-ms")? {
        cfg.pfs_commit_ms = v;
    }
    if let Some(v) = args.get_parsed::<usize>("group-size")? {
        cfg.group_size = v;
    }
    if let Some(v) = args.get_parsed::<usize>("batch-max-records")? {
        cfg.batch_max_records = v;
    }
    if let Some(v) = args.get_parsed::<usize>("batch-max-bytes")? {
        cfg.batch_max_bytes = v;
    }
    if let Some(v) = args.get_parsed::<u64>("linger-ms")? {
        cfg.linger_ms = v;
    }
    if let Some(v) = args.get_parsed::<u64>("stage-decimate")? {
        cfg.stages.decimate = v;
    }
    if let Some(v) = args.get_parsed::<u32>("stage-rank-stride")? {
        cfg.stages.rank_stride = v;
    }
    if let Some(v) = args.get("stage-roi") {
        cfg.stages.roi = Some(crate::broker::StagesConfig::parse_roi(v)?);
    }
    if let Some(v) = args.get_parsed::<usize>("stage-aggregate")? {
        cfg.stages.aggregate = v;
    }
    if args.has_flag("stage-stats") {
        cfg.stages.stats = true;
    }
    if let Some(v) = args.get("stage-convert") {
        cfg.stages.convert = crate::record::Encoding::parse(v)?;
    }
    if let Some(v) = args.get_parsed::<f32>("stage-qdelta-step")? {
        cfg.stages.qdelta_step = v;
    }
    if let Some(v) = args.get("stage-codec") {
        cfg.stages.codec = crate::record::CodecKind::parse(v)?;
    }
    if let Some(v) = args.get_parsed::<f32>("stage-max-err")? {
        cfg.stages.max_err = v;
    }
    if let Some(v) = args.get_parsed::<usize>("store-shards")? {
        cfg.store_shards = v;
    }
    if let Some(v) = args.get_parsed::<usize>("executors")? {
        cfg.executors = v;
    }
    if let Some(v) = args.get_parsed::<u64>("trigger-ms")? {
        cfg.trigger_ms = v;
    }
    if let Some(v) = args.get_parsed::<usize>("dmd-window")? {
        cfg.dmd_window = v;
    }
    if let Some(v) = args.get_parsed::<usize>("dmd-rank")? {
        cfg.dmd_rank = v;
    }
    if let Some(v) = args.get_parsed::<bool>("dmd-use-pjrt")? {
        cfg.dmd_use_pjrt = v;
    }
    if args.has_flag("dmd-per-batch") {
        cfg.dmd_per_batch = true;
    }
    if let Some(v) = args.get_parsed::<usize>("dmd-gram-refresh")? {
        cfg.dmd_gram_refresh = v;
    }
    if let Some(v) = args.get_parsed::<usize>("dmd-shards")? {
        cfg.dmd_shards = v;
    }
    if let Some(v) = args.get("analysis-csv") {
        cfg.analysis_csv = v.to_string();
    }
    if let Some(v) = args.get("consumer-group") {
        cfg.consumer_group = v.to_string();
    }
    if args.has_flag("results-stream") {
        cfg.results_stream = true;
    }
    if let Some(v) = args.get("persist-dir") {
        cfg.wal_dir = v.to_string();
    }
    if let Some(v) = args.get("wal-fsync") {
        cfg.wal_fsync = crate::endpoint::FsyncPolicy::parse(v)?;
    }
    if let Some(v) = args.get_parsed::<usize>("wal-segment-bytes")? {
        cfg.wal_segment_bytes = v;
    }
    if args.has_flag("retention") {
        cfg.retention = true;
    }
    if let Some(v) = args.get_parsed::<usize>("io-shards")? {
        cfg.io_shards = v;
    }
    if let Some(v) = args.get_parsed::<usize>("read-ring-bytes")? {
        cfg.read_ring_bytes = v;
    }
    if let Some(v) = args.get_parsed::<usize>("max-conns-per-shard")? {
        cfg.max_conns_per_shard = v;
    }
    if let Some(v) = args.get_parsed::<u64>("rebalance-ms")? {
        cfg.rebalance_ms = v;
    }
    if let Some(v) = args.get_parsed::<u64>("qos-flush-p95-us")? {
        cfg.qos_flush_p95_us = v;
    }
    if let Some(v) = args.get_parsed::<u64>("qos-queue-depth")? {
        cfg.qos_queue_depth = v;
    }
    if let Some(v) = args.get_parsed::<u64>("qos-reconnects")? {
        cfg.qos_reconnects = v;
    }
    if let Some(v) = args.get_parsed::<usize>("replication-factor")? {
        cfg.replication_factor = v;
    }
    if let Some(v) = args.get("replication-domains") {
        cfg.replication_domains = v
            .split(',')
            .map(|p| p.trim().to_string())
            .filter(|p| !p.is_empty())
            .collect();
    }
    if let Some(v) = args.get("replication-ack") {
        cfg.replication_ack = crate::endpoint::ReplAck::parse(v)?;
    }
    if let Some(v) = args.get_parsed::<u64>("adapt-sweep-ms")? {
        cfg.adapt_sweep_ms = v;
    }
    if let Some(v) = args.get_parsed::<u64>("adapt-target-p95-us")? {
        cfg.adapt_target_p95_us = v;
    }
    if let Some(v) = args.get_parsed::<u64>("adapt-queue-hi")? {
        cfg.adapt_queue_hi = v;
    }
    if let Some(v) = args.get_parsed::<u32>("adapt-hysteresis")? {
        cfg.adapt_hysteresis = v;
    }
    if let Some(v) = args.get_parsed::<u64>("obs-trace-sample")? {
        cfg.obs_trace_sample = v;
    }
    if let Some(v) = args.get_parsed::<u64>("obs-snapshot-ms")? {
        cfg.obs_snapshot_ms = v;
    }
    if let Some(v) = args.get("obs-dir") {
        cfg.obs_dir = v.to_string();
    }
    if let Some(v) = args.get_parsed::<usize>("obs-events-ring")? {
        cfg.obs_events_ring = v;
    }
    Ok(())
}

pub const USAGE: &str = "\
elasticbroker — HPC↔Cloud in-situ workflow system (ElasticBroker reproduction)

USAGE:
  elasticbroker <subcommand> [flags]

SUBCOMMANDS:
  info        Show artifact registry and default configuration
  endpoint    Run a Cloud endpoint (RESP stream store)
                --bind ADDR          (default 127.0.0.1:6379)
                --maxlen N           per-stream entry cap
                --max-memory BYTES   global budget
                --shards N           store shards (default 8)
                --persist-dir DIR    write-ahead log dir (default: none,
                                     in-memory only)
                --wal-fsync P        never|always|every_ms(N)
                                     (default every_ms(5))
                --wal-segment-bytes N  rotation threshold (default 64 MiB)
                --retention          never trim/GC unread entries; readers
                                     ack cursors (needs --persist-dir)
                --io-shards N        event-loop shard threads (default 4)
                --read-ring-bytes N  per-shard read buffer (default 64 KiB)
                --max-conns-per-shard N  accept cap per shard (default 4096)
  sim         Run the HPC-side CFD simulation against remote endpoints
                --endpoints A[,B..]  required for --io-mode broker
                --ranks/--height/--width/--steps/--write-interval
                --io-mode file|broker|none   --out-dir DIR   --no-pjrt
                --batch-max-records N --batch-max-bytes B --linger-ms MS
                data-reduction stages ([stages] in TOML):
                --stage-decimate N   ship every Nth write (default 1)
                --stage-rank-stride N  ship ranks where rank%N==0
                --stage-roi LO:HI    crop last axis to [LO, HI)
                --stage-aggregate K  block-mean last axis by K
                --stage-stats        min/max/mean sidecar stats
                --stage-convert E    f32|f16|qdelta (default f32)
                --stage-qdelta-step S  qdelta quantization step
                --stage-codec C      none|shuffle-lz (default none)
                --stage-max-err E    per-stream accuracy target: measured
                                     frame err_bound stays <= E (0 = off)
  analysis    Run the Cloud-side streaming + DMD service
                --endpoints A[,B..]  --ranks N  --field NAME
                --trigger-ms MS --executors N --dmd-window M --dmd-rank R
                --dmd-gram-refresh N full Gram rebuild cadence (default 64)
                --dmd-shards N       analysis window shards (default 8)
                --duration-secs S    how long to serve (default 60)
                --analysis-csv PATH  --store-shards N (workflow mode)
                --consumer-group G   named group the readers ack under
                                     (independent cursor per group)
                --results-stream     publish DMD fires back into the
                                     endpoints as results/<field>/<rank>
  synth       Run synthetic generators against remote endpoints
                --endpoints A[,B..]  --ranks N --dim D --records N --rate HZ
                --batch-max-records N --batch-max-bytes B --linger-ms MS
  workflow    Run the whole paper workflow in one process
                --config FILE (TOML)  plus any sim/analysis flag above
                --rebalance-ms MS    QoS rebalancer sweep cadence
                                     (0 = static topology, the default)
                --qos-flush-p95-us N --qos-queue-depth N
                --qos-reconnects N   saturation / death thresholds
                --replication-factor N  chain-replicate every stream
                                     through N endpoints (1 = off, max 3;
                                     needs --rebalance-ms for failover)
                --replication-domains A,B,..  failure-domain labels cycled
                                     over endpoint slots ([replication])
                --replication-ack M  tail (chain-durable acks, default)
                                     or head (best-effort forwarding)
                --persist-dir DIR    durable endpoints: per-endpoint WALs
                                     under DIR/ep<i> ([endpoint] wal_dir)
                --wal-fsync P --wal-segment-bytes N --retention
                                     (see `endpoint`; retention turns on
                                     reader cursor acks + log GC)
                --io-shards N --read-ring-bytes N --max-conns-per-shard N
                                     endpoint event-loop sizing
                                     ([endpoint] in TOML)
                --adapt-sweep-ms MS  adaptive-reduction controller sweep
                                     cadence (0 = static stages, default)
                --adapt-target-p95-us N  flush-p95 latency budget (µs)
                --adapt-queue-hi N   queue/backlog pressure threshold
                --adapt-hysteresis N calm sweeps before stepping back up
                                     ([adapt] in TOML; --stage-max-err
                                     bounds the ladder's fidelity loss)
                --obs-trace-sample N flight-recorder tracing: stamp every
                                     Nth record per writer with hop
                                     timestamps (0 = off, the default)
                --obs-snapshot-ms MS metrics-registry JSONL snapshot
                                     cadence (needs --obs-dir)
                --obs-dir DIR        observability output: metrics.jsonl
                                     + events.jsonl land here
                --obs-events-ring N  control-plane event ring capacity
                                     (default 1024; [obs] in TOML)

ENVIRONMENT:
  ELASTICBROKER_ARTIFACTS  artifact dir (default ./artifacts)
  ELASTICBROKER_LOG        error|warn|info|debug|trace
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_values_and_bools() {
        let a = Args::parse(&argv(&[
            "--ranks", "32", "--io-mode=file", "--no-pjrt", "pos1",
        ]))
        .unwrap();
        assert_eq!(a.get("ranks"), Some("32"));
        assert_eq!(a.get("io-mode"), Some("file"));
        assert!(a.has_flag("no-pjrt"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_parsed::<usize>("ranks").unwrap(), Some(32));
    }

    #[test]
    fn bad_numeric_flag_is_error() {
        let a = Args::parse(&argv(&["--ranks", "many"])).unwrap();
        assert!(a.get_parsed::<usize>("ranks").is_err());
    }

    #[test]
    fn parses_address_lists() {
        let a = Args::parse(&argv(&["--endpoints", "127.0.0.1:6379,127.0.0.1:6380"])).unwrap();
        let addrs = a.get_addrs("endpoints").unwrap().unwrap();
        assert_eq!(addrs.len(), 2);
        assert_eq!(addrs[1].port(), 6380);
        let bad = Args::parse(&argv(&["--endpoints", "nonsense"])).unwrap();
        assert!(bad.get_addrs("endpoints").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&[
            "--ranks",
            "8",
            "--steps",
            "100",
            "--io-mode",
            "none",
            "--trigger-ms",
            "500",
            "--dmd-gram-refresh",
            "32",
            "--dmd-shards",
            "4",
            "--rebalance-ms",
            "250",
            "--qos-queue-depth",
            "32",
            "--persist-dir",
            "/tmp/eb-wal",
            "--wal-fsync",
            "always",
            "--retention",
            "--no-pjrt",
            "--consumer-group",
            "dashboard",
            "--results-stream",
            "--io-shards",
            "2",
            "--read-ring-bytes",
            "8192",
            "--max-conns-per-shard",
            "256",
        ]))
        .unwrap();
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.ranks, 8);
        assert_eq!(cfg.steps, 100);
        assert_eq!(cfg.io_mode, crate::config::IoMode::None);
        assert_eq!(cfg.trigger_ms, 500);
        assert_eq!(cfg.dmd_gram_refresh, 32);
        assert_eq!(cfg.dmd_shards, 4);
        assert_eq!(cfg.rebalance_ms, 250);
        assert_eq!(cfg.qos_queue_depth, 32);
        assert_eq!(cfg.wal_dir, "/tmp/eb-wal");
        assert_eq!(cfg.wal_fsync, crate::endpoint::FsyncPolicy::Always);
        assert!(cfg.retention);
        assert!(!cfg.use_pjrt);
        assert_eq!(cfg.consumer_group, "dashboard");
        assert!(cfg.results_stream);
        assert_eq!(cfg.io_shards, 2);
        assert_eq!(cfg.read_ring_bytes, 8192);
        assert_eq!(cfg.max_conns_per_shard, 256);
    }

    #[test]
    fn replication_flags_apply() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&[
            "--replication-factor",
            "2",
            "--replication-domains",
            "rack1, rack2,rack3",
            "--replication-ack",
            "head",
        ]))
        .unwrap();
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.replication_factor, 2);
        assert_eq!(cfg.replication_domains, vec!["rack1", "rack2", "rack3"]);
        assert_eq!(cfg.replication_ack, crate::endpoint::ReplAck::Head);
        // unknown ack mode surfaces as an error
        let bad = Args::parse(&argv(&["--replication-ack", "quorum"])).unwrap();
        let mut cfg = crate::config::WorkflowConfig::default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn stage_flags_apply() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&[
            "--stage-decimate",
            "2",
            "--stage-roi",
            "4:60",
            "--stage-aggregate",
            "4",
            "--stage-convert",
            "f16",
            "--stage-codec",
            "shuffle-lz",
            "--stage-stats",
        ]))
        .unwrap();
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.stages.decimate, 2);
        assert_eq!(cfg.stages.roi, Some((4, 60)));
        assert_eq!(cfg.stages.aggregate, 4);
        assert_eq!(cfg.stages.convert, crate::record::Encoding::F16);
        assert_eq!(cfg.stages.codec, crate::record::CodecKind::ShuffleLz);
        assert!(cfg.stages.stats);
        // bad specs surface as errors
        let bad = Args::parse(&argv(&["--stage-convert", "f64"])).unwrap();
        let mut cfg = crate::config::WorkflowConfig::default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
        let bad = Args::parse(&argv(&["--stage-roi", "60"])).unwrap();
        let mut cfg = crate::config::WorkflowConfig::default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn adapt_flags_apply() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&[
            "--adapt-sweep-ms",
            "100",
            "--adapt-target-p95-us",
            "20000",
            "--adapt-queue-hi",
            "8",
            "--adapt-hysteresis",
            "2",
            "--stage-max-err",
            "0.001",
        ]))
        .unwrap();
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.adapt_sweep_ms, 100);
        assert_eq!(cfg.adapt_target_p95_us, 20_000);
        assert_eq!(cfg.adapt_queue_hi, 8);
        assert_eq!(cfg.adapt_hysteresis, 2);
        assert!((cfg.stages.max_err - 1e-3).abs() < 1e-9);
        assert!(cfg.adapt().enabled());
        cfg.validate().unwrap();
    }

    #[test]
    fn obs_flags_apply() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&[
            "--obs-trace-sample",
            "64",
            "--obs-snapshot-ms",
            "500",
            "--obs-dir",
            "/tmp/eb-obs",
            "--obs-events-ring",
            "512",
        ]))
        .unwrap();
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.obs_trace_sample, 64);
        assert_eq!(cfg.obs_snapshot_ms, 500);
        assert_eq!(cfg.obs_dir, "/tmp/eb-obs");
        assert_eq!(cfg.obs_events_ring, 512);
        cfg.validate().unwrap();
    }

    #[test]
    fn bad_fsync_policy_flag_is_error() {
        let mut cfg = crate::config::WorkflowConfig::default();
        let a = Args::parse(&argv(&["--wal-fsync", "sometimes"])).unwrap();
        assert!(apply_overrides(&mut cfg, &a).is_err());
    }
}
