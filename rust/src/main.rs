//! `elasticbroker` — the launcher.  See [`elasticbroker::cli::USAGE`].

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use elasticbroker::analysis::{CsvSink, DmdConfig, DmdEngine};
use elasticbroker::broker::{Broker, BrokerConfig};
use elasticbroker::cli::{self, Args};
use elasticbroker::config::{IoMode, WorkflowConfig};
use elasticbroker::endpoint::{EndpointServer, ServerConfig, StoreConfig};
use elasticbroker::metrics::WorkflowMetrics;
use elasticbroker::runtime::ArtifactSet;
use elasticbroker::sim::{SimConfig, SimRunner};
use elasticbroker::streamproc::{StreamReader, StreamingConfig, StreamingContext};
use elasticbroker::synth::{self, SynthConfig};
use elasticbroker::transport::ConnConfig;
use elasticbroker::util;
use elasticbroker::workflow;

fn main() {
    elasticbroker::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{}", cli::USAGE);
        std::process::exit(2);
    }
    let sub = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let res = match sub.as_str() {
        "info" => cmd_info(),
        "endpoint" => cmd_endpoint(&args),
        "sim" => cmd_sim(&args),
        "analysis" => cmd_analysis(&args),
        "synth" => cmd_synth(&args),
        "workflow" => cmd_workflow(&args),
        "help" | "--help" | "-h" => {
            print!("{}", cli::USAGE);
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{}", cli::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_info() -> Result<()> {
    println!("elasticbroker 0.1.0 — ElasticBroker (ICCS 2020) reproduction");
    match ArtifactSet::try_load_default() {
        Some(arts) => {
            println!("artifacts ({}):", arts.specs().len());
            for s in arts.specs() {
                let ins: Vec<String> = s
                    .inputs
                    .iter()
                    .map(|t| format!("{}:{:?}", t.name, t.dims))
                    .collect();
                println!("  {:10} {:16} {}", s.name, s.key, ins.join(" "));
            }
        }
        None => println!("artifacts: NOT FOUND (run `make artifacts`; Rust fallbacks active)"),
    }
    let cfg = WorkflowConfig::default();
    println!(
        "defaults: ranks={} lattice={}x{} steps={} interval={} trigger={}ms window={} rank={}",
        cfg.ranks,
        cfg.height,
        cfg.width,
        cfg.steps,
        cfg.write_interval,
        cfg.trigger_ms,
        cfg.dmd_window,
        cfg.dmd_rank
    );
    Ok(())
}

fn cmd_endpoint(args: &Args) -> Result<()> {
    let bind = args.get("bind").unwrap_or("127.0.0.1:6379");
    let wal = match args.get("persist-dir") {
        Some(dir) => Some(elasticbroker::endpoint::WalConfig {
            dir: dir.into(),
            fsync: elasticbroker::endpoint::FsyncPolicy::parse(
                args.get("wal-fsync").unwrap_or("every_ms(5)"),
            )?,
            segment_bytes: args
                .get_parsed::<usize>("wal-segment-bytes")?
                .unwrap_or(64 << 20),
        }),
        None => None,
    };
    let cfg = StoreConfig {
        stream_maxlen: args.get_parsed::<usize>("maxlen")?.unwrap_or(4096),
        max_memory: args.get_parsed::<usize>("max-memory")?.unwrap_or(1 << 30),
        shards: args.get_parsed::<usize>("shards")?.unwrap_or(8).max(1),
        wal,
        retention: args.has_flag("retention"),
    };
    let io_defaults = ServerConfig::default();
    let srv_cfg = ServerConfig {
        io_shards: args
            .get_parsed::<usize>("io-shards")?
            .unwrap_or(io_defaults.io_shards)
            .max(1),
        read_ring_bytes: args
            .get_parsed::<usize>("read-ring-bytes")?
            .unwrap_or(io_defaults.read_ring_bytes)
            .max(512),
        max_conns_per_shard: args
            .get_parsed::<usize>("max-conns-per-shard")?
            .unwrap_or(io_defaults.max_conns_per_shard)
            .max(1),
        ..io_defaults
    };
    let srv = EndpointServer::start_with(bind, cfg, srv_cfg)?;
    println!("endpoint listening on {}", srv.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn load_workflow_config(args: &Args) -> Result<WorkflowConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => WorkflowConfig::from_file(path)?,
        None => WorkflowConfig::default(),
    };
    cli::apply_overrides(&mut cfg, args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cfg = load_workflow_config(args)?;
    let artifacts = ArtifactSet::try_load_default();
    let broker = if cfg.io_mode == IoMode::Broker {
        let endpoints = args
            .get_addrs("endpoints")?
            .context("--endpoints required for --io-mode broker")?;
        Some(Arc::new(Broker::new(
            BrokerConfig {
                group_size: cfg.group_size,
                queue_cap: cfg.queue_cap,
                batch_max_records: cfg.batch_max_records,
                batch_max_bytes: cfg.batch_max_bytes,
                linger_ms: cfg.linger_ms,
                stages: cfg.stages.clone(),
                ..BrokerConfig::new(endpoints)
            },
            cfg.ranks,
            WorkflowMetrics::new(),
        )?))
    } else {
        None
    };
    let sim_cfg = SimConfig {
        ranks: cfg.ranks,
        height: cfg.height,
        width: cfg.width,
        steps: cfg.steps,
        write_interval: cfg.write_interval,
        io_mode: cfg.io_mode,
        out_dir: cfg.out_dir.clone(),
        field: "velocity".into(),
        params: Default::default(),
        use_pjrt: cfg.use_pjrt,
        pfs_commit_ms: cfg.pfs_commit_ms,
    };
    let rep = SimRunner::run(&sim_cfg, broker, artifacts)?;
    println!(
        "simulation: {} ranks × {} steps in {:.2}s [{}] writes/rank={}",
        rep.ranks,
        rep.steps,
        rep.elapsed.as_secs_f64(),
        rep.backend,
        rep.writes_per_rank
    );
    Ok(())
}

fn cmd_analysis(args: &Args) -> Result<()> {
    let cfg = load_workflow_config(args)?;
    let endpoints = args
        .get_addrs("endpoints")?
        .context("--endpoints required")?;
    let field = args.get("field").unwrap_or("velocity").to_string();
    let duration = Duration::from_secs(args.get_parsed::<u64>("duration-secs")?.unwrap_or(60));
    let artifacts = ArtifactSet::try_load_default();
    let metrics = WorkflowMetrics::new();

    // Subscribe each endpoint reader to its groups' streams.
    let groups =
        elasticbroker::broker::GroupMap::new(cfg.ranks, cfg.group_size, endpoints.len())?;
    let mut readers = Vec::new();
    for (e, addr) in endpoints.iter().enumerate() {
        readers.push(StreamReader::connect(
            *addr,
            groups.streams_of_endpoint(e, &field),
            0,
            ConnConfig::default(),
        )?);
    }
    let engine = Arc::new(DmdEngine::new(
        DmdConfig {
            window: cfg.dmd_window,
            rank: cfg.dmd_rank,
            hop: 1,
            gram_refresh: cfg.dmd_gram_refresh,
            shards: cfg.dmd_shards,
            ..Default::default()
        },
        artifacts,
        metrics.clone(),
    )?);
    let csv = if cfg.analysis_csv.is_empty() {
        None
    } else {
        Some(CsvSink::create(&cfg.analysis_csv)?)
    };
    let (tx, rx) = std::sync::mpsc::channel();
    let ctx = StreamingContext::start(
        StreamingConfig {
            trigger_interval: Duration::from_millis(cfg.trigger_ms),
            executors: cfg.executors,
            batch_limit: 0,
        },
        readers,
        move |b| engine.process(b),
        tx,
    );
    let t0 = std::time::Instant::now();
    let mut n = 0usize;
    while t0.elapsed() < duration {
        if let Ok((_seq, res)) = rx.recv_timeout(Duration::from_millis(200)) {
            n += 1;
            if let Some(c) = &csv {
                c.write(&res)?;
            }
            if n % 50 == 0 {
                println!(
                    "analysis: {n} results; latest {} step {} stability {:.3e} ({} µs)",
                    res.key, res.step, res.stability, res.latency_us
                );
            }
        }
    }
    ctx.stop()?;
    if let Some(c) = &csv {
        c.flush()?;
    }
    println!(
        "analysis done: {n} results; latency {}",
        metrics.e2e_latency_us.summary()
    );
    println!(
        "  per-fire analysis {}; gram updates: {} incremental / {} full",
        metrics.analysis_us.summary(),
        metrics.gram_incremental.get(),
        metrics.gram_full.get()
    );
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let endpoints = args
        .get_addrs("endpoints")?
        .context("--endpoints required")?;
    let ranks = args.get_parsed::<usize>("ranks")?.unwrap_or(16);
    let cfg = SynthConfig {
        ranks,
        dim: args.get_parsed::<usize>("dim")?.unwrap_or(512),
        records_per_rank: args.get_parsed::<u64>("records")?.unwrap_or(200),
        rate_hz: args.get_parsed::<f64>("rate")?.unwrap_or(0.0),
        field: args.get("field").unwrap_or("synth").to_string(),
        ..Default::default()
    };
    let metrics = WorkflowMetrics::new();
    let defaults = WorkflowConfig::default();
    let broker = Arc::new(Broker::new(
        BrokerConfig {
            group_size: args.get_parsed::<usize>("group-size")?.unwrap_or(16),
            batch_max_records: args
                .get_parsed::<usize>("batch-max-records")?
                .unwrap_or(defaults.batch_max_records),
            batch_max_bytes: args
                .get_parsed::<usize>("batch-max-bytes")?
                .unwrap_or(defaults.batch_max_bytes),
            linger_ms: args.get_parsed::<u64>("linger-ms")?.unwrap_or(defaults.linger_ms),
            ..BrokerConfig::new(endpoints)
        },
        ranks,
        metrics.clone(),
    )?);
    let rep = synth::run(&cfg, broker)?;
    println!(
        "synth: {} records ({}) in {:.2}s → {}/s",
        rep.records,
        util::fmt_bytes(rep.bytes),
        rep.elapsed.as_secs_f64(),
        util::fmt_bytes((rep.bytes as f64 / rep.elapsed.as_secs_f64()) as u64)
    );
    Ok(())
}

fn cmd_workflow(args: &Args) -> Result<()> {
    let cfg = load_workflow_config(args)?;
    let artifacts = ArtifactSet::try_load_default();
    if artifacts.is_none() && cfg.use_pjrt {
        log::warn!("artifacts not found; running with Rust fallbacks");
    }
    let rep = workflow::run_cfd_workflow(&cfg, artifacts)?;
    println!(
        "workflow [{}] io={} interval={}: sim {:.2}s, end-to-end {:.2}s, {} analyses",
        rep.backend,
        cfg.io_mode.name(),
        cfg.write_interval,
        rep.sim_elapsed.as_secs_f64(),
        rep.workflow_elapsed.as_secs_f64(),
        rep.analysis_results.len()
    );
    if !rep.analysis_results.is_empty() {
        println!("  e2e latency: {}", rep.metrics.e2e_latency_us.summary());
        println!(
            "  shipped: {} ({}/s)",
            util::fmt_bytes(rep.metrics.shipped.bytes()),
            util::fmt_bytes(rep.metrics.shipped.lifetime_bytes_per_sec() as u64)
        );
        // Fig 5 style summary: mean stability per rank/region.
        let mut per_rank: std::collections::BTreeMap<u32, (f64, usize)> = Default::default();
        for a in &rep.analysis_results {
            let e = per_rank.entry(a.rank).or_insert((0.0, 0));
            e.0 += a.stability;
            e.1 += 1;
        }
        println!("  per-region stability (mean over windows):");
        for (rank, (sum, n)) in per_rank {
            println!("    region {rank:>3}: {:.4e}", sum / n as f64);
        }
    }
    Ok(())
}
