//! End-to-end workflow orchestration — the paper's Fig 2 deployment,
//! in-process: bring up the Cloud side (endpoints + streaming service +
//! DMD executors + collector), run the HPC side (simulation or
//! synthetic generators + broker), and gather the metrics every
//! experiment reports.
//!
//! The experiment drivers here are what the benches and examples call:
//!
//! * [`run_cfd_workflow`]   — Fig 5 (per-region stability) + Fig 6
//!   (elapsed/end-to-end time per I/O mode),
//! * [`run_synth_workflow`] — Fig 7 (latency + aggregated throughput at
//!   scale, ranks : endpoints : executors = 16 : 1 : 16).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::analysis::{AnalysisResult, CsvSink, DmdConfig, DmdEngine};
use crate::broker::{
    AdaptController, Broker, BrokerConfig, QosThresholds, Rebalancer, TopologyHandle,
};
use crate::config::{IoMode, WorkflowConfig};
use crate::endpoint::{
    DialReplicaLink, EndpointServer, ReplAck, ReplicaLink, ReplicationMap,
    ServerConfig, Store, StoreConfig,
};
use crate::metrics::WorkflowMetrics;
use crate::runtime::ArtifactSet;
use crate::sim::{SimConfig, SimRunner};
use crate::streamproc::{
    ElasticReader, Poller, StreamReader, StreamingConfig, StreamingContext,
};
use crate::synth::{self, SynthConfig};
use crate::transport::{ConnConfig, Dialer, TcpDialer};

/// The running Cloud side: endpoints + streaming + analysis + collector.
pub struct CloudSide {
    pub endpoints: Vec<EndpointServer>,
    streaming: Option<StreamingContext>,
    collector: Option<std::thread::JoinHandle<Vec<AnalysisResult>>>,
    pub metrics: WorkflowMetrics,
    /// The shared versioned topology when the run is elastic
    /// (`cfg.rebalance_ms > 0`); `None` for static runs.
    pub topology: Option<TopologyHandle>,
    last_result_us: Arc<AtomicU64>,
    obs_stop: Arc<AtomicBool>,
    obs_writer: Option<std::thread::JoinHandle<()>>,
}

/// One [`DialReplicaLink`] (one lazily-dialed connection) per
/// `(endpoint, successor)` chain edge, shared by every stream routed
/// over that edge and reused across rewires while the edge survives —
/// an epoch bump must not redial connections that didn't move.
type LinkCache = Mutex<HashMap<(usize, usize), Arc<dyn ReplicaLink>>>;

/// Compute each endpoint's per-stream successor links from the current
/// replica chains (ISSUE 10): every non-tail chain member gets a link
/// to its successor for every stream of the group; tails and
/// unreplicated groups get none (`None` map = forwarding off).  Links
/// come from `links`, so the N streams of a group share one connection
/// and unchanged edges keep theirs across epoch bumps; edges no longer
/// in any chain are dropped from the cache (closing the connection once
/// the last old map holding it is swapped out).
fn replication_maps(
    topo: &crate::broker::Topology,
    field: &str,
    ack: ReplAck,
    dialer: &Arc<dyn Dialer>,
    links: &LinkCache,
    n_endpoints: usize,
) -> Result<Vec<Option<Arc<ReplicationMap>>>> {
    let mut maps: Vec<ReplicationMap> =
        (0..n_endpoints).map(|_| ReplicationMap::new(ack)).collect();
    let mut links = links.lock().unwrap();
    let mut used: HashSet<(usize, usize)> = HashSet::new();
    for r in 0..topo.groups.total_ranks() {
        let key = crate::record::stream_key(field, r as u32);
        let g = topo.groups.group_of_rank(r)?;
        let chain = topo.replica_chain(g)?;
        for w in chain.windows(2) {
            let edge = (w[0], w[1]);
            used.insert(edge);
            let link = links
                .entry(edge)
                .or_insert_with(|| {
                    Arc::new(DialReplicaLink::new(dialer.clone(), w[1]))
                        as Arc<dyn ReplicaLink>
                })
                .clone();
            maps[w[0]].insert(key.clone(), link);
        }
    }
    links.retain(|edge, _| used.contains(edge));
    Ok(maps
        .into_iter()
        .map(|m| if m.is_empty() { None } else { Some(Arc::new(m)) })
        .collect())
}

/// Install the maps from [`replication_maps`] onto the endpoint stores.
fn install_replication(
    topo: &crate::broker::Topology,
    stores: &[Arc<Store>],
    field: &str,
    ack: ReplAck,
    dialer: &Arc<dyn Dialer>,
    links: &LinkCache,
) -> Result<()> {
    let maps = replication_maps(topo, field, ack, dialer, links, stores.len())?;
    for (store, map) in stores.iter().zip(maps) {
        store.set_replication(map);
    }
    Ok(())
}

impl CloudSide {
    /// Bring up `n_endpoints` endpoint servers and a streaming service
    /// subscribed to `field/<rank>` for every rank, analysing with DMD.
    pub fn start(
        cfg: &WorkflowConfig,
        field: &str,
        artifacts: Option<Arc<ArtifactSet>>,
        metrics: WorkflowMetrics,
        csv: Option<CsvSink>,
        warm_dim: Option<usize>,
    ) -> Result<CloudSide> {
        let n_endpoints = cfg.endpoint_count();

        // Flight recorder (ISSUE 9): size the control-plane event ring
        // and, when an obs dir is configured, attach the JSONL event
        // sink and start the periodic registry snapshot writer.
        metrics.events.set_capacity(cfg.obs_events_ring);
        let obs_stop = Arc::new(AtomicBool::new(false));
        let mut obs_writer = None;
        if !cfg.obs_dir.is_empty() {
            std::fs::create_dir_all(&cfg.obs_dir)?;
            let dir = std::path::PathBuf::from(&cfg.obs_dir);
            metrics.events.set_sink(&dir.join("events.jsonl"))?;
            if cfg.obs_snapshot_ms > 0 {
                let registry = metrics.registry.clone();
                let stop = obs_stop.clone();
                let period = Duration::from_millis(cfg.obs_snapshot_ms);
                let path = dir.join("metrics.jsonl");
                obs_writer = Some(
                    std::thread::Builder::new()
                        .name("obs-snapshot".into())
                        .spawn(move || {
                            use std::io::Write;
                            let mut file = match std::fs::OpenOptions::new()
                                .create(true)
                                .append(true)
                                .open(&path)
                            {
                                Ok(f) => f,
                                Err(e) => {
                                    log::warn!("obs: open {}: {e}", path.display());
                                    return;
                                }
                            };
                            let mut buf = String::new();
                            'sweeps: loop {
                                let deadline = Instant::now() + period;
                                while Instant::now() < deadline {
                                    if stop.load(Ordering::Relaxed) {
                                        break 'sweeps;
                                    }
                                    std::thread::sleep(Duration::from_millis(5));
                                }
                                buf.clear();
                                registry
                                    .snapshot_json(crate::util::epoch_micros(), &mut buf);
                                buf.push('\n');
                                if let Err(e) = file.write_all(buf.as_bytes()) {
                                    log::warn!("obs: snapshot write: {e}");
                                    return;
                                }
                            }
                            // Final snapshot at shutdown so runs shorter
                            // than one period still land a data point.
                            buf.clear();
                            registry.snapshot_json(crate::util::epoch_micros(), &mut buf);
                            buf.push('\n');
                            let _ = file.write_all(buf.as_bytes());
                        })?,
                );
            }
        }

        let mut endpoints = Vec::with_capacity(n_endpoints);
        for i in 0..n_endpoints {
            // Durable endpoints (ISSUE 4): one WAL per endpoint under
            // `wal_dir/ep<i>`, so a restarted endpoint replays only its
            // own streams.
            let wal = if cfg.wal_dir.is_empty() {
                None
            } else {
                Some(crate::endpoint::WalConfig {
                    dir: std::path::PathBuf::from(&cfg.wal_dir).join(format!("ep{i}")),
                    fsync: cfg.wal_fsync,
                    segment_bytes: cfg.wal_segment_bytes,
                })
            };
            // ISSUE 7: size the endpoint's event loop from the config
            // and mirror its connection/byte stats into the QoS board
            // slot the rebalancer already watches.
            let srv = EndpointServer::start_with(
                "127.0.0.1:0",
                StoreConfig {
                    shards: cfg.store_shards,
                    wal,
                    retention: cfg.retention,
                    ..StoreConfig::default()
                },
                ServerConfig {
                    io_shards: cfg.io_shards,
                    read_ring_bytes: cfg.read_ring_bytes,
                    max_conns_per_shard: cfg.max_conns_per_shard,
                    metrics: Some(metrics.qos.slot(i)),
                    events: Some(metrics.events.clone()),
                },
            )?;
            // METRICS exposition on the endpoint covers the workflow
            // registry too, and WAL lifecycle events land in the shared
            // journal.
            srv.store().set_registry(metrics.registry.clone());
            srv.store().set_events(metrics.events.clone());
            endpoints.push(srv);
            if !cfg.wal_dir.is_empty() {
                // Advertise durability on the QoS board: the rebalancer
                // prefers durable endpoints as migration targets.
                metrics.qos.slot(i).durable.set(1);
            }
        }

        // Readers.  Static runs keep the paper's fixed executor↔stream
        // mapping (one reader per endpoint).  Elastic runs poll through
        // a single ElasticReader that follows streams across endpoints
        // as the rebalancer migrates them.
        let groups = crate::broker::GroupMap::new(cfg.ranks, cfg.group_size, n_endpoints)?;
        let addrs: Vec<std::net::SocketAddr> =
            endpoints.iter().map(|e| e.addr()).collect();
        let mut readers: Vec<Box<dyn Poller>> = Vec::with_capacity(n_endpoints);
        let topology = if cfg.rebalance_ms > 0 {
            // Chain replication (ISSUE 10) hangs off the same versioned
            // topology; factor 1 keeps the plain static layout.
            let topo = if cfg.replication_factor > 1 {
                TopologyHandle::new_replicated(
                    groups.clone(),
                    addrs,
                    &cfg.replication_domains,
                    cfg.replication_factor,
                )?
            } else {
                TopologyHandle::new_static(groups.clone(), addrs)?
            };
            let resolver = topo.clone();
            let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
                move |e| resolver.endpoint_addr(e),
                ConnConfig::default(),
            ));
            let keys: Vec<String> = (0..cfg.ranks)
                .map(|r| crate::record::stream_key(field, r as u32))
                .collect();
            let mut elastic = ElasticReader::new(topo.clone(), dialer, keys, 0)?;
            // With retention on, consumed cursors are acked back so the
            // endpoints can trim their WALs.
            elastic.set_auto_ack(cfg.retention);
            // Named consumer group (ISSUE 6): acks land on this group's
            // cursor, so side-car consumers keep independent positions.
            if !cfg.consumer_group.is_empty() {
                elastic.set_group(cfg.consumer_group.as_str());
            }
            elastic.set_corrupt_counter(metrics.records_corrupt.clone());
            elastic.set_trace(metrics.trace.clone());
            readers.push(Box::new(elastic));
            Some(topo)
        } else {
            for (e, srv) in endpoints.iter().enumerate() {
                let keys = groups.streams_of_endpoint(e, field);
                let mut reader =
                    StreamReader::connect(srv.addr(), keys, 0, ConnConfig::default())?;
                reader.set_auto_ack(cfg.retention);
                if !cfg.consumer_group.is_empty() {
                    reader.set_group(cfg.consumer_group.as_str());
                }
                reader.set_corrupt_counter(metrics.records_corrupt.clone());
                reader.set_trace(metrics.trace.clone());
                readers.push(Box::new(reader));
            }
            None
        };

        // ISSUE 10: wire each store's per-stream successor link from the
        // replica chains, and re-wire *synchronously inside every epoch
        // bump* via the topology change observer — a failover promotion
        // must install the new head's map in the same call stack, or
        // tail-acked writes in the window before a polling sweep would
        // be acked without ever reaching a successor.
        if cfg.replication_factor > 1 {
            if let Some(topo) = &topology {
                let resolver = topo.clone();
                // Bounded reads on the forwarding links: a wedged
                // successor must bounce the write (REPL, retried by the
                // shipper) rather than park the head's I/O shard.
                let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
                    move |e| resolver.endpoint_addr(e),
                    ConnConfig {
                        max_retries: 1,
                        read_timeout: Some(Duration::from_secs(2)),
                        ..ConnConfig::default()
                    },
                ));
                let stores: Vec<Arc<Store>> =
                    endpoints.iter().map(|s| s.store().clone()).collect();
                let ack = cfg.replication_ack;
                let wfield = field.to_string();
                let links: Arc<LinkCache> = Arc::new(Mutex::new(HashMap::new()));
                install_replication(
                    &topo.snapshot(), &stores, &wfield, ack, &dialer, &links,
                )?;
                topo.set_on_change(move |t| {
                    if let Err(e) = install_replication(
                        t, &stores, &wfield, ack, &dialer, &links,
                    ) {
                        log::warn!(
                            "replication: re-wire at epoch {}: {e:#}",
                            t.epoch
                        );
                    }
                });
            }
        }

        let engine = Arc::new(DmdEngine::new(
            DmdConfig {
                window: cfg.dmd_window,
                rank: cfg.dmd_rank,
                hop: 1,
                backend: if cfg.dmd_use_pjrt {
                    crate::analysis::DmdBackend::Pjrt
                } else {
                    crate::analysis::DmdBackend::Rust
                },
                fire: if cfg.dmd_per_batch {
                    crate::analysis::FirePolicy::PerBatch
                } else {
                    crate::analysis::FirePolicy::PerSnapshot
                },
                gram_refresh: cfg.dmd_gram_refresh,
                shards: cfg.dmd_shards,
            },
            artifacts,
            metrics.clone(),
        )?);
        if let Some(d) = warm_dim {
            engine.warm(d);
        }

        let (tx, rx) = channel::<(u64, AnalysisResult)>();
        let streaming = StreamingContext::start(
            StreamingConfig {
                trigger_interval: Duration::from_millis(cfg.trigger_ms),
                executors: cfg.executors,
                batch_limit: 0,
            },
            readers,
            move |batch| engine.process(batch),
            tx,
        );

        // Results stream (ISSUE 6): every fire is published back into
        // the first endpoint's store as a compact `results/<field>/<rank>`
        // stream that any number of subscribers tail through the same
        // reader machinery as the data streams.
        let results_store = if cfg.results_stream {
            Some(endpoints[0].store().clone())
        } else {
            None
        };
        let last_result_us = Arc::new(AtomicU64::new(0));
        let collector_last = last_result_us.clone();
        let collector = std::thread::Builder::new()
            .name("collector".into())
            .spawn(move || {
                let mut results = Vec::new();
                while let Ok((_seq, res)) = rx.recv() {
                    collector_last.store(crate::util::epoch_micros(), Ordering::Relaxed);
                    if let Some(store) = &results_store {
                        let rec = res.to_record();
                        let key = rec.stream_key();
                        if let Err(e) =
                            store.xadd(&key, None, vec![(b"r".to_vec(), rec.encode())])
                        {
                            log::warn!("results stream: publish to {key} failed: {e:#}");
                        }
                    }
                    if let Some(sink) = &csv {
                        let _ = sink.write(&res);
                    }
                    results.push(res);
                }
                if let Some(sink) = &csv {
                    let _ = sink.flush();
                }
                results
            })?;

        Ok(CloudSide {
            endpoints,
            streaming: Some(streaming),
            collector: Some(collector),
            metrics,
            topology,
            last_result_us,
            obs_stop,
            obs_writer,
        })
    }

    /// Endpoint addresses (for the HPC-side broker config).
    pub fn endpoint_addrs(&self) -> Vec<std::net::SocketAddr> {
        self.endpoints.iter().map(|e| e.addr()).collect()
    }

    /// Stop streaming (drains the tail), then collect all results.
    pub fn finish(mut self) -> Result<(Vec<AnalysisResult>, u64)> {
        if let Some(s) = self.streaming.take() {
            s.stop()?;
        }
        let results = self
            .collector
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow::anyhow!("collector panicked"))?;
        self.obs_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.obs_writer.take() {
            let _ = h.join();
        }
        // Drop the replication rewire observer: it owns clones of the
        // endpoint stores and the link cache, which must not outlive
        // the cloud side.
        if let Some(topo) = &self.topology {
            topo.clear_on_change();
        }
        self.metrics.events.flush();
        let last_us = self.last_result_us.load(Ordering::Relaxed);
        Ok((results, last_us))
    }
}

/// Everything the Fig 5/6 experiments report.
pub struct CfdWorkflowReport {
    /// Simulation wall-clock (the paper's "simulation elapsed time").
    pub sim_elapsed: Duration,
    /// Simulation start → last analysis result (the paper's "workflow
    /// end-to-end time"); equals `sim_elapsed` for non-broker modes.
    pub workflow_elapsed: Duration,
    pub analysis_results: Vec<AnalysisResult>,
    pub metrics: WorkflowMetrics,
    pub backend: &'static str,
}

/// Fig 5 + Fig 6 driver: CFD simulation (16 ranks by default) with the
/// chosen I/O mode; when the mode is `Broker`, the full Cloud side runs
/// alongside and DMD results are collected.
pub fn run_cfd_workflow(
    cfg: &WorkflowConfig,
    artifacts: Option<Arc<ArtifactSet>>,
) -> Result<CfdWorkflowReport> {
    cfg.validate()?;
    let field = "velocity";
    let metrics = WorkflowMetrics::new();

    let sim_cfg = SimConfig {
        ranks: cfg.ranks,
        height: cfg.height,
        width: cfg.width,
        steps: cfg.steps,
        write_interval: cfg.write_interval,
        io_mode: cfg.io_mode,
        out_dir: cfg.out_dir.clone(),
        field: field.into(),
        params: Default::default(),
        use_pjrt: cfg.use_pjrt,
        pfs_commit_ms: cfg.pfs_commit_ms,
    };

    if cfg.io_mode != IoMode::Broker {
        // No Cloud side: Fig 6 baseline modes.
        let t0 = Instant::now();
        let rep = SimRunner::run(&sim_cfg, None, artifacts)?;
        let elapsed = t0.elapsed();
        return Ok(CfdWorkflowReport {
            sim_elapsed: rep.elapsed,
            workflow_elapsed: elapsed,
            analysis_results: Vec::new(),
            metrics,
            backend: rep.backend,
        });
    }

    let csv = if cfg.analysis_csv.is_empty() {
        None
    } else {
        Some(CsvSink::create(&cfg.analysis_csv)?)
    };
    let cloud = CloudSide::start(
        cfg,
        field,
        artifacts.clone(),
        metrics.clone(),
        csv,
        Some(cfg.snapshot_dim()?),
    )?;
    let broker_cfg = BrokerConfig {
        group_size: cfg.group_size,
        queue_cap: cfg.queue_cap,
        policy: if cfg.drop_oldest {
            crate::broker::QueuePolicy::DropOldest
        } else {
            crate::broker::QueuePolicy::Block
        },
        batch_max_records: cfg.batch_max_records,
        batch_max_bytes: cfg.batch_max_bytes,
        linger_ms: cfg.linger_ms,
        stages: cfg.stages.clone(),
        adapt: cfg.adapt(),
        trace_sample: cfg.obs_trace_sample,
        ..BrokerConfig::new(cloud.endpoint_addrs())
    };
    // Elastic runs share the Cloud side's versioned topology with the
    // broker writers and run the QoS rebalancer alongside.
    let (broker, rebalancer) = match cloud.topology.clone() {
        Some(topo) => {
            let conn_cfg = broker_cfg.conn.clone();
            let resolver = topo.clone();
            let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
                move |e| resolver.endpoint_addr(e),
                conn_cfg,
            ));
            let broker = Arc::new(Broker::with_topology(
                broker_cfg,
                topo.clone(),
                dialer,
                metrics.clone(),
            )?);
            let reb = Rebalancer::start(
                topo,
                metrics.clone(),
                QosThresholds {
                    flush_p95_us: cfg.qos_flush_p95_us,
                    queue_depth: cfg.qos_queue_depth,
                    reconnects: cfg.qos_reconnects,
                },
                Duration::from_millis(cfg.rebalance_ms),
            );
            (broker, Some(reb))
        }
        None => (
            Arc::new(Broker::new(broker_cfg, cfg.ranks, metrics.clone())?),
            None,
        ),
    };
    // ISSUE 8: fidelity adaptation runs with *any* topology — static
    // runs adapt too; elasticity is orthogonal.  The controller sweeps
    // the same QoS windows as the rebalancer (shared, non-destructive).
    let adapt_controller = if broker.adapt_enabled() {
        Some(AdaptController::start(
            broker.adapt_registry(),
            broker.topology().clone(),
            metrics.clone(),
            cfg.adapt(),
        ))
    } else {
        None
    };

    let t0 = Instant::now();
    let start_us = crate::util::epoch_micros();
    let rep = SimRunner::run(&sim_cfg, Some(broker), artifacts)?;
    let sim_elapsed = rep.elapsed;
    if let Some(ac) = adapt_controller {
        ac.stop(); // freeze fidelity while the tail drains
    }
    if let Some(reb) = rebalancer {
        reb.stop(); // no topology churn while the tail drains
    }
    let (results, last_us) = cloud.finish()?;
    let workflow_elapsed = if last_us > start_us {
        Duration::from_micros(last_us - start_us)
    } else {
        t0.elapsed()
    };
    let snapshots_per_rank = cfg.steps / cfg.write_interval;
    if results.is_empty() && snapshots_per_rank > cfg.dmd_window as u64 {
        anyhow::bail!(
            "broker workflow produced no analysis results \
             ({snapshots_per_rank} snapshots/rank should fill the {}+1 window)",
            cfg.dmd_window
        );
    }
    Ok(CfdWorkflowReport {
        sim_elapsed,
        workflow_elapsed,
        analysis_results: results,
        metrics,
        backend: rep.backend,
    })
}

/// Fig 7 report for one scale point.
pub struct SynthWorkflowReport {
    pub ranks: usize,
    pub endpoints: usize,
    pub executors: usize,
    pub records: u64,
    pub analyses: usize,
    /// Generation wall-clock.
    pub gen_elapsed: Duration,
    /// Aggregated generator throughput (bytes/sec).
    pub gen_bytes_per_sec: f64,
    pub metrics: WorkflowMetrics,
}

/// Fig 7 driver: synthetic generators at `ranks` scale with the paper's
/// 16:1:16 ratio, measuring end-to-end latency and aggregated
/// throughput.
pub fn run_synth_workflow(
    ranks: usize,
    records_per_rank: u64,
    dim: usize,
    trigger_ms: u64,
    rate_hz: f64,
    artifacts: Option<Arc<ArtifactSet>>,
) -> Result<SynthWorkflowReport> {
    let cfg = WorkflowConfig {
        ranks,
        group_size: 16,
        executors: ranks, // paper: #executors == #simulation processes
        trigger_ms,
        dmd_window: 8,
        dmd_rank: 6,
        // On the CPU PJRT plugin the ~2 ms per-dispatch overhead of the
        // compiled reduction swamps the d=512 maths at Fig 7 record
        // rates (EXPERIMENTS.md §Perf); the Rust mirror is semantically
        // identical, so the scaling experiment uses it by default.
        dmd_use_pjrt: false,
        // height/width unused by the synth path but must validate:
        height: ranks, // 1 row per rank keeps height % ranks == 0
        ..Default::default()
    };
    let field = "synth";
    let metrics = WorkflowMetrics::new();
    let cloud = CloudSide::start(&cfg, field, artifacts, metrics.clone(), None, Some(dim))?;
    let broker = Arc::new(Broker::new(
        BrokerConfig {
            group_size: cfg.group_size,
            queue_cap: cfg.queue_cap,
            batch_max_records: cfg.batch_max_records,
            batch_max_bytes: cfg.batch_max_bytes,
            linger_ms: cfg.linger_ms,
            ..BrokerConfig::new(cloud.endpoint_addrs())
        },
        ranks,
        metrics.clone(),
    )?);

    let synth_cfg = SynthConfig {
        ranks,
        dim,
        records_per_rank,
        rate_hz,
        field: field.into(),
        ..Default::default()
    };
    let gen = synth::run(&synth_cfg, broker)?;
    let (results, _) = cloud.finish()?;
    Ok(SynthWorkflowReport {
        ranks,
        endpoints: cfg.endpoint_count(),
        executors: cfg.executors,
        records: gen.records,
        analyses: results.len(),
        gen_elapsed: gen.elapsed,
        gen_bytes_per_sec: gen.bytes as f64 / gen.elapsed.as_secs_f64().max(1e-9),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(io: IoMode) -> WorkflowConfig {
        WorkflowConfig {
            ranks: 4,
            height: 32,
            width: 64,
            steps: 60,
            write_interval: 5,
            io_mode: io,
            out_dir: std::env::temp_dir()
                .join(format!("eb-wf-{}", std::process::id()))
                .to_string_lossy()
                .into_owned(),
            use_pjrt: false,
            group_size: 4,
            executors: 4,
            trigger_ms: 50,
            dmd_window: 4,
            dmd_rank: 3,
            ..Default::default()
        }
    }

    #[test]
    fn broker_workflow_end_to_end() {
        let cfg = tiny_cfg(IoMode::Broker);
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        // 60 steps, write every 5 → 12 snapshots/rank; window 4+1 fills
        // at 5 then fires per snapshot → 8 analyses per rank × 4 ranks.
        assert_eq!(rep.analysis_results.len(), 8 * 4);
        assert!(rep.workflow_elapsed >= rep.sim_elapsed);
        // every rank produced results with finite stability
        for r in 0..4u32 {
            let per: Vec<_> = rep
                .analysis_results
                .iter()
                .filter(|a| a.rank == r)
                .collect();
            assert_eq!(per.len(), 8, "rank {r}");
            assert!(per.iter().all(|a| a.stability.is_finite()));
        }
        assert_eq!(rep.metrics.dropped.get(), 0);
        assert!(rep.metrics.shipped.bytes() > 0);
    }

    /// ISSUE 3: the elastic wiring (versioned topology + ElasticReader
    /// + rebalancer) behind `rebalance_ms > 0` must reproduce the
    /// static run exactly when QoS stays quiet.
    #[test]
    fn elastic_workflow_matches_static_behaviour() {
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.rebalance_ms = 25;
        // thresholds a healthy loopback run never crosses
        cfg.qos_flush_p95_us = 60_000_000;
        cfg.qos_queue_depth = 1 << 32;
        cfg.qos_reconnects = 1 << 32;
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert_eq!(rep.analysis_results.len(), 8 * 4);
        assert_eq!(rep.metrics.dropped.get(), 0);
        assert_eq!(
            rep.metrics.migrations.get(),
            0,
            "quiet QoS must not migrate anything"
        );
        assert_eq!(rep.metrics.stale_rejections.get(), 0);
        for r in 0..4u32 {
            let per = rep
                .analysis_results
                .iter()
                .filter(|a| a.rank == r)
                .count();
            assert_eq!(per, 8, "rank {r}");
        }
    }

    /// ISSUE 10: a factor-2 replicated run with calm QoS keeps the
    /// static coverage, and — because acks are tail-acks — every
    /// stream's follower copy is byte-identical to the head's.
    #[test]
    fn replicated_workflow_mirrors_streams_on_chain_tails() {
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.endpoints = Some(2);
        cfg.group_size = 2; // 4 ranks → 2 groups over 2 endpoints
        cfg.rebalance_ms = 25;
        cfg.qos_flush_p95_us = 60_000_000;
        cfg.qos_queue_depth = 1 << 32;
        cfg.qos_reconnects = 1 << 32;
        cfg.replication_factor = 2;
        cfg.validate().unwrap();
        let field = "velocity";
        let metrics = WorkflowMetrics::new();
        let cloud =
            CloudSide::start(&cfg, field, None, metrics.clone(), None, None).unwrap();
        let topo = cloud.topology.clone().expect("elastic topology");
        let resolver = topo.clone();
        let dialer: Arc<dyn Dialer> = Arc::new(TcpDialer::new(
            move |e| resolver.endpoint_addr(e),
            ConnConfig::default(),
        ));
        let broker = Arc::new(
            Broker::with_topology(
                BrokerConfig {
                    group_size: cfg.group_size,
                    ..BrokerConfig::new(cloud.endpoint_addrs())
                },
                topo.clone(),
                dialer,
                metrics.clone(),
            )
            .unwrap(),
        );
        let sim_cfg = SimConfig {
            ranks: cfg.ranks,
            height: cfg.height,
            width: cfg.width,
            steps: cfg.steps,
            write_interval: cfg.write_interval,
            io_mode: cfg.io_mode,
            out_dir: cfg.out_dir.clone(),
            field: field.into(),
            params: Default::default(),
            use_pjrt: false,
            pfs_commit_ms: 0,
        };
        let stores: Vec<Arc<Store>> =
            cloud.endpoints.iter().map(|s| s.store().clone()).collect();
        let snap = topo.snapshot();
        SimRunner::run(&sim_cfg, Some(broker), None).unwrap();
        let (results, _) = cloud.finish().unwrap();
        assert_eq!(results.len(), 8 * 4);
        assert_eq!(metrics.dropped.get(), 0);
        assert_eq!(metrics.migrations.get(), 0, "calm QoS: no failover");
        let max = crate::endpoint::EntryId {
            ms: u64::MAX,
            seq: u64::MAX,
        };
        let mut forwarded = 0;
        for r in 0..cfg.ranks {
            let key = crate::record::stream_key(field, r as u32);
            let g = snap.groups.group_of_rank(r).unwrap();
            let chain = snap.replica_chain(g).unwrap();
            assert_eq!(chain.len(), 2, "{key}: chain not at factor");
            let head = stores[chain[0]].range(&key, crate::endpoint::EntryId::ZERO, max, 0);
            let tail = stores[chain[1]].range(&key, crate::endpoint::EntryId::ZERO, max, 0);
            assert_eq!(head.len(), 12, "{key}: 12 snapshots on the head");
            assert_eq!(head.len(), tail.len(), "{key}: tail copy short");
            for (x, y) in head.iter().zip(&tail) {
                assert_eq!(x.id, y.id, "{key}: divergent entry ids");
                assert_eq!(x.fields, y.fields, "{key}: divergent payloads");
            }
        }
        for s in &stores {
            forwarded += s.repl_forwarded();
        }
        // 12 writes × 4 streams, plus one HELLO per stream registration.
        assert!(forwarded >= 12 * 4, "head writes not forwarded: {forwarded}");
    }

    /// ISSUE 4: the same workflow with durable endpoints + retention
    /// produces identical analysis coverage, leaves WAL segments on
    /// disk, and the reader acks keep the logs bounded.
    #[test]
    fn durable_workflow_matches_in_memory_behaviour() {
        let wal_root = std::env::temp_dir().join(format!(
            "eb-wf-wal-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&wal_root);
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.wal_dir = wal_root.to_string_lossy().into_owned();
        cfg.wal_fsync = crate::endpoint::FsyncPolicy::EveryMs(2);
        cfg.retention = true;
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert_eq!(rep.analysis_results.len(), 8 * 4);
        assert_eq!(rep.metrics.dropped.get(), 0);
        assert_eq!(rep.metrics.replay_gaps.get(), 0);
        // the endpoint's WAL really exists on disk
        let ep0 = wal_root.join("ep0");
        let segs = std::fs::read_dir(&ep0)
            .expect("wal dir missing")
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with("wal-"))
            .count();
        assert!(segs >= 1, "no wal segments written");
        let _ = std::fs::remove_dir_all(&wal_root);
    }

    /// ISSUE 5: a lossless staged run (shuffle-lz wire codec) keeps
    /// the exact analysis coverage of the raw run while shipping fewer
    /// bytes end to end.
    #[test]
    fn staged_workflow_reduces_shipped_bytes() {
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.stages.codec = crate::record::CodecKind::ShuffleLz;
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert_eq!(rep.analysis_results.len(), 8 * 4, "coverage must not change");
        assert_eq!(rep.metrics.dropped.get(), 0);
        let st = &rep.metrics.stages;
        assert_eq!(st.records_in.get(), 12 * 4, "12 snapshots × 4 ranks");
        assert!(
            st.bytes_out.get() < st.bytes_in.get(),
            "smooth CFD fields must compress: {} vs {}",
            st.bytes_out.get(),
            st.bytes_in.get()
        );
        assert!(st.reduction_factor() > 1.0);
        // per-stage cost clocks ticked
        assert_eq!(st.compress_us.count(), 12 * 4);
        for r in 0..4u32 {
            let per = rep
                .analysis_results
                .iter()
                .filter(|a| a.rank == r)
                .count();
            assert_eq!(per, 8, "rank {r}");
        }
    }

    /// ISSUE 6: with `results_stream` on, every collected fire is also
    /// published on a `results/<field>/<rank>` stream; a subscriber
    /// tailing it through the ordinary reader machinery sees the same
    /// eigenvalues/σ/stability the engine fired (bit-exact here, well
    /// inside the 1e-9 acceptance bound).
    #[test]
    fn results_stream_mirrors_collected_fires() {
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.results_stream = true;
        cfg.consumer_group = "fig5-dashboard".into();
        let field = "velocity";
        let metrics = WorkflowMetrics::new();
        let cloud =
            CloudSide::start(&cfg, field, None, metrics.clone(), None, None).unwrap();
        let broker = Arc::new(
            Broker::new(
                BrokerConfig {
                    group_size: cfg.group_size,
                    ..BrokerConfig::new(cloud.endpoint_addrs())
                },
                cfg.ranks,
                metrics.clone(),
            )
            .unwrap(),
        );
        let sim_cfg = SimConfig {
            ranks: cfg.ranks,
            height: cfg.height,
            width: cfg.width,
            steps: cfg.steps,
            write_interval: cfg.write_interval,
            io_mode: cfg.io_mode,
            out_dir: cfg.out_dir.clone(),
            field: field.into(),
            params: Default::default(),
            use_pjrt: false,
            pfs_commit_ms: 0,
        };
        SimRunner::run(&sim_cfg, Some(broker), None).unwrap();
        // Tail the results streams while the cloud is still up.  The
        // poller keeps triggering after the simulation ends, so all
        // 8 fires × 4 ranks land without needing finish() first.
        let keys: Vec<String> = (0..cfg.ranks)
            .map(|r| {
                crate::analysis::results_key(&crate::record::stream_key(
                    field, r as u32,
                ))
            })
            .collect();
        let mut sub = StreamReader::connect(
            cloud.endpoints[0].addr(),
            keys,
            0,
            ConnConfig::default(),
        )
        .unwrap();
        let mut seen = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(30);
        while seen.len() < 8 * 4 && Instant::now() < deadline {
            for batch in sub.poll().unwrap() {
                for rec in &batch.records {
                    seen.push(AnalysisResult::from_record(rec).unwrap());
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let (results, _) = cloud.finish().unwrap();
        assert_eq!(results.len(), 8 * 4);
        assert_eq!(seen.len(), 8 * 4, "subscriber missed fires");
        for s in &seen {
            let orig = results
                .iter()
                .find(|r| r.key == s.key && r.step == s.step)
                .unwrap_or_else(|| panic!("no engine fire for {}@{}", s.key, s.step));
            assert!((orig.stability - s.stability).abs() <= 1e-9);
            assert_eq!(orig.eigs.len(), s.eigs.len());
            for (a, b) in orig.eigs.iter().zip(&s.eigs) {
                assert!((a.re - b.re).abs() <= 1e-9 && (a.im - b.im).abs() <= 1e-9);
            }
            assert_eq!(orig.sigma.len(), s.sigma.len());
            for (a, b) in orig.sigma.iter().zip(&s.sigma) {
                assert!((a - b).abs() <= 1e-9);
            }
            assert_eq!(orig.backend, s.backend);
        }
    }

    /// ISSUE 8: with the adaptation controller on but the QoS calm
    /// (loopback, generous budgets), every stream stays pinned at
    /// level 0 and the run reproduces the static coverage exactly —
    /// the adaptive write path must be a no-op when nothing hurts.
    #[test]
    fn adaptive_workflow_stays_at_level_zero_when_calm() {
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.adapt_sweep_ms = 25;
        cfg.adapt_target_p95_us = 60_000_000; // loopback never crosses
        cfg.adapt_queue_hi = 1 << 32;
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert_eq!(rep.analysis_results.len(), 8 * 4);
        assert_eq!(rep.metrics.dropped.get(), 0);
        assert_eq!(
            rep.metrics.adapt.steps_down.get(),
            0,
            "calm QoS must not degrade fidelity"
        );
        assert_eq!(rep.metrics.adapt.steps_up.get(), 0, "nowhere up from level 0");
        for r in 0..4u32 {
            let per = rep
                .analysis_results
                .iter()
                .filter(|a| a.rank == r)
                .count();
            assert_eq!(per, 8, "rank {r}");
        }
    }

    /// ISSUE 9: a traced run (1-in-1 sampling so it's deterministic)
    /// closes the whole hop chain — every fire records a staleness
    /// sample — without changing analysis coverage, and the obs dir
    /// receives both JSONL sinks.
    #[test]
    fn traced_workflow_records_staleness_and_writes_sinks() {
        let obs_root = std::env::temp_dir().join(format!(
            "eb-wf-obs-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&obs_root);
        let mut cfg = tiny_cfg(IoMode::Broker);
        cfg.obs_trace_sample = 1;
        cfg.obs_snapshot_ms = 50;
        cfg.obs_dir = obs_root.to_string_lossy().into_owned();
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert_eq!(
            rep.analysis_results.len(),
            8 * 4,
            "tracing must not change coverage"
        );
        assert_eq!(rep.metrics.dropped.get(), 0);
        let tr = &rep.metrics.trace;
        assert_eq!(tr.sampled.get(), 12 * 4, "every write stamped at 1-in-1");
        assert!(tr.hop_enqueue_us.count() > 0, "enqueue hop ticked");
        assert!(tr.hop_queue_us.count() > 0, "flush hop ticked");
        assert!(tr.hop_deliver_us.count() > 0, "deliver hop ticked");
        assert_eq!(
            tr.staleness_us.count(),
            8 * 4,
            "every fire closes the chain"
        );
        // JSONL sinks landed: at least the shutdown registry snapshot,
        // with the staleness series present by its hierarchical name.
        let snaps =
            std::fs::read_to_string(obs_root.join("metrics.jsonl")).unwrap();
        assert!(snaps.lines().count() >= 1);
        assert!(snaps.contains("\"trace.staleness_us\""));
        assert!(obs_root.join("events.jsonl").exists());
        let _ = std::fs::remove_dir_all(&obs_root);
    }

    #[test]
    fn simulation_only_mode_has_no_cloud() {
        let cfg = tiny_cfg(IoMode::None);
        let rep = run_cfd_workflow(&cfg, None).unwrap();
        assert!(rep.analysis_results.is_empty());
        assert_eq!(rep.metrics.shipped.bytes(), 0);
    }

    #[test]
    fn synth_workflow_small_scale() {
        let rep = run_synth_workflow(4, 30, 64, 50, 0.0, None).unwrap();
        assert_eq!(rep.records, 120);
        assert_eq!(rep.endpoints, 1);
        // window 8+1 fills at 9 → 22 analyses per rank
        assert_eq!(rep.analyses, 4 * 22);
        assert!(rep.metrics.e2e_latency_us.count() > 0);
        assert!(rep.gen_bytes_per_sec > 0.0);
    }
}
