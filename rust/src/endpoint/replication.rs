//! Chain-replication forwarding (ISSUE 10).
//!
//! Each stream is chain-replicated across 2–3 endpoints: the
//! [`crate::broker::Shipper`] writes to the chain *head*, and every
//! replica forwards fenced mutations to its successor before (or
//! after, see [`ReplAck`]) acknowledging them.  This module is the
//! plumbing the [`store`](super::store)/[`server`](super::server) pair
//! uses to reach "the next endpoint in my chain":
//!
//! * [`ReplicaLink`] — one persistent, lazily-dialed connection to a
//!   successor endpoint.  Implemented over the [`Dialer`]/[`Conn`]
//!   transport abstraction, so the exact same code drives real TCP
//!   links in the workflow and in-process [`crate::transport::sim`]
//!   endpoints in the failover tests.
//! * [`ReplicationMap`] — the per-endpoint routing table: stream key →
//!   successor link.  An endpoint can head one chain and sit mid-chain
//!   in another, so the map is keyed per stream, not per store.
//!
//! The forwarded "wire" is the decoded RESP command [`Value`] itself —
//! the successor's [`server::execute`](super::server) dispatches it
//! exactly as if a client had sent it, which is what makes chains of
//! length 3 recurse with no extra protocol: the mid-chain replica's own
//! `ReplicationMap` forwards onward to the tail.
//!
//! Failure semantics: a link failure surfaces as a RESP
//! `Error("REPL ...")` value.  Under [`ReplAck::Tail`] the head turns
//! that into a `REPL` error back to the writer, which retries the frame
//! (the step-watermark dedupe makes the retry exactly-once); under
//! [`ReplAck::Head`] the head acks after its local store and the
//! forward is best-effort (the chain is repaired by the rebalancer's
//! next sweep).

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::transport::{Conn, Dialer, Request};
use crate::wire::Value;

/// When does a replicated write ack back to the writer?
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReplAck {
    /// Ack only after the chain tail has stored the record (zero data
    /// loss on machine failure: anything acked lives on every replica).
    #[default]
    Tail,
    /// Ack after the head's local store; forwarding is asynchronous
    /// best-effort (faster, but records acked in the forwarding window
    /// can be lost with the head's machine).
    Head,
}

impl ReplAck {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tail" => Ok(ReplAck::Tail),
            "head" => Ok(ReplAck::Head),
            other => bail!("replication.ack must be 'tail' or 'head', got '{other}'"),
        }
    }
}

impl fmt::Display for ReplAck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplAck::Tail => write!(f, "tail"),
            ReplAck::Head => write!(f, "head"),
        }
    }
}

/// One connection to a successor endpoint in a replica chain.
///
/// `forward` never returns `Err`: transport failures are folded into a
/// RESP `Error("REPL ...")` value so the caller can treat "successor
/// rejected the write" and "successor unreachable" uniformly (both
/// mean the chain is broken past this endpoint).
pub trait ReplicaLink: Send + Sync {
    /// Ship one decoded command to the successor and return its reply.
    fn forward(&self, cmd: &Value) -> Value;

    /// Topology endpoint slot this link points at (for logs/tests).
    fn target(&self) -> usize;
}

/// Decoded command array → owned [`Request`] (the transport's unit).
fn value_to_request(cmd: &Value) -> Result<Request> {
    let Value::Array(parts) = cmd else {
        bail!("replication: command must be a RESP array, got {cmd}");
    };
    let mut it = parts.iter();
    let Some(Value::Bulk(name)) = it.next() else {
        bail!("replication: empty or non-bulk command array");
    };
    let mut req = Request::new(name.clone());
    for p in it {
        match p {
            Value::Bulk(b) => req = req.arg(b.clone()),
            other => bail!("replication: non-bulk command argument {other}"),
        }
    }
    Ok(req)
}

/// [`ReplicaLink`] over the transport [`Dialer`]: dials lazily on first
/// forward, keeps the connection cached, and retries exactly once on a
/// fresh dial when an exchange fails (the successor may have restarted;
/// the fenced protocol dedupes the re-sent command).
pub struct DialReplicaLink {
    dialer: Arc<dyn Dialer>,
    endpoint: usize,
    conn: Mutex<Option<Box<dyn Conn>>>,
}

impl DialReplicaLink {
    pub fn new(dialer: Arc<dyn Dialer>, endpoint: usize) -> Self {
        DialReplicaLink {
            dialer,
            endpoint,
            conn: Mutex::new(None),
        }
    }

    fn try_forward(&self, req: &Request) -> Result<Value> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(self.dialer.dial(self.endpoint)?);
        }
        let conn = guard.as_mut().unwrap();
        match conn.exchange(std::slice::from_ref(req)) {
            Ok(mut replies) if replies.len() == 1 => Ok(replies.pop().unwrap()),
            Ok(replies) => {
                *guard = None;
                bail!("replica returned {} replies to 1 command", replies.len())
            }
            Err(e) => {
                // Drop the broken connection; the retry dials afresh.
                *guard = None;
                Err(e)
            }
        }
    }
}

impl ReplicaLink for DialReplicaLink {
    fn forward(&self, cmd: &Value) -> Value {
        let req = match value_to_request(cmd) {
            Ok(r) => r,
            Err(e) => return Value::Error(format!("REPL bad forward command: {e:#}")),
        };
        match self.try_forward(&req).or_else(|_| self.try_forward(&req)) {
            Ok(v) => v,
            Err(e) => Value::Error(format!(
                "REPL successor endpoint {} unreachable: {e:#}",
                self.endpoint
            )),
        }
    }

    fn target(&self) -> usize {
        self.endpoint
    }
}

/// Per-endpoint replication routing: stream key → link to the chain
/// successor.  Streams this endpoint *tails* (or that are unreplicated)
/// simply have no entry.  Swapped wholesale on every topology epoch
/// bump via [`super::Store::set_replication`] — links for unchanged
/// successors can be reused across maps by the wiring layer.
pub struct ReplicationMap {
    ack: ReplAck,
    links: HashMap<String, Arc<dyn ReplicaLink>>,
}

impl ReplicationMap {
    pub fn new(ack: ReplAck) -> Self {
        ReplicationMap {
            ack,
            links: HashMap::new(),
        }
    }

    pub fn ack(&self) -> ReplAck {
        self.ack
    }

    /// Route `key`'s forwards to `link`.
    pub fn insert(&mut self, key: impl Into<String>, link: Arc<dyn ReplicaLink>) {
        self.links.insert(key.into(), link);
    }

    /// The successor link for `key`, if this endpoint is not the tail.
    pub fn link_for(&self, key: &str) -> Option<&Arc<dyn ReplicaLink>> {
        self.links.get(key)
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ack_parses_both_modes() {
        assert_eq!(ReplAck::parse("tail").unwrap(), ReplAck::Tail);
        assert_eq!(ReplAck::parse("HEAD").unwrap(), ReplAck::Head);
        assert!(ReplAck::parse("quorum").is_err());
        assert_eq!(ReplAck::Tail.to_string(), "tail");
    }

    #[test]
    fn value_round_trips_to_request() {
        let cmd = Value::Array(vec![
            Value::Bulk(b"XADDF".to_vec()),
            Value::Bulk(b"k".to_vec()),
            Value::Bulk(b"3".to_vec()),
        ]);
        let req = value_to_request(&cmd).unwrap();
        assert_eq!(req.len(), 3);
        assert_eq!(req.part(0), Some(&b"XADDF"[..]));
        assert_eq!(req.to_value(), cmd);
        assert!(value_to_request(&Value::Int(1)).is_err());
    }

    #[test]
    fn map_routes_per_stream() {
        struct Fake(usize);
        impl ReplicaLink for Fake {
            fn forward(&self, _cmd: &Value) -> Value {
                Value::Int(self.0 as i64)
            }
            fn target(&self) -> usize {
                self.0
            }
        }
        let mut map = ReplicationMap::new(ReplAck::Tail);
        map.insert("u/0", Arc::new(Fake(1)));
        map.insert("u/1", Arc::new(Fake(2)));
        assert_eq!(map.link_for("u/0").unwrap().target(), 1);
        assert_eq!(map.link_for("u/1").unwrap().target(), 2);
        assert!(map.link_for("u/2").is_none());
        assert_eq!(map.len(), 2);
    }
}
