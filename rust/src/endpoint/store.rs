//! In-memory stream store — the data model of a Redis-streams endpoint.
//!
//! Streams are append-only logs of `(EntryId, [(field, value)...])`
//! entries.  Entry ids are `<ms>-<seq>` pairs, monotonically increasing
//! per stream exactly like Redis; readers poll with "entries after id".
//!
//! **Sharding:** the key space is hashed (FNV-1a) across
//! [`StoreConfig::shards`] independent shards, each with its own
//! `RwLock<HashMap>` and its own monotonic clock.  Writers to distinct
//! streams on distinct shards never touch the same lock, so concurrent
//! `XADD` throughput scales with the shard count instead of serializing
//! on one global map lock — the scaling substrate for the paper's
//! many-ranks-per-endpoint fan-in.
//!
//! **Id allocation** is a single atomic `fetch_max` on the shard clock
//! (monotonicized wall-clock ms) followed by seq resolution under the
//! per-stream lock, so concurrent auto-id writers can never mint
//! duplicate `(ms, seq)` pairs.
//!
//! Two bounds protect the endpoint (the backpressure story of
//! DESIGN.md §6): a per-stream `maxlen` (oldest entries trimmed, like
//! `XADD ... MAXLEN ~ n`) and a global memory budget (when exceeded,
//! writes fail with a Redis-style `OOM` error the broker backs off on).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use anyhow::{bail, Result};

/// A Redis-style stream entry id: milliseconds + sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct EntryId {
    pub ms: u64,
    pub seq: u64,
}

impl EntryId {
    pub const ZERO: EntryId = EntryId { ms: 0, seq: 0 };

    pub fn next(self) -> EntryId {
        EntryId {
            ms: self.ms,
            seq: self.seq + 1,
        }
    }

    /// Parse `"123-4"`, `"123"` (seq 0), `"0"`, or `"$"`/`"-"`-free forms.
    pub fn parse(s: &str) -> Result<EntryId> {
        let (ms, seq) = match s.split_once('-') {
            Some((a, b)) => (a.parse()?, b.parse()?),
            None => (s.parse()?, 0),
        };
        Ok(EntryId { ms, seq })
    }
}

impl std::fmt::Display for EntryId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-{}", self.ms, self.seq)
    }
}

/// One entry in a stream.
#[derive(Clone, Debug)]
pub struct Entry {
    pub id: EntryId,
    pub fields: Vec<(Vec<u8>, Vec<u8>)>,
}

impl Entry {
    fn byte_size(&self) -> usize {
        16 + self
            .fields
            .iter()
            .map(|(k, v)| k.len() + v.len() + 16)
            .sum::<usize>()
    }
}

/// A single append-only stream.
#[derive(Debug)]
struct Stream {
    entries: VecDeque<Entry>,
    last_id: EntryId,
    bytes: usize,
    /// Total entries ever added (survives trims; used by INFO).
    added: u64,
    /// Epoch fence: the topology epoch of the writer currently allowed
    /// to append (0 = unfenced, plain `XADD` only).  `HELLO`/`XHANDOFF`
    /// raise it; fenced writes (`XADDF`) below it are rejected with a
    /// `STALE` error so a migrated-away (or zombie) writer can never
    /// interleave with its successor.
    writer_epoch: u64,
    /// Highest simulation step landed through fenced writes
    /// (`u64::MAX` = none yet).  `XADDF` at or below this is answered
    /// `DUP` without storing — the server-side dedupe that keeps a
    /// stream exactly-once when a writer re-ships an unacked frame
    /// after a connection failure.
    last_step: u64,
}

impl Default for Stream {
    fn default() -> Self {
        Stream {
            entries: VecDeque::new(),
            last_id: EntryId::ZERO,
            bytes: 0,
            added: 0,
            writer_epoch: 0,
            last_step: u64::MAX, // sentinel: no fenced write yet
        }
    }
}

impl Stream {
    fn last_step(&self) -> Option<u64> {
        if self.last_step == u64::MAX {
            None
        } else {
            Some(self.last_step)
        }
    }
}

/// What [`Store::hello`] tells a (re-)registering writer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HelloReply {
    /// Last assigned entry id (0-0 when the stream is empty).
    pub last_id: EntryId,
    /// Highest step landed through fenced writes, if any — the resume
    /// point: everything at or below this is already durable here.
    pub last_step: Option<u64>,
    /// The epoch now fencing the stream (the caller's).
    pub epoch: u64,
}

/// Outcome of a fenced append ([`Store::xadd_fenced`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FencedAdd {
    /// Stored under this id.
    Added(EntryId),
    /// Step at or below the stream's high-water mark: already stored
    /// by an earlier (possibly unacked) frame; nothing written.
    Duplicate,
}

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Per-stream entry cap; oldest are trimmed past this (0 = unbounded).
    pub stream_maxlen: usize,
    /// Global payload budget in bytes; XADD fails with OOM above it
    /// (0 = unbounded).
    pub max_memory: usize,
    /// Number of independent map shards the key space is hashed across
    /// (values < 1 are clamped to 1).  More shards = less cross-stream
    /// lock contention; streams never span shards.
    pub shards: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            stream_maxlen: 4096,
            max_memory: 1 << 30, // 1 GiB
            shards: 8,
        }
    }
}

/// One independent slice of the key space.
struct Shard {
    streams: RwLock<HashMap<String, Mutex<Stream>>>,
    /// Monotonicized wall-clock ms for this shard's auto-assigned ids.
    clock_ms: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Shard {
            streams: RwLock::new(HashMap::new()),
            clock_ms: AtomicU64::new(0),
        }
    }

    /// Current wall-clock ms, monotonicized (Redis semantics: if the
    /// clock steps back, keep using the last ms and bump seq).  One
    /// atomic op: `fetch_max` returns the previous value, so
    /// `max(prev, wall)` is exactly the value this call stored — no
    /// separate load that could observe a *different* (later) value and
    /// race two writers onto the same `(ms, seq)`.
    fn now_ms(&self) -> u64 {
        let wall = crate::util::epoch_micros() / 1000;
        self.clock_ms.fetch_max(wall, Ordering::AcqRel).max(wall)
    }
}

/// Thread-safe sharded stream store (shared by all connection handlers).
pub struct Store {
    cfg: StoreConfig,
    shards: Vec<Shard>,
    total_bytes: AtomicU64,
    total_entries: AtomicU64,
}

impl Store {
    pub fn new(cfg: StoreConfig) -> Self {
        let n = cfg.shards.max(1);
        Store {
            cfg,
            shards: (0..n).map(|_| Shard::new()).collect(),
            total_bytes: AtomicU64::new(0),
            total_entries: AtomicU64::new(0),
        }
    }

    /// Number of shards the key space is split across.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a key lives on (stable for the store's lifetime).
    pub fn shard_of(&self, key: &str) -> usize {
        (crate::util::fnv1a(key.as_bytes()) % self.shards.len() as u64) as usize
    }

    fn shard(&self, key: &str) -> &Shard {
        &self.shards[self.shard_of(key)]
    }

    /// Run `f` on the (created-if-missing) stream behind `key`, holding
    /// its per-stream lock.
    fn with_stream<R>(&self, key: &str, f: impl FnOnce(&Shard, &mut Stream) -> R) -> R {
        let shard = self.shard(key);
        {
            let map = shard.streams.read().unwrap();
            if let Some(stream) = map.get(key) {
                let mut guard = stream.lock().unwrap();
                return f(shard, &mut guard);
            }
        }
        let mut map = shard.streams.write().unwrap();
        let stream = map.entry(key.to_string()).or_default();
        let mut guard = stream.lock().unwrap();
        f(shard, &mut guard)
    }

    /// Writer (re-)registration with epoch fencing (`HELLO key epoch`).
    ///
    /// Raises the stream's fence to `epoch` and reports the resume
    /// point (last id + last fenced step).  A caller whose epoch is
    /// behind the fence — a writer that was migrated away and didn't
    /// notice yet — is rejected with a `STALE` error and must re-read
    /// the topology before trying again.
    pub fn hello(&self, key: &str, epoch: u64) -> Result<HelloReply> {
        self.with_stream(key, |_, s| {
            if epoch < s.writer_epoch {
                bail!(
                    "STALE epoch {epoch} behind stream epoch {}",
                    s.writer_epoch
                );
            }
            s.writer_epoch = epoch;
            Ok(HelloReply {
                last_id: s.last_id,
                last_step: s.last_step(),
                epoch,
            })
        })
    }

    /// Epoch-fenced, step-deduplicated append (`XADDF`) — the elastic
    /// broker's write primitive.
    ///
    /// * `epoch < fence` → `STALE` error (a migrated-away writer can
    ///   never interleave with its successor);
    /// * `step ≤ high-water` and not `force` → [`FencedAdd::Duplicate`],
    ///   nothing stored (a writer re-shipping an *unacked* frame after
    ///   a connection failure cannot double-store a record);
    /// * `force` skips the dedupe: the writer affirmatively knows the
    ///   record was rejected (an explicit `OOM` reply) even though a
    ///   later step of the same frame landed, so the watermark lies —
    ///   the record is appended late (out of step order, like the
    ///   pre-elastic OOM-inversion behaviour; readers' step dedupe
    ///   skips it at delivery, it stays readable via `XRANGE`);
    /// * otherwise append with an auto id, like `XADD key *`.
    pub fn xadd_fenced(
        &self,
        key: &str,
        epoch: u64,
        step: u64,
        force: bool,
        fields: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> Result<FencedAdd> {
        self.with_stream(key, |shard, s| {
            if epoch < s.writer_epoch {
                bail!(
                    "STALE epoch {epoch} behind stream epoch {}",
                    s.writer_epoch
                );
            }
            s.writer_epoch = epoch;
            if !force && s.last_step != u64::MAX && step <= s.last_step {
                return Ok(FencedAdd::Duplicate);
            }
            if self.cfg.max_memory > 0
                && self.total_bytes.load(Ordering::Relaxed) as usize >= self.cfg.max_memory
            {
                bail!("OOM command not allowed when used memory > 'maxmemory'");
            }
            let id = self.append(shard, s, None, fields)?;
            if s.last_step == u64::MAX || step > s.last_step {
                s.last_step = step;
            }
            Ok(FencedAdd::Added(id))
        })
    }

    /// Append a handoff tombstone (`XHANDOFF key epoch [dest]`): marks
    /// this endpoint's segment of the stream as finished and raises the
    /// fence to `epoch`, so readers know to follow the stream onward
    /// (to `dest`, the endpoint slot the writer migrated to, when
    /// given; readers fall back to the live topology otherwise) and any
    /// write still in flight from the departing epoch is rejected as
    /// stale.  Bypasses the memory budget — the tombstone is the
    /// migration signal and must land even under OOM backpressure.
    pub fn xhandoff(&self, key: &str, epoch: u64, dest: Option<u64>) -> Result<EntryId> {
        self.with_stream(key, |shard, s| {
            if epoch < s.writer_epoch {
                bail!(
                    "STALE epoch {epoch} behind stream epoch {}",
                    s.writer_epoch
                );
            }
            s.writer_epoch = epoch;
            let mut fields = vec![(b"h".to_vec(), epoch.to_string().into_bytes())];
            if let Some(d) = dest {
                fields.push((b"d".to_vec(), d.to_string().into_bytes()));
            }
            self.append(shard, s, None, fields)
        })
    }

    /// Highest fenced step landed on `key` (`XLASTSTEP`; read-only, no
    /// fence check — a departing writer uses it to learn what its
    /// broken frame managed to land before it moves on).
    pub fn fenced_last_step(&self, key: &str) -> Option<u64> {
        let map = self.shard(key).streams.read().unwrap();
        map.get(key).and_then(|s| s.lock().unwrap().last_step())
    }

    /// Current epoch fence of `key` (0 when absent/unfenced).
    pub fn stream_epoch(&self, key: &str) -> u64 {
        let map = self.shard(key).streams.read().unwrap();
        map.get(key)
            .map(|s| s.lock().unwrap().writer_epoch)
            .unwrap_or(0)
    }

    /// Append an entry; `id` of `None` means auto-assign (`XADD key *`).
    pub fn xadd(
        &self,
        key: &str,
        id: Option<EntryId>,
        fields: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> Result<EntryId> {
        if self.cfg.max_memory > 0
            && self.total_bytes.load(Ordering::Relaxed) as usize >= self.cfg.max_memory
        {
            bail!("OOM command not allowed when used memory > 'maxmemory'");
        }
        let shard = self.shard(key);
        // Fast path: stream exists (read lock on the shard map).
        {
            let map = shard.streams.read().unwrap();
            if let Some(stream) = map.get(key) {
                return self.append(shard, &mut stream.lock().unwrap(), id, fields);
            }
        }
        // Slow path: create the stream.
        let mut map = shard.streams.write().unwrap();
        let stream = map.entry(key.to_string()).or_default();
        let mut guard = stream.lock().unwrap();
        let res = self.append(shard, &mut guard, id, fields);
        drop(guard);
        res
    }

    fn append(
        &self,
        shard: &Shard,
        s: &mut Stream,
        id: Option<EntryId>,
        fields: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> Result<EntryId> {
        let id = match id {
            Some(explicit) => {
                if explicit <= s.last_id {
                    bail!(
                        "ERR The ID specified in XADD is equal or smaller than the target stream top item"
                    );
                }
                explicit
            }
            None => {
                let ms = shard.now_ms();
                if ms <= s.last_id.ms {
                    s.last_id.next()
                } else {
                    EntryId { ms, seq: 0 }
                }
            }
        };
        let entry = Entry { id, fields };
        let sz = entry.byte_size();
        s.entries.push_back(entry);
        s.last_id = id;
        s.bytes += sz;
        s.added += 1;
        self.total_bytes.fetch_add(sz as u64, Ordering::Relaxed);
        self.total_entries.fetch_add(1, Ordering::Relaxed);
        if self.cfg.stream_maxlen > 0 {
            while s.entries.len() > self.cfg.stream_maxlen {
                if let Some(old) = s.entries.pop_front() {
                    let osz = old.byte_size();
                    s.bytes -= osz;
                    self.total_bytes.fetch_sub(osz as u64, Ordering::Relaxed);
                }
            }
        }
        Ok(id)
    }

    /// Entries of `key` with id strictly greater than `after`
    /// (`XREAD`-style), up to `count` (0 = all).
    pub fn read_after(&self, key: &str, after: EntryId, count: usize) -> Vec<Entry> {
        let map = self.shard(key).streams.read().unwrap();
        let Some(stream) = map.get(key) else {
            return Vec::new();
        };
        let s = stream.lock().unwrap();
        // Binary search: entries are sorted by id.
        let start = s.entries.partition_point(|e| e.id <= after);
        let take = if count == 0 { usize::MAX } else { count };
        s.entries.iter().skip(start).take(take).cloned().collect()
    }

    /// Inclusive range query (`XRANGE key start end [COUNT n]`).
    pub fn range(&self, key: &str, start: EntryId, end: EntryId, count: usize) -> Vec<Entry> {
        let map = self.shard(key).streams.read().unwrap();
        let Some(stream) = map.get(key) else {
            return Vec::new();
        };
        let s = stream.lock().unwrap();
        let from = s.entries.partition_point(|e| e.id < start);
        let take = if count == 0 { usize::MAX } else { count };
        s.entries
            .iter()
            .skip(from)
            .take_while(|e| e.id <= end)
            .take(take)
            .cloned()
            .collect()
    }

    /// Stream length (`XLEN`).
    pub fn xlen(&self, key: &str) -> usize {
        let map = self.shard(key).streams.read().unwrap();
        map.get(key)
            .map(|s| s.lock().unwrap().entries.len())
            .unwrap_or(0)
    }

    /// Last assigned id of a stream (0-0 when absent).
    pub fn last_id(&self, key: &str) -> EntryId {
        let map = self.shard(key).streams.read().unwrap();
        map.get(key)
            .map(|s| s.lock().unwrap().last_id)
            .unwrap_or(EntryId::ZERO)
    }

    /// Delete streams; returns how many existed (`DEL`).
    pub fn del(&self, keys: &[&str]) -> usize {
        let mut n = 0;
        for key in keys {
            let mut map = self.shard(key).streams.write().unwrap();
            if let Some(s) = map.remove(*key) {
                let bytes = s.lock().unwrap().bytes;
                self.total_bytes.fetch_sub(bytes as u64, Ordering::Relaxed);
                n += 1;
            }
        }
        n
    }

    /// Drop everything (`FLUSHALL`).
    pub fn flush_all(&self) {
        for shard in &self.shards {
            shard.streams.write().unwrap().clear();
        }
        self.total_bytes.store(0, Ordering::Relaxed);
    }

    /// Keys matching a glob-lite pattern (`*` suffix/prefix only, or exact).
    pub fn keys(&self, pattern: &str) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for shard in &self.shards {
            let map = shard.streams.read().unwrap();
            out.extend(map.keys().filter(|k| glob_lite(pattern, k)).cloned());
        }
        out.sort();
        out
    }

    /// Total number of live streams across all shards.
    pub fn stream_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.streams.read().unwrap().len())
            .sum()
    }

    /// INFO text (mirrors the fields the paper's Table 1b cares about).
    pub fn info(&self) -> String {
        format!(
            "# Server\r\nserver:elasticbroker-endpoint\r\nversion:0.1.0\r\nproto:RESP2\r\n\
             # Memory\r\nused_memory:{}\r\nmaxmemory:{}\r\n\
             # Streams\r\nstreams:{}\r\ntotal_entries_added:{}\r\nstream_maxlen:{}\r\nshards:{}\r\n",
            self.total_bytes.load(Ordering::Relaxed),
            self.cfg.max_memory,
            self.stream_count(),
            self.total_entries.load(Ordering::Relaxed),
            self.cfg.stream_maxlen,
            self.shards.len(),
        )
    }

    pub fn used_bytes(&self) -> u64 {
        self.total_bytes.load(Ordering::Relaxed)
    }

    pub fn total_entries_added(&self) -> u64 {
        self.total_entries.load(Ordering::Relaxed)
    }
}

/// `*`, `prefix*`, `*suffix`, `*infix*`, or exact match.
fn glob_lite(pattern: &str, s: &str) -> bool {
    if pattern == "*" {
        return true;
    }
    match (pattern.strip_prefix('*'), pattern.strip_suffix('*')) {
        (Some(rest), None) => s.ends_with(rest),
        (None, Some(rest)) => s.starts_with(rest),
        (Some(_), Some(_)) => {
            let infix = &pattern[1..pattern.len() - 1];
            s.contains(infix)
        }
        (None, None) => s == pattern,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, U64Range};

    fn fields(v: &str) -> Vec<(Vec<u8>, Vec<u8>)> {
        vec![(b"r".to_vec(), v.as_bytes().to_vec())]
    }

    #[test]
    fn xadd_auto_ids_monotonic() {
        let store = Store::new(StoreConfig::default());
        let mut last = EntryId::ZERO;
        for i in 0..100 {
            let id = store.xadd("s", None, fields(&i.to_string())).unwrap();
            assert!(id > last, "id {id} not > {last}");
            last = id;
        }
        assert_eq!(store.xlen("s"), 100);
        assert_eq!(store.last_id("s"), last);
    }

    #[test]
    fn xadd_explicit_id_must_increase() {
        let store = Store::new(StoreConfig::default());
        let id = EntryId { ms: 5, seq: 1 };
        store.xadd("s", Some(id), fields("a")).unwrap();
        assert!(store.xadd("s", Some(id), fields("b")).is_err());
        assert!(store
            .xadd("s", Some(EntryId { ms: 5, seq: 0 }), fields("c"))
            .is_err());
        store
            .xadd("s", Some(EntryId { ms: 5, seq: 2 }), fields("d"))
            .unwrap();
    }

    #[test]
    fn read_after_returns_only_newer() {
        let store = Store::new(StoreConfig::default());
        let mut ids = Vec::new();
        for i in 0..10 {
            ids.push(
                store
                    .xadd("s", Some(EntryId { ms: i + 1, seq: 0 }), fields(&i.to_string()))
                    .unwrap(),
            );
        }
        let got = store.read_after("s", ids[4], 0);
        assert_eq!(got.len(), 5);
        assert_eq!(got[0].id, ids[5]);
        let limited = store.read_after("s", EntryId::ZERO, 3);
        assert_eq!(limited.len(), 3);
        assert!(store.read_after("s", ids[9], 0).is_empty());
        assert!(store.read_after("missing", EntryId::ZERO, 0).is_empty());
    }

    #[test]
    fn range_inclusive() {
        let store = Store::new(StoreConfig::default());
        for i in 1..=5u64 {
            store
                .xadd("s", Some(EntryId { ms: i, seq: 0 }), fields("x"))
                .unwrap();
        }
        let got = store.range(
            "s",
            EntryId { ms: 2, seq: 0 },
            EntryId { ms: 4, seq: 0 },
            0,
        );
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn maxlen_trims_oldest() {
        let store = Store::new(StoreConfig {
            stream_maxlen: 5,
            max_memory: 0,
            ..Default::default()
        });
        for i in 0..12u64 {
            store
                .xadd("s", Some(EntryId { ms: i + 1, seq: 0 }), fields("x"))
                .unwrap();
        }
        assert_eq!(store.xlen("s"), 5);
        let got = store.read_after("s", EntryId::ZERO, 0);
        assert_eq!(got[0].id.ms, 8); // 12 added, first 7 trimmed
        assert_eq!(store.total_entries_added(), 12);
    }

    #[test]
    fn oom_when_over_budget() {
        let store = Store::new(StoreConfig {
            stream_maxlen: 0,
            max_memory: 100,
            ..Default::default()
        });
        let big = vec![(b"r".to_vec(), vec![0u8; 100])];
        store.xadd("s", None, big.clone()).unwrap();
        let err = store.xadd("s", None, big).unwrap_err();
        assert!(err.to_string().contains("OOM"), "{err}");
        // freeing makes room again
        store.flush_all();
        assert_eq!(store.used_bytes(), 0);
        store.xadd("s", None, fields("ok")).unwrap();
    }

    #[test]
    fn del_and_keys() {
        let store = Store::new(StoreConfig::default());
        store.xadd("velocity/0", None, fields("a")).unwrap();
        store.xadd("velocity/1", None, fields("b")).unwrap();
        store.xadd("pressure/0", None, fields("c")).unwrap();
        assert_eq!(store.keys("velocity/*").len(), 2);
        assert_eq!(store.keys("*"), vec!["pressure/0", "velocity/0", "velocity/1"]);
        assert_eq!(store.keys("*0").len(), 2);
        assert_eq!(store.del(&["velocity/0", "nope"]), 1);
        assert_eq!(store.keys("velocity/*").len(), 1);
    }

    #[test]
    fn entry_id_parse_display_roundtrip() {
        for s in ["0-0", "123-4", "99999-1"] {
            assert_eq!(EntryId::parse(s).unwrap().to_string(), s);
        }
        assert_eq!(
            EntryId::parse("42").unwrap(),
            EntryId { ms: 42, seq: 0 }
        );
        assert!(EntryId::parse("a-b").is_err());
    }

    #[test]
    fn info_contains_counters() {
        let store = Store::new(StoreConfig::default());
        store.xadd("s", None, fields("x")).unwrap();
        let info = store.info();
        assert!(info.contains("streams:1"));
        assert!(info.contains("total_entries_added:1"));
        assert!(info.contains("shards:8"));
    }

    #[test]
    fn shard_of_is_stable_and_spreads() {
        let store = Store::new(StoreConfig::default());
        assert_eq!(store.shard_count(), 8);
        let keys: Vec<String> = (0..64).map(|i| format!("velocity/{i}")).collect();
        let mut hit = vec![false; store.shard_count()];
        for k in &keys {
            let s = store.shard_of(k);
            assert_eq!(s, store.shard_of(k), "unstable shard for {k}");
            assert!(s < store.shard_count());
            hit[s] = true;
        }
        // 64 keys over 8 shards: FNV must touch more than one shard.
        assert!(hit.iter().filter(|&&h| h).count() > 1, "all keys on one shard");
    }

    #[test]
    fn single_shard_store_still_correct() {
        let store = Store::new(StoreConfig {
            shards: 1,
            ..Default::default()
        });
        for i in 0..10 {
            store.xadd(&format!("k/{i}"), None, fields("x")).unwrap();
        }
        assert_eq!(store.keys("*").len(), 10);
        assert_eq!(store.stream_count(), 10);
        assert_eq!(store.shard_count(), 1);
    }

    #[test]
    fn zero_shards_clamped_to_one() {
        let store = Store::new(StoreConfig {
            shards: 0,
            ..Default::default()
        });
        store.xadd("s", None, fields("x")).unwrap();
        assert_eq!(store.shard_count(), 1);
        assert_eq!(store.xlen("s"), 1);
    }

    /// Regression (ISSUE 1): id allocation must be a single atomic op.
    /// 8 threads hammering auto-ids on ONE stream must never mint a
    /// duplicate `(ms, seq)` pair.
    #[test]
    fn concurrent_xadd_ids_unique_and_monotonic() {
        let store = std::sync::Arc::new(Store::new(StoreConfig::default()));
        let mut handles = Vec::new();
        for t in 0..8 {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                for i in 0..500 {
                    ids.push(
                        store
                            .xadd("s", None, fields(&format!("{t}:{i}")))
                            .unwrap(),
                    );
                }
                ids
            }));
        }
        let mut all: Vec<EntryId> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate ids under concurrency");
        assert_eq!(store.xlen("s"), 4000);
    }

    /// 8 threads × 8 distinct streams (spread across shards): every
    /// record lands exactly once, per-stream ids stay unique and
    /// strictly increasing, and global counters agree.
    #[test]
    fn concurrent_distinct_streams_exactly_once_across_shards() {
        let store = std::sync::Arc::new(Store::new(StoreConfig::default()));
        let per = 500usize;
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let store = store.clone();
                std::thread::spawn(move || {
                    let key = format!("u/{t}");
                    let mut ids = Vec::new();
                    for i in 0..per {
                        ids.push(store.xadd(&key, None, fields(&i.to_string())).unwrap());
                    }
                    (key, ids)
                })
            })
            .collect();
        for h in handles {
            let (key, ids) = h.join().unwrap();
            assert_eq!(store.xlen(&key), per);
            for w in ids.windows(2) {
                assert!(w[1] > w[0], "{key}: {} !> {}", w[1], w[0]);
            }
            // what the store returns matches what the writer saw, in order
            let entries = store.read_after(&key, EntryId::ZERO, 0);
            let got: Vec<EntryId> = entries.iter().map(|e| e.id).collect();
            assert_eq!(got, ids, "{key}");
        }
        assert_eq!(store.total_entries_added(), 8 * per as u64);
        assert_eq!(store.stream_count(), 8);
    }

    /// ISSUE 3: epoch fencing — a writer behind the stream's epoch is
    /// rejected (write *and* registration) until it re-registers at a
    /// current epoch.
    #[test]
    fn stale_epoch_writes_rejected_after_takeover() {
        let store = Store::new(StoreConfig::default());
        store.hello("u/0", 1).unwrap();
        assert_eq!(
            store.xadd_fenced("u/0", 1, 0, false, fields("a")).unwrap(),
            FencedAdd::Added(store.last_id("u/0"))
        );
        // takeover: a successor hands the stream off at epoch 2
        store.xhandoff("u/0", 2, Some(1)).unwrap();
        assert_eq!(store.stream_epoch("u/0"), 2);
        let err = store.xadd_fenced("u/0", 1, 1, false, fields("b")).unwrap_err();
        assert!(err.to_string().starts_with("STALE"), "{err}");
        let err = store.hello("u/0", 1).unwrap_err();
        assert!(err.to_string().starts_with("STALE"), "{err}");
        // re-register at the current epoch: accepted, resume point intact
        let re = store.hello("u/0", 2).unwrap();
        assert_eq!(re.last_step, Some(0));
        assert!(matches!(
            store.xadd_fenced("u/0", 2, 1, false, fields("c")).unwrap(),
            FencedAdd::Added(_)
        ));
        // stream: record a, tombstone, record c — the stale 'b' never landed
        assert_eq!(store.xlen("u/0"), 3);
    }

    /// ISSUE 3: server-side step dedupe — re-shipping an unacked frame
    /// cannot double-store a record.
    #[test]
    fn fenced_duplicate_steps_not_stored() {
        let store = Store::new(StoreConfig::default());
        let hello = store.hello("u/0", 1).unwrap();
        assert_eq!(hello.last_step, None);
        assert_eq!(hello.last_id, EntryId::ZERO);
        for step in 0..4u64 {
            assert!(matches!(
                store.xadd_fenced("u/0", 1, step, false, fields("x")).unwrap(),
                FencedAdd::Added(_)
            ));
        }
        // the whole frame re-shipped: every record is a dup, none stored
        for step in 0..4u64 {
            assert_eq!(
                store.xadd_fenced("u/0", 1, step, false, fields("x")).unwrap(),
                FencedAdd::Duplicate
            );
        }
        assert_eq!(store.xlen("u/0"), 4);
        assert_eq!(store.fenced_last_step("u/0"), Some(3));
        // fresh steps still land
        assert!(matches!(
            store.xadd_fenced("u/0", 1, 4, false, fields("x")).unwrap(),
            FencedAdd::Added(_)
        ));
        assert_eq!(store.xlen("u/0"), 5);
    }

    /// The OOM-inversion escape hatch: a writer that *knows* a record
    /// was explicitly rejected (not merely unacked) forces it past the
    /// watermark dedupe so it is never silently lost.
    #[test]
    fn forced_write_bypasses_step_dedupe() {
        let store = Store::new(StoreConfig::default());
        store.hello("u/0", 1).unwrap();
        store.xadd_fenced("u/0", 1, 5, false, fields("a")).unwrap();
        // un-forced: swallowed as a duplicate
        assert_eq!(
            store.xadd_fenced("u/0", 1, 3, false, fields("late")).unwrap(),
            FencedAdd::Duplicate
        );
        // forced: stored (late, out of step order), watermark untouched
        assert!(matches!(
            store.xadd_fenced("u/0", 1, 3, true, fields("late")).unwrap(),
            FencedAdd::Added(_)
        ));
        assert_eq!(store.xlen("u/0"), 2);
        assert_eq!(store.fenced_last_step("u/0"), Some(5));
        // fencing still applies to forced writes
        store.xhandoff("u/0", 2, None).unwrap();
        let err = store
            .xadd_fenced("u/0", 1, 9, true, fields("x"))
            .unwrap_err();
        assert!(err.to_string().starts_with("STALE"), "{err}");
    }

    #[test]
    fn handoff_tombstone_lands_even_under_oom() {
        let store = Store::new(StoreConfig {
            stream_maxlen: 0,
            max_memory: 60,
            ..Default::default()
        });
        store.hello("u/0", 1).unwrap();
        store
            .xadd_fenced("u/0", 1, 0, false, vec![(b"r".to_vec(), vec![0u8; 64])])
            .unwrap();
        let err = store
            .xadd_fenced("u/0", 1, 1, false, vec![(b"r".to_vec(), vec![0u8; 64])])
            .unwrap_err();
        assert!(err.to_string().contains("OOM"), "{err}");
        // the migration signal must still land
        store.xhandoff("u/0", 2, Some(1)).unwrap();
        assert_eq!(store.stream_epoch("u/0"), 2);
        let entries = store.read_after("u/0", EntryId::ZERO, 0);
        assert_eq!(entries.last().unwrap().fields[0].0, b"h");
    }

    #[test]
    fn unfenced_stream_reports_zero_epoch_and_no_step() {
        let store = Store::new(StoreConfig::default());
        store.xadd("plain", None, fields("x")).unwrap();
        assert_eq!(store.stream_epoch("plain"), 0);
        assert_eq!(store.fenced_last_step("plain"), None);
        assert_eq!(store.stream_epoch("absent"), 0);
        assert_eq!(store.fenced_last_step("absent"), None);
    }

    /// Property: after any interleaving of adds, read_after(last_id of a
    /// prefix) returns exactly the suffix.
    #[test]
    fn prop_read_after_partitions_stream() {
        prop::forall(31, 50, &U64Range(1, 60), |n| {
            let store = Store::new(StoreConfig::default());
            let mut ids = Vec::new();
            for i in 0..*n {
                ids.push(
                    store
                        .xadd("s", Some(EntryId { ms: i + 1, seq: 0 }), fields("x"))
                        .unwrap(),
                );
            }
            for (i, id) in ids.iter().enumerate() {
                let rest = store.read_after("s", *id, 0);
                if rest.len() != ids.len() - i - 1 {
                    return Err(format!(
                        "after {id}: got {} want {}",
                        rest.len(),
                        ids.len() - i - 1
                    ));
                }
            }
            Ok(())
        });
    }
}
